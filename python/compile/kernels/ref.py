"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
ground truth (pytest asserts kernel == oracle before any artifact ships).
"""

import jax
import jax.numpy as jnp


def xt_r_ref(x, r):
    """``X^T r`` reference."""
    return x.T @ r


def x_beta_ref(x, beta):
    """``X @ beta`` reference."""
    return x @ beta


def sgl_prox_ref(z_pad, l1_thresh, group_thresh):
    """Exact SGL prox on the segment-padded layout, straight jnp."""
    u = jnp.sign(z_pad) * jnp.maximum(jnp.abs(z_pad) - l1_thresh, 0.0)
    norms = jnp.sqrt(jnp.sum(u * u, axis=1))
    scale = jnp.where(
        norms > group_thresh, 1.0 - group_thresh / jnp.maximum(norms, 1e-300), 0.0
    )
    return u * scale[:, None]


def grad_squared_ref(x, beta, y):
    """``∇ (1/2n)‖y − Xβ‖²  =  Xᵀ(Xβ − y)/n``."""
    n = x.shape[0]
    return x.T @ (x @ beta - y) / n


def grad_logistic_ref(x, beta, y):
    """``∇ mean logistic deviance = Xᵀ(σ(Xβ) − y)/n``."""
    n = x.shape[0]
    eta = x @ beta
    return x.T @ (jax.nn.sigmoid(eta) - y) / n
