"""Fused sparse-group prox Pallas kernel.

The exact SGL prox is soft-threshold-then-group-shrink (Simon et al. 2013).
Group structure is irregular, so the kernel works on a *segment-padded*
layout the Rust coordinator also uses for its bucketed artifacts: groups are
padded to a common width ``gmax`` and stacked, giving a dense
``(m, gmax)`` tile where pad lanes carry zeros (zeros are fixed points of
the prox, so padding is harmless).

One grid step processes a strip of groups: soft-threshold the strip,
compute per-group ℓ2 norms with an in-VMEM row reduction, then apply the
group scaling — all fused, one HBM round trip.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Groups per grid step. With gmax ≤ 128 a strip is ≤ 8·128 f64 lanes.
TILE_G = 8


def _sgl_prox_kernel(z_ref, l1_ref, gthr_ref, o_ref):
    z = z_ref[...]  # (TILE_G, gmax)
    l1 = l1_ref[...]  # (TILE_G, gmax) per-lane soft thresholds
    gthr = gthr_ref[...]  # (TILE_G,) group l2 thresholds
    u = jnp.sign(z) * jnp.maximum(jnp.abs(z) - l1, 0.0)
    norms = jnp.sqrt(jnp.sum(u * u, axis=1))
    scale = jnp.where(norms > gthr, 1.0 - gthr / jnp.maximum(norms, 1e-300), 0.0)
    o_ref[...] = u * scale[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sgl_prox(z_pad, l1_thresh, group_thresh, interpret=True):
    """Exact SGL prox on the segment-padded layout.

    Args:
        z_pad: ``(m, gmax)`` padded coefficient blocks.
        l1_thresh: ``(m, gmax)`` per-lane ℓ1 thresholds
            (``t·λ·α·v_i``; set pad lanes to anything — they hold zeros).
        group_thresh: ``(m,)`` per-group ℓ2 thresholds
            (``t·λ·(1−α)·w_g·√p_g``).
    Returns:
        ``(m, gmax)`` prox output in the same layout.
    """
    m, gmax = z_pad.shape
    pad_m = (-m) % TILE_G
    if pad_m:
        z_pad = jnp.pad(z_pad, ((0, pad_m), (0, 0)))
        l1_thresh = jnp.pad(l1_thresh, ((0, pad_m), (0, 0)))
        group_thresh = jnp.pad(group_thresh, ((0, pad_m),))
    grid = (z_pad.shape[0] // TILE_G,)
    out = pl.pallas_call(
        _sgl_prox_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_G, gmax), lambda i: (i, 0)),
            pl.BlockSpec((TILE_G, gmax), lambda i: (i, 0)),
            pl.BlockSpec((TILE_G,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE_G, gmax), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(z_pad.shape, z_pad.dtype),
        interpret=interpret,
    )(z_pad, l1_thresh, group_thresh)
    return out[:m]
