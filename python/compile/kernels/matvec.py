"""Tiled mat-vec Pallas kernels — the two O(np) operations under every
screening gradient.

TPU mapping (DESIGN.md §Hardware-Adaptation): a mat-vec is VPU/bandwidth
bound, so the win is the HBM↔VMEM schedule, expressed with BlockSpec tiles:

* ``xt_r`` tiles the *p* axis: each grid step keeps one ``(n, TILE_P)``
  block of ``X`` plus the full residual ``r`` resident in VMEM and emits a
  ``TILE_P`` slice of the output. For the paper-scale designs
  (n ≈ 200–10 000), a 128-column f32 tile is ≤ 5 MB — comfortably within
  the ~16 MB VMEM budget, leaving room for double buffering.
* ``x_beta`` tiles the *n* axis symmetrically.

Grid sizes must divide the array, so callers pad to the tile multiple; the
wrappers here handle the padding (zero rows/columns contribute zeros).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes chosen for the VMEM budget discussed above. Kept small enough
# that even the surrogate real datasets (p ≈ 18k) get >100 grid steps of
# pipelining.
TILE_P = 128
TILE_N = 128


def _xt_r_kernel(x_ref, r_ref, o_ref):
    """One output tile: o[tile] = X[:, tile]^T @ r."""
    x_blk = x_ref[...]  # (n, TILE_P)
    r = r_ref[...]  # (n,)
    o_ref[...] = x_blk.T @ r


def _x_beta_kernel(x_ref, b_ref, o_ref):
    """One output tile: o[tile] = X[tile, :] @ beta."""
    x_blk = x_ref[...]  # (TILE_N, p)
    b = b_ref[...]  # (p,)
    o_ref[...] = x_blk @ b


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, size
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("interpret",))
def xt_r(x, r, interpret=True):
    """``X^T r`` via the tiled Pallas kernel.

    Args:
        x: ``(n, p)`` design block.
        r: ``(n,)`` residual.
    Returns:
        ``(p,)`` correlation vector.
    """
    n, p = x.shape
    x_pad, p0 = _pad_to(x, 1, TILE_P)
    grid = (x_pad.shape[1] // TILE_P,)
    out = pl.pallas_call(
        _xt_r_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, TILE_P), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_P,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x_pad.shape[1],), x.dtype),
        interpret=interpret,
    )(x_pad, r)
    return out[:p0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def x_beta(x, beta, interpret=True):
    """``X @ beta`` via the tiled Pallas kernel.

    Args:
        x: ``(n, p)`` design block.
        beta: ``(p,)`` coefficients.
    Returns:
        ``(n,)`` linear predictor.
    """
    n, p = x.shape
    x_pad, n0 = _pad_to(x, 0, TILE_N)
    grid = (x_pad.shape[0] // TILE_N,)
    out = pl.pallas_call(
        _x_beta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, p), lambda i: (i, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x_pad.shape[0],), x.dtype),
        interpret=interpret,
    )(x_pad, beta)
    return out[:n0]
