"""Layer-1 Pallas kernels for the DFR compute hot path.

All kernels run in ``interpret=True`` mode: the PJRT CPU plugin cannot
execute real-TPU Mosaic custom-calls, so interpret mode lowers them to plain
HLO that both pytest (build time) and the Rust runtime (fit time) can run.
Correctness is pinned against the pure-jnp oracles in :mod:`ref`.
"""

from .matvec import x_beta, xt_r  # noqa: F401
from .prox import sgl_prox  # noqa: F401
