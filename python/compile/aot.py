"""AOT compile path: lower the L2 gradient graphs to HLO **text** under
``artifacts/`` for the Rust PJRT runtime.

Run once by ``make artifacts`` (no-op when outputs are newer than inputs);
Python never runs at fit time.

HLO text — not ``lowered.compile()`` or serialized protos — is the
interchange format: the image's xla_extension 0.5.1 rejects jax ≥ 0.5
serialized ``HloModuleProto``s (64-bit instruction ids), while the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and aot_recipe.md).

Artifact naming is the runtime contract
(``rust/src/runtime/mod.rs::gradient_stem``)::

    grad_sq_{n}x{p}.hlo.txt    (X[n,p], beta[p], y[n]) -> (grad[p],)
    grad_log_{n}x{p}.hlo.txt   same, logistic residual

The default shape set covers the experiment configurations the examples and
benches use; extend with ``--shape NxP`` (repeatable).
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Shapes compiled by default: (n, p) pairs used by the examples/benches.
# The e2e example uses the Table A1 default (200, 1000); smoke shapes keep
# tests fast.
DEFAULT_SHAPES = [
    (32, 64),  # integration-test smoke shape
    (200, 1000),  # Table A1 default synthetic design
    (80, 400),  # Table 1 interaction base design
]

LOSSES = ("sq", "log")

# Bucketed FISTA-chunk artifacts: (n, p_bucket) pairs. The coordinator
# gathers the screened optimization set into the next power-of-two bucket
# (DESIGN.md §6.1); one 50-iteration executable per shape.
FISTA_ITERS = 50
FISTA_BUCKETS = [
    (32, 32),
    (32, 64),
    (200, 32),
    (200, 64),
    (200, 128),
    (200, 256),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gradient(loss: str, n: int, p: int, use_pallas: bool = True) -> str:
    x = jax.ShapeDtypeStruct((n, p), jnp.float64)
    beta = jax.ShapeDtypeStruct((p,), jnp.float64)
    y = jax.ShapeDtypeStruct((n,), jnp.float64)
    fn = model.grad_squared if loss == "sq" else model.grad_logistic
    jitted = jax.jit(lambda X, b, Y: fn(X, b, Y, use_pallas=use_pallas, interpret=True))
    return to_hlo_text(jitted.lower(x, beta, y))


def lower_fista_chunk(n: int, pb: int, n_iters: int = FISTA_ITERS) -> str:
    """Lower a fixed-step FISTA chunk on an (n, pb) bucket (squared loss).

    Parameter order is the runtime contract
    (`rust/src/runtime/mod.rs::solve_reduced`): x, y, beta, z, t, step,
    l1_thresh, group_onehot, group_thresh → (beta', z', t', delta).
    """
    f64 = jnp.float64
    args = (
        jax.ShapeDtypeStruct((n, pb), f64),  # x
        jax.ShapeDtypeStruct((n,), f64),  # y
        jax.ShapeDtypeStruct((pb,), f64),  # beta
        jax.ShapeDtypeStruct((pb,), f64),  # z
        jax.ShapeDtypeStruct((), f64),  # t
        jax.ShapeDtypeStruct((), f64),  # step
        jax.ShapeDtypeStruct((pb,), f64),  # l1_thresh
        jax.ShapeDtypeStruct((pb, pb), f64),  # group_onehot (m_b = p_b)
        jax.ShapeDtypeStruct((pb,), f64),  # group_thresh
    )
    jitted = jax.jit(
        lambda x, y, b, z, t, s, l1, oh, gt: model.fista_chunk(
            x, y, b, z, t, s, l1, oh, gt, n_iters=n_iters
        )
    )
    return to_hlo_text(jitted.lower(*args))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shape",
        action="append",
        default=[],
        help="extra NxP gradient shapes (e.g. --shape 120x1898)",
    )
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower plain-jnp graphs instead of the Pallas kernels (ablation)",
    )
    args = ap.parse_args()

    shapes = list(DEFAULT_SHAPES)
    for s in args.shape:
        n, p = s.lower().split("x")
        shapes.append((int(n), int(p)))

    os.makedirs(args.out_dir, exist_ok=True)
    written = 0
    for n, p in shapes:
        for loss in LOSSES:
            name = f"grad_{loss}_{n}x{p}.hlo.txt"
            path = os.path.join(args.out_dir, name)
            text = lower_gradient(loss, n, p, use_pallas=not args.no_pallas)
            with open(path, "w") as f:
                f.write(text)
            written += 1
            print(f"[aot] {path} ({len(text)} chars)")
    for n, pb in FISTA_BUCKETS:
        name = f"fista_sq_{n}x{pb}_t{FISTA_ITERS}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_fista_chunk(n, pb)
        with open(path, "w") as f:
            f.write(text)
        written += 1
        print(f"[aot] {path} ({len(text)} chars)")
    # Stamp file lets `make` skip rebuilds when inputs are unchanged.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"[aot] wrote {written} artifacts to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
