"""Layer-2 JAX model: the gradient graphs the Rust coordinator executes.

The pathwise screening loop needs the *full* gradient ``∇f(β̂)`` at every
path point (screening rules Eq. 5–8 and the KKT checks Eq. 17/26 all read
it) — an O(np) computation and the dominant per-point cost. These functions
express it in JAX, with the inner mat-vecs delegated to the Layer-1 Pallas
kernels, and are lowered once by :mod:`aot` to HLO text for the PJRT
runtime. Everything is f64 (``jax_enable_x64``) so Rust-side screening
decisions keep full precision.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import matvec  # noqa: E402


def grad_squared(x, beta, y, *, use_pallas=True, interpret=True):
    """``Xᵀ(Xβ − y)/n`` — gradient of ``(1/2n)‖y − Xβ‖²``.

    Returns a 1-tuple so the lowered computation has a tuple root (the Rust
    loader unwraps with ``to_tuple1``).
    """
    n = x.shape[0]
    if use_pallas:
        xb = matvec.x_beta(x, beta, interpret=interpret)
        g = matvec.xt_r(x, xb - y, interpret=interpret)
    else:
        xb = x @ beta
        g = x.T @ (xb - y)
    return (g / n,)


def grad_logistic(x, beta, y, *, use_pallas=True, interpret=True):
    """``Xᵀ(σ(Xβ) − y)/n`` — gradient of the mean logistic deviance."""
    n = x.shape[0]
    if use_pallas:
        eta = matvec.x_beta(x, beta, interpret=interpret)
        r = jax.nn.sigmoid(eta) - y
        g = matvec.xt_r(x, r, interpret=interpret)
    else:
        eta = x @ beta
        g = x.T @ (jax.nn.sigmoid(eta) - y)
    return (g / n,)


def fista_chunk(x, y, beta, z, t, step, l1_thresh, group_onehot, group_thresh,
                n_iters=50):
    """A fixed-step FISTA chunk on a *bucketed* reduced design — the AOT
    inner-solver of DESIGN.md §6.1.

    Screening makes the optimization set shrink per path point while XLA
    artifacts are fixed-shape; the Rust coordinator gathers the active
    columns into the next power-of-two bucket and runs chunks of
    ``n_iters`` iterations between convergence checks. Padding is safe by
    construction: pad columns of ``x`` are zero (gradient 0), their
    ``l1_thresh`` ≥ 0 keeps them at 0 through the soft-threshold, and pad
    groups have zero one-hot rows (norm 0 → scale 0).

    Group structure arrives as a dense one-hot matrix ``(m_b, p_b)`` so the
    prox is pure matmul/elementwise — no scatters, which XLA-CPU handles
    poorly.

    Args:
        x: ``(n, p_b)`` padded reduced design.
        y: ``(n,)`` response.
        beta, z: ``(p_b,)`` FISTA state (iterate and extrapolation point).
        t: scalar momentum state.
        step: scalar step size (≤ 1/L, supplied by the coordinator from its
            power-iteration Lipschitz bound).
        l1_thresh: ``(p_b,)`` per-variable ℓ1 prox thresholds ``λαvᵢ``
            (NOT yet multiplied by the step).
        group_onehot: ``(m_b, p_b)`` group membership.
        group_thresh: ``(m_b,)`` group ℓ2 thresholds ``λ(1−α)w_g√p_g``.
    Returns:
        ``(beta', z', t', delta)`` — updated state plus the last
        iteration's ‖β_{k+1} − β_k‖₂ for the coordinator's convergence
        check.
    """
    n = x.shape[0]

    def body(_, state):
        beta, z, t, _ = state
        grad = x.T @ (x @ z - y) / n
        u = z - step * grad
        u = jnp.sign(u) * jnp.maximum(jnp.abs(u) - step * l1_thresh, 0.0)
        gnorm = jnp.sqrt(group_onehot @ (u * u))
        gthr = step * group_thresh
        scale_g = jnp.where(gnorm > gthr, 1.0 - gthr / jnp.maximum(gnorm, 1e-300), 0.0)
        beta_new = u * (group_onehot.T @ scale_g)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = beta_new + ((t - 1.0) / t_new) * (beta_new - beta)
        delta = jnp.sqrt(jnp.sum((beta_new - beta) ** 2))
        return (beta_new, z_new, t_new, delta)

    init = (beta, z, t, jnp.asarray(0.0, x.dtype))
    return jax.lax.fori_loop(0, n_iters, body, init)


def objective_squared(x, beta, y, lam_l1, lam_group, gid_onehot, sqrt_pg):
    """Primal SGL objective on a padded group layout — exported for
    diagnostics/ablations (not on the fit hot path).

    ``gid_onehot``: (m, p) one-hot rows mapping variables to groups;
    ``sqrt_pg``: (m,) group weights. Dense one-hot keeps the graph free of
    scatters, which XLA-CPU handles poorly.
    """
    n = x.shape[0]
    resid = y - x @ beta
    f = 0.5 * jnp.sum(resid * resid) / n
    l1 = lam_l1 * jnp.sum(jnp.abs(beta))
    gnorms = jnp.sqrt(gid_onehot @ (beta * beta))
    gl = lam_group * jnp.sum(sqrt_pg * gnorms)
    return (f + l1 + gl,)
