"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; fixed cases pin edge geometry
(tile-exact, sub-tile, off-by-one) and value edge cases (zeros, negatives,
huge thresholds).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matvec, prox, ref

SHAPES = st.tuples(st.integers(1, 70), st.integers(1, 300))


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@settings(max_examples=30, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16), dtype=st.sampled_from(["float32", "float64"]))
def test_xt_r_matches_reference(shape, seed, dtype):
    n, p = shape
    rng = np.random.default_rng(seed)
    x = rand(rng, (n, p), dtype)
    r = rand(rng, (n,), dtype)
    got = matvec.xt_r(x, r)
    want = ref.xt_r_ref(x, r)
    tol = 1e-5 if dtype == "float32" else 1e-12
    assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@settings(max_examples=30, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16), dtype=st.sampled_from(["float32", "float64"]))
def test_x_beta_matches_reference(shape, seed, dtype):
    n, p = shape
    rng = np.random.default_rng(seed)
    x = rand(rng, (n, p), dtype)
    b = rand(rng, (p,), dtype)
    got = matvec.x_beta(x, b)
    want = ref.x_beta_ref(x, b)
    tol = 1e-5 if dtype == "float32" else 1e-12
    assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,p", [(128, 128), (128, 256), (1, 1), (129, 127), (5, 128)])
def test_matvec_tile_boundaries(n, p):
    rng = np.random.default_rng(7)
    x = rand(rng, (n, p), "float64")
    r = rand(rng, (n,), "float64")
    b = rand(rng, (p,), "float64")
    assert_allclose(np.asarray(matvec.xt_r(x, r)), np.asarray(ref.xt_r_ref(x, r)), rtol=1e-12)
    assert_allclose(
        np.asarray(matvec.x_beta(x, b)), np.asarray(ref.x_beta_ref(x, b)), rtol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 20),
    gmax=st.integers(1, 40),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.0, 3.0),
)
def test_sgl_prox_matches_reference(m, gmax, seed, scale):
    rng = np.random.default_rng(seed)
    z = rand(rng, (m, gmax), "float64")
    l1 = jnp.asarray(scale * np.abs(rng.standard_normal((m, gmax))))
    gthr = jnp.asarray(scale * np.abs(rng.standard_normal((m,))))
    got = prox.sgl_prox(z, l1, gthr)
    want = ref.sgl_prox_ref(z, l1, gthr)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_sgl_prox_kills_group_below_threshold():
    z = jnp.asarray([[0.1, -0.05, 0.0, 0.0]])
    l1 = jnp.zeros((1, 4))
    gthr = jnp.asarray([10.0])
    out = prox.sgl_prox(z, l1, gthr)
    assert np.all(np.asarray(out) == 0.0)


def test_sgl_prox_zero_threshold_is_identity():
    rng = np.random.default_rng(3)
    z = rand(rng, (4, 6), "float64")
    out = prox.sgl_prox(z, jnp.zeros((4, 6)), jnp.zeros((4,)))
    assert_allclose(np.asarray(out), np.asarray(z), rtol=1e-12)


def test_prox_is_nonexpansive():
    rng = np.random.default_rng(11)
    z1 = rand(rng, (6, 9), "float64")
    z2 = rand(rng, (6, 9), "float64")
    l1 = jnp.full((6, 9), 0.3)
    gthr = jnp.full((6,), 0.5)
    p1 = np.asarray(prox.sgl_prox(z1, l1, gthr))
    p2 = np.asarray(prox.sgl_prox(z2, l1, gthr))
    assert np.linalg.norm(p1 - p2) <= np.linalg.norm(np.asarray(z1 - z2)) + 1e-12
