"""L2 fista_chunk correctness: the AOT inner solver must (a) decrease the
SGL objective, (b) converge to a point satisfying the SGL KKT conditions,
and (c) be padding-invariant (pad columns stay exactly zero)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model


def make_problem(seed, n, p, m):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p))
    x -= x.mean(axis=0)
    x /= np.linalg.norm(x, axis=0)
    beta_true = np.zeros(p)
    beta_true[:: max(p // 5, 1)] = rng.standard_normal(len(beta_true[:: max(p // 5, 1)]))
    y = x @ beta_true + 0.05 * rng.standard_normal(n)
    y -= y.mean()
    gid = np.arange(p) % m
    onehot = np.zeros((m, p))
    onehot[gid, np.arange(p)] = 1.0
    sizes = onehot.sum(axis=1)
    return x, y, onehot, sizes


def sgl_objective(x, y, beta, lam, alpha, onehot, sizes):
    n = x.shape[0]
    resid = y - x @ beta
    f = 0.5 * np.sum(resid**2) / n
    gnorms = np.sqrt(onehot @ (beta**2))
    return f + lam * alpha * np.abs(beta).sum() + lam * (1 - alpha) * (
        np.sqrt(sizes) * gnorms
    ).sum()


def run_chunks(x, y, onehot, sizes, lam, alpha, chunks=40, iters=50):
    n, p = x.shape
    m = onehot.shape[0]
    lip = np.linalg.norm(x, 2) ** 2 / n
    step = 1.0 / (1.05 * lip)
    l1 = np.full(p, lam * alpha)
    gthr = lam * (1 - alpha) * np.sqrt(sizes)
    beta = jnp.zeros(p)
    z = jnp.zeros(p)
    t = jnp.asarray(1.0)
    for _ in range(chunks):
        beta, z, t, delta = model.fista_chunk(
            jnp.asarray(x), jnp.asarray(y), beta, z, t, jnp.asarray(step),
            jnp.asarray(l1), jnp.asarray(onehot), jnp.asarray(gthr), n_iters=iters,
        )
        if float(delta) < 1e-12:
            break
    return np.asarray(beta)


def test_objective_decreases_and_kkt_holds():
    x, y, onehot, sizes = make_problem(0, 40, 24, 6)
    lam, alpha = 0.05, 0.9
    beta = run_chunks(x, y, onehot, sizes, lam, alpha)
    obj0 = sgl_objective(x, y, np.zeros(24), lam, alpha, onehot, sizes)
    obj = sgl_objective(x, y, beta, lam, alpha, onehot, sizes)
    assert obj < obj0
    # KKT: inactive variables in inactive groups satisfy the soft-threshold
    # bound; active variables satisfy stationarity.
    n = x.shape[0]
    grad = x.T @ (x @ beta - y) / n
    gid = np.argmax(onehot, axis=0)
    gnorms = np.sqrt(onehot @ (beta**2))
    for i in range(24):
        g = gid[i]
        if gnorms[g] == 0.0:
            s = np.sign(grad[i]) * max(
                abs(grad[i]) - lam * (1 - alpha) * np.sqrt(sizes[g]), 0.0
            )
            assert abs(s) <= lam * alpha + 1e-6, f"KKT violated at {i}"
        elif beta[i] != 0.0:
            sub = (
                grad[i]
                + lam * alpha * np.sign(beta[i])
                + lam * (1 - alpha) * np.sqrt(sizes[g]) * beta[i] / gnorms[g]
            )
            assert abs(sub) < 1e-5, f"stationarity violated at {i}: {sub}"


def test_padding_invariance():
    x, y, onehot, sizes = make_problem(1, 30, 16, 4)
    lam, alpha = 0.08, 0.95
    beta_ref = run_chunks(x, y, onehot, sizes, lam, alpha)
    # Pad to p_b = 32, m_b = 32 with zero columns / zero one-hot rows.
    pb = 32
    x_pad = np.zeros((30, pb))
    x_pad[:, :16] = x
    oh_pad = np.zeros((pb, pb))
    oh_pad[:4, :16] = onehot
    l1 = np.full(pb, lam * alpha)
    gthr = np.zeros(pb)
    gthr[:4] = lam * (1 - alpha) * np.sqrt(sizes)
    n = 30
    lip = np.linalg.norm(x, 2) ** 2 / n
    step = 1.0 / (1.05 * lip)
    beta = jnp.zeros(pb)
    z = jnp.zeros(pb)
    t = jnp.asarray(1.0)
    for _ in range(40):
        beta, z, t, delta = model.fista_chunk(
            jnp.asarray(x_pad), jnp.asarray(y), beta, z, t, jnp.asarray(step),
            jnp.asarray(l1), jnp.asarray(oh_pad), jnp.asarray(gthr),
        )
        if float(delta) < 1e-12:
            break
    beta = np.asarray(beta)
    assert np.all(beta[16:] == 0.0), "pad columns moved off zero"
    assert_allclose(beta[:16], beta_ref, atol=1e-8)


def test_fista_artifact_lowering_shapes():
    text = aot.lower_fista_chunk(8, 16, n_iters=3)
    assert "HloModule" in text
    assert "f64[8,16]" in text
    assert "f64[16,16]" in text  # one-hot
