"""L2 model correctness: gradient graphs (Pallas inside) vs oracles and vs
jax.grad, plus shape/dtype contracts the Rust runtime relies on."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def problem(seed, n, p, logistic=False):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)))
    beta = jnp.asarray(rng.standard_normal((p,)) * 0.3)
    if logistic:
        y = jnp.asarray((rng.random(n) > 0.5).astype(np.float64))
    else:
        y = jnp.asarray(rng.standard_normal((n,)))
    return x, beta, y


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 60), p=st.integers(1, 200), seed=st.integers(0, 2**16))
def test_grad_squared_matches_oracle(n, p, seed):
    x, beta, y = problem(seed, n, p)
    (got,) = model.grad_squared(x, beta, y)
    want = ref.grad_squared_ref(x, beta, y)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 60), p=st.integers(1, 200), seed=st.integers(0, 2**16))
def test_grad_logistic_matches_oracle(n, p, seed):
    x, beta, y = problem(seed, n, p, logistic=True)
    (got,) = model.grad_logistic(x, beta, y)
    want = ref.grad_logistic_ref(x, beta, y)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-12)


def test_grad_squared_matches_autodiff():
    x, beta, y = problem(1, 25, 40)
    (got,) = model.grad_squared(x, beta, y)
    loss = lambda b: 0.5 * jnp.mean((y - x @ b) ** 2)
    want = jax.grad(loss)(beta)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_grad_logistic_matches_autodiff():
    x, beta, y = problem(2, 30, 20, logistic=True)
    (got,) = model.grad_logistic(x, beta, y)

    def loss(b):
        eta = x @ b
        return jnp.mean(jnp.logaddexp(0.0, eta) - y * eta)

    want = jax.grad(loss)(beta)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_pallas_and_plain_paths_agree():
    x, beta, y = problem(3, 33, 77)
    (a,) = model.grad_squared(x, beta, y, use_pallas=True)
    (b,) = model.grad_squared(x, beta, y, use_pallas=False)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def test_outputs_are_f64_tuples():
    x, beta, y = problem(4, 8, 12)
    out = model.grad_squared(x, beta, y)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].dtype == jnp.float64
    assert out[0].shape == (12,)
