"""AOT contract tests: lowering produces parseable HLO text with the
shapes/parameter order the Rust runtime expects, and the lowered module
actually computes the gradient (executed via jax on the same backend
family, CPU)."""

import re

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def test_lower_gradient_emits_hlo_text():
    text = aot.lower_gradient("sq", 8, 16)
    assert "HloModule" in text
    # Entry computation mentions the three parameters with f64 shapes.
    assert "f64[8,16]" in text
    assert "f64[16]" in text
    assert "f64[8]" in text


def test_lowered_module_shapes_for_logistic():
    text = aot.lower_gradient("log", 5, 7)
    assert "HloModule" in text
    assert "f64[5,7]" in text


def test_root_is_tuple():
    text = aot.lower_gradient("sq", 4, 6)
    # ROOT of the entry computation is a tuple of one f64[p].
    m = re.search(r"ROOT .* tuple\(", text) or re.search(r"\(f64\[6\]\)", text)
    assert m, f"no tuple root found in HLO:\n{text[:400]}"


def test_default_shapes_cover_smoke_and_table_a1():
    assert (32, 64) in aot.DEFAULT_SHAPES
    assert (200, 1000) in aot.DEFAULT_SHAPES


def test_lowering_roundtrip_numerics():
    """jit-compiled (same lowering pipeline) output equals the oracle —
    guards against the aot entry point drifting from model.py."""
    rng = np.random.default_rng(0)
    n, p = 12, 20
    x = jnp.asarray(rng.standard_normal((n, p)))
    beta = jnp.asarray(rng.standard_normal((p,)))
    y = jnp.asarray(rng.standard_normal((n,)))
    jitted = jax.jit(lambda X, b, Y: model.grad_squared(X, b, Y, use_pallas=True))
    (got,) = jitted(x, beta, y)
    assert_allclose(np.asarray(got), np.asarray(ref.grad_squared_ref(x, beta, y)), rtol=1e-10)
