//! END-TO-END DRIVER: the full three-layer system on the paper's default
//! workload (Table A1: p=1000, n=200, m≈22 uneven groups, ρ=0.3,
//! 50-point path to 0.1·λ₁).
//!
//! Layers exercised:
//!   L1  dispatched SIMD kernels —  dot/axpy/gather behind `DFR_KERNEL`
//!   L2  design kernels          —  dense, centered-sparse, and the
//!                                  out-of-core streaming store (`dfr pack`)
//!   L3  Rust coordinator        —  DFR screening, KKT loop, warm-started
//!                                  pathwise FISTA, persistent serving
//!
//! Reports the paper's headline metrics (improvement factor, input
//! proportion, ℓ₂ distance to no-screen, KKT violations) for every rule,
//! and verifies the out-of-core fit matches the in-memory fit. Results
//! are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_full_stack
//! ```

use dfr::path::compare_with_no_screen;
use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    // Table A1 defaults.
    let data = SyntheticConfig::default().generate(2025);
    let ds = &data.dataset;
    println!(
        "workload: {} (m={} groups, sizes {:?}..)",
        ds.name,
        ds.m(),
        &ds.groups.sizes()[..4.min(ds.m())]
    );

    // Tight solver tolerance so the ℓ₂-distance check isolates screening
    // correctness from optimizer noise.
    let cfg = PathConfig {
        path_len: 50,
        path_end_ratio: 0.1,
        alpha: 0.95,
        solver: dfr::solver::SolverConfig { tol: 1e-7, max_iters: 20_000, ..Default::default() },
        ..PathConfig::default()
    };

    // --- Stage 1: three-layer wiring check -------------------------------
    // The same DFR fit streamed out-of-core from a pack file, verified
    // against the all-in-memory fit. The design matrix never sits in RAM:
    // only `DFR_OOC_BLOCK`-sized column blocks are resident.
    println!("\n[stage 1] out-of-core DFR fit vs in-memory DFR fit");
    let native = PathRunner::new(ds, cfg.clone()).rule(RuleKind::DfrSgl).run()?;
    {
        let pack = std::env::temp_dir().join(format!("dfr-e2e-{}.dfrpack", std::process::id()));
        // Pack the raw (pre-standardization) design: a same-seed twin
        // with `standardize: false` regenerates exactly the matrix the
        // in-memory pipeline standardized, so the pack-time stats match
        // the ingest-time stats bit for bit.
        let raw =
            SyntheticConfig { standardize: false, ..SyntheticConfig::default() }.generate(2025);
        let ooc = dfr::linalg::ooc::pack_matrix(raw.dataset.x.dense(), &pack)?;
        dfr::linalg::ooc_reset_peak();
        let mut ooc_ds = ds.clone();
        ooc_ds.x = DesignOps::Ooc(ooc.clone());
        let ooc_fit = PathRunner::new(&ooc_ds, cfg.clone())
            .rule(RuleKind::DfrSgl)
            .fixed_path(native.lambdas.clone())
            .run()?;
        let dist = ooc_fit.l2_distance_to(&native);
        println!(
            "  ℓ₂(in-memory, ooc) = {:.2e} | block {} cols | peak resident {} KiB vs \
             dense design {} KiB | in-memory {:.2}s vs ooc {:.2}s",
            dist,
            ooc.block_cols(),
            dfr::linalg::ooc_peak_resident_bytes() >> 10,
            (ds.n() * ds.p() * 8) >> 10,
            native.metrics.total_seconds,
            ooc_fit.metrics.total_seconds,
        );
        assert!(dist < 1e-8, "out-of-core and in-memory fits disagree");
        let _ = std::fs::remove_file(&pack);
    }

    // --- Stage 2: the paper's headline table ------------------------------
    println!("\n[stage 2] screened vs no-screen, all rules (paper §3 metrics)");
    println!(
        "{:<13} {:>8} {:>12} {:>12} {:>10} {:>6} {:>7}",
        "method", "IF", "screen(s)", "no-scr(s)", "input-prop", "KKT", "ℓ₂"
    );
    let rules = [
        (RuleKind::DfrAsgl, Some((0.1, 0.1))),
        (RuleKind::DfrSgl, None),
        (RuleKind::Sparsegl, None),
        (RuleKind::GapSafeSeq, None),
        (RuleKind::GapSafeDyn, None),
    ];
    for (rule, adaptive) in rules {
        let mut c = cfg.clone();
        c.adaptive = adaptive;
        let cmp = compare_with_no_screen(ds, &c, rule)?;
        println!(
            "{:<13} {:>7.2}× {:>12.3} {:>12.3} {:>10.4} {:>6} {:>7.0e}",
            rule.name(),
            cmp.improvement_factor,
            cmp.screened.metrics.total_seconds,
            cmp.no_screen.metrics.total_seconds,
            cmp.screened.metrics.input_proportion(),
            cmp.screened.metrics.total_kkt_violations(),
            cmp.l2_distance,
        );
        assert!(
            cmp.l2_distance < 1e-3,
            "{} lost the optimal solution (ℓ₂ {})",
            rule.name(),
            cmp.l2_distance
        );
    }
    println!(
        "\nexpected shape (paper Fig. 1/3, Tables A2–A4): DFR > sparsegl > GAP-safe ≈ 1; \
         DFR input proportion ≈ 0.02–0.15; zero-to-rare KKT violations."
    );

    // --- Stage 3: the serving layer ---------------------------------------
    // A persistent SglFitter handling repeated requests on one design:
    // request 1 pays ingest + solve, every later request is served from
    // the prepared-dataset and path caches.
    println!("\n[stage 3] persistent serving API (SglFitter)");
    let model = SglModel {
        path: PathConfig { path_len: 20, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        ..SglModel::default()
    };
    let mut fitter = model.fitter();
    let sizes = ds.groups.sizes();
    let design = Design::Matrix(ds.x.dense());
    let t0 = std::time::Instant::now();
    let first = fitter.fit_at(&design, &ds.y, &sizes, ds.response, 19)?;
    let cold = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    for idx in [19usize, 10, 5, 19] {
        let _ = fitter.fit_at(&design, &ds.y, &sizes, ds.response, idx)?;
    }
    let warm = t1.elapsed().as_secs_f64() / 4.0;
    let mut preds = vec![0.0; ds.n()];
    first.predict_into(&design, &mut preds);
    println!(
        "  cold request {:.4}s vs warm request {:.2e}s ({} prepared-cache hits, \
         {} path-cache hits, {} solve(s), pool slots {})",
        cold,
        warm,
        fitter.prepared_hits(),
        fitter.path_hits(),
        fitter.pool_checkouts(),
        fitter.pool_slots(),
    );
    assert_eq!(fitter.pool_checkouts(), 1, "warm requests must not re-solve");
    assert!(
        first.selected_with_tol(1e-8).len() <= first.selected().len(),
        "tolerance-aware support cannot exceed the exact-zero support"
    );
    Ok(())
}
