//! Genetics workload: pathway-grouped gene expression with a binary disease
//! outcome — the setting that motivates the paper's introduction.
//!
//! Uses the `celiac` surrogate (p ≈ 14.7k genes in 276 pathways at full
//! scale; scaled here for demo runtime), fits adaptive SGL with DFR-aSGL
//! screening under a logistic model, cross-validates over (α, γ) — the
//! "expanded tuning regimes" DFR's savings unlock (§1.2, Appendix D.7) —
//! and finishes with the sparse-genotype serving path: a CSC
//! minor-allele-count design fed zero-densification into the fitter.
//!
//! ```bash
//! cargo run --release --example genetics_pathways [-- --scale 0.3]
//! ```

use dfr::bench_harness::BenchArgs;
use dfr::cv::{grid_search, CvConfig};
use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let scale = args.f64_or("--scale", 0.2);
    let ds = SurrogateConfig::scaled(RealDatasetKind::Celiac, scale).generate();
    println!(
        "celiac surrogate at scale {scale}: p={}, n={}, m={} pathways (logistic)",
        ds.p(),
        ds.n(),
        ds.m()
    );

    // 1. One DFR-aSGL path fit with screening diagnostics.
    let cfg = PathConfig {
        path_len: 25,
        path_end_ratio: 0.2, // real-data setting (Table A1)
        adaptive: Some((0.1, 0.1)),
        ..PathConfig::default()
    };
    let fit = PathRunner::new(&ds, cfg.clone()).rule(RuleKind::DfrAsgl).run()?;
    println!(
        "DFR-aSGL path: input proportion {:.4}, {} KKT violations, {} active genes at λ_l",
        fit.metrics.input_proportion(),
        fit.metrics.total_kkt_violations(),
        fit.active_vars_last()
    );

    // 2. Which pathways does the model put mass on?
    let last = fit.betas.last().unwrap();
    let mut pathway_mass: Vec<(usize, f64)> = ds
        .groups
        .iter()
        .map(|(g, r)| (g, last[r].iter().map(|b| b.abs()).sum::<f64>()))
        .filter(|(_, m)| *m > 0.0)
        .collect();
    pathway_mass.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top selected pathways (id, |β|₁):");
    for (g, mass) in pathway_mass.iter().take(5) {
        println!("  pathway {:>4}  {:.4}  ({} genes)", g, mass, ds.groups.size(*g));
    }

    // 3. Joint (α, γ) tuning by 5-fold CV — feasible because of
    //    screening. Demonstrated on the trust-experts surrogate (n ≫ p, so
    //    held-out loss actually discriminates between grid cells; the
    //    p ≫ n celiac surrogate above would just select the null model, as
    //    regularized fits at n = 33 should).
    let cv_ds = SurrogateConfig::scaled(RealDatasetKind::TrustExperts, 0.3).generate();
    println!(
        "\nCV demo on trust-experts surrogate: p={}, n={}, m={} (linear)",
        cv_ds.p(),
        cv_ds.n(),
        cv_ds.m()
    );
    let cv = CvConfig {
        folds: 5,
        path: PathConfig { path_len: 15, path_end_ratio: 0.1, ..PathConfig::default() },
        rule: RuleKind::DfrAsgl,
        ..CvConfig::default()
    };
    let alphas = [0.9, 0.95];
    let gammas = [Some((0.1, 0.1)), Some((0.5, 0.5))];
    let (cells, best) = grid_search(&cv_ds, &cv, &alphas, &gammas)?;
    println!("CV grid (α × γ): held-out loss at each cell's best λ");
    for (i, cell) in cells.iter().enumerate() {
        let marker = if i == best { " <-- selected" } else { "" };
        println!(
            "  α={:.2} γ={:?}: loss {:.4} at λ={:.5} (index {}, {:.1}s){marker}",
            cell.alpha,
            cell.gamma.map(|g| g.0),
            cell.cv_loss[cell.best_idx],
            cell.lambdas[cell.best_idx],
            cell.best_idx,
            cell.seconds
        );
    }

    // 4. The sparse-genotype serving path: minor-allele counts in {0, 1, 2}
    //    with low MAF are mostly zeros, so the design ships as CSC and —
    //    because its density sits below the DFR_SPARSE_DENSITY threshold
    //    (default 0.25) — the whole solve runs on the centered-implicit
    //    sparse kernels: no n×p dense standardized matrix is ever built.
    let (n, p, group_size) = (160usize, 480usize, 24usize);
    let mut rng = Rng::new(33);
    let mut col_ptr = vec![0usize];
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..p {
        let maf = 0.02 + 0.10 * rng.uniform(); // per-SNP minor-allele frequency
        for i in 0..n {
            let dosage = (rng.bernoulli(maf) as u8 + rng.bernoulli(maf) as u8) as f64;
            if dosage > 0.0 {
                row_idx.push(i);
                values.push(dosage);
            }
        }
        col_ptr.push(row_idx.len());
    }
    let geno = CscMatrix::new(n, p, col_ptr, row_idx, values);
    // Disease status driven by a handful of causal SNPs in the first gene.
    let y: Vec<f64> = {
        let dense = geno.to_dense();
        (0..n)
            .map(|i| {
                let eta = 1.4 * dense.get(i, 0) + 1.2 * dense.get(i, 1)
                    - 1.3 * dense.get(i, 2)
                    + 0.4 * rng.gauss();
                if eta > 0.35 { 1.0 } else { 0.0 }
            })
            .collect()
    };
    let sizes = vec![group_size; p / group_size];
    println!(
        "\nsparse genotype serving: n={n}, p={p} SNPs in {} genes, density {:.3} \
         (threshold {})",
        sizes.len(),
        geno.density(),
        dfr::model_api::sparse_density_threshold(),
    );
    let model = SglModel {
        path: PathConfig { path_len: 15, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        sparse: SparseMode::Auto, // density-gated centered-implicit kernels
        ..SglModel::default()
    };
    let mut fitter = model.fitter();
    let densified_before = dfr::linalg::dense_materializations();
    let fitted =
        fitter.fit_at(&Design::Csc(&geno), &y, &sizes, Response::Logistic, 14)?;
    println!(
        "  DFR-SGL on CSC input: {} SNPs selected (|β| > 1e-8), input proportion {:.4}",
        fitted.selected_with_tol(1e-8).len(),
        fitted.path_fit.metrics.input_proportion()
    );
    println!(
        "  solve kernel: {} (dense materializations during fit: {})",
        fitter.kernel_variant().unwrap_or("dense"),
        dfr::linalg::dense_materializations() - densified_before,
    );
    // One-matvec batch predictions straight off the sparse design.
    let mut risk = vec![0.0; n];
    fitted.predict_into(&Design::Csc(&geno), &mut risk);
    let acc = risk
        .iter()
        .zip(&y)
        .filter(|(r, &yy)| (**r > 0.5) == (yy == 1.0))
        .count() as f64
        / n as f64;
    println!("  in-sample accuracy from sparse batch predictions: {acc:.3}");
    Ok(())
}
