//! Genetics workload: pathway-grouped gene expression with a binary disease
//! outcome — the setting that motivates the paper's introduction.
//!
//! Uses the `celiac` surrogate (p ≈ 14.7k genes in 276 pathways at full
//! scale; scaled here for demo runtime), fits adaptive SGL with DFR-aSGL
//! screening under a logistic model, and cross-validates over (α, γ) — the
//! "expanded tuning regimes" DFR's savings unlock (§1.2, Appendix D.7).
//!
//! ```bash
//! cargo run --release --example genetics_pathways [-- --scale 0.3]
//! ```

use dfr::bench_harness::BenchArgs;
use dfr::cv::{grid_search, CvConfig};
use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let scale = args.f64_or("--scale", 0.2);
    let ds = SurrogateConfig::scaled(RealDatasetKind::Celiac, scale).generate();
    println!(
        "celiac surrogate at scale {scale}: p={}, n={}, m={} pathways (logistic)",
        ds.p(),
        ds.n(),
        ds.m()
    );

    // 1. One DFR-aSGL path fit with screening diagnostics.
    let cfg = PathConfig {
        path_len: 25,
        path_end_ratio: 0.2, // real-data setting (Table A1)
        adaptive: Some((0.1, 0.1)),
        ..PathConfig::default()
    };
    let fit = PathRunner::new(&ds, cfg.clone()).rule(RuleKind::DfrAsgl).run()?;
    println!(
        "DFR-aSGL path: input proportion {:.4}, {} KKT violations, {} active genes at λ_l",
        fit.metrics.input_proportion(),
        fit.metrics.total_kkt_violations(),
        fit.active_vars_last()
    );

    // 2. Which pathways does the model put mass on?
    let last = fit.betas.last().unwrap();
    let mut pathway_mass: Vec<(usize, f64)> = ds
        .groups
        .iter()
        .map(|(g, r)| (g, last[r].iter().map(|b| b.abs()).sum::<f64>()))
        .filter(|(_, m)| *m > 0.0)
        .collect();
    pathway_mass.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top selected pathways (id, |β|₁):");
    for (g, mass) in pathway_mass.iter().take(5) {
        println!("  pathway {:>4}  {:.4}  ({} genes)", g, mass, ds.groups.size(*g));
    }

    // 3. Joint (α, γ) tuning by 5-fold CV — feasible because of
    //    screening. Demonstrated on the trust-experts surrogate (n ≫ p, so
    //    held-out loss actually discriminates between grid cells; the
    //    p ≫ n celiac surrogate above would just select the null model, as
    //    regularized fits at n = 33 should).
    let cv_ds = SurrogateConfig::scaled(RealDatasetKind::TrustExperts, 0.3).generate();
    println!(
        "\nCV demo on trust-experts surrogate: p={}, n={}, m={} (linear)",
        cv_ds.p(),
        cv_ds.n(),
        cv_ds.m()
    );
    let cv = CvConfig {
        folds: 5,
        path: PathConfig { path_len: 15, path_end_ratio: 0.1, ..PathConfig::default() },
        rule: RuleKind::DfrAsgl,
        ..CvConfig::default()
    };
    let alphas = [0.9, 0.95];
    let gammas = [Some((0.1, 0.1)), Some((0.5, 0.5))];
    let (cells, best) = grid_search(&cv_ds, &cv, &alphas, &gammas)?;
    println!("CV grid (α × γ): held-out loss at each cell's best λ");
    for (i, cell) in cells.iter().enumerate() {
        let marker = if i == best { " <-- selected" } else { "" };
        println!(
            "  α={:.2} γ={:?}: loss {:.4} at λ={:.5} (index {}, {:.1}s){marker}",
            cell.alpha,
            cell.gamma.map(|g| g.0),
            cell.cv_loss[cell.best_idx],
            cell.lambdas[cell.best_idx],
            cell.best_idx,
            cell.seconds
        );
    }
    Ok(())
}
