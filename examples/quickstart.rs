//! Quickstart: fit a sparse-group lasso path with DFR screening on a small
//! synthetic problem through the serving API ([`dfr::model_api::SglFitter`]),
//! inspect what the screening did, and batch-predict with one matvec.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A Table-A1-style synthetic problem, scaled down for a fast demo.
    let gen = SyntheticConfig {
        n: 120,
        p: 400,
        group_sparsity: 0.2,
        var_sparsity: 0.2,
        rho: 0.3,
        ..SyntheticConfig::default()
    };
    let data = gen.generate(42);
    let ds = &data.dataset;
    println!(
        "dataset: p={}, n={}, m={} groups; {} truly active variables",
        ds.p(),
        ds.n(),
        ds.m(),
        data.active_vars.len()
    );

    // 2. Build a persistent fitter and fit a 30-point DFR-SGL path. The
    //    design goes in as a borrowed `Design` — no copy on repeat fits.
    let model = SglModel {
        path: PathConfig { path_len: 30, alpha: 0.95, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        ..SglModel::default()
    };
    let mut fitter = model.fitter();
    let sizes = ds.groups.sizes();
    let design = Design::Matrix(ds.x.dense());
    // Report inside the borrow's scope so nothing needs cloning.
    let path_points = {
        let fit = fitter.fit_path(&design, &ds.y, &sizes, ds.response)?;
        println!("\n  λ-index   λ        |C_v|  |O_v|  |A_v|  KKT  iters");
        for (i, pt) in fit.metrics.points.iter().enumerate().step_by(3) {
            println!(
                "  {:>7}   {:<8.4} {:>5}  {:>5}  {:>5}  {:>3}  {:>5}",
                i, pt.lambda, pt.c_v, pt.o_v, pt.a_v, pt.kkt_violations, pt.solver_iterations
            );
        }
        println!(
            "\ninput proportion (mean |O_v|/p): {:.4}  — the solver only ever saw \
             {:.1}% of the design",
            fit.metrics.input_proportion(),
            100.0 * fit.metrics.input_proportion()
        );
        fit.lambdas.len()
    };

    // 3. Select the densest path point — a pure cache hit on the fitter
    //    (no solve, no data pass) — and batch-predict with one matvec.
    let fitted = fitter.refit(path_points - 1)?;
    let mut preds = vec![0.0; ds.n()];
    fitted.predict_into(&design, &mut preds);
    println!(
        "selected {} variables at λ_l (|β| > 1e-8: {}); {} path solves total",
        fitted.selected().len(),
        fitted.selected_with_tol(1e-8).len(),
        fitter.pool_checkouts(),
    );

    // 4. Verify against a no-screen fit: same solutions, less work.
    let cfg = PathConfig { path_len: 30, alpha: 0.95, ..PathConfig::default() };
    let cmp = dfr::path::compare_with_no_screen(ds, &cfg, RuleKind::DfrSgl)?;
    println!(
        "improvement factor vs no screening: {:.2}×  (ℓ₂ distance between solutions: {:.2e})",
        cmp.improvement_factor, cmp.l2_distance
    );

    // 5. Support recovery sanity: how much of the truth did the model find
    //    at the densest path point? (Tolerance-aware support, so stray
    //    near-zero FISTA iterates don't inflate the count.)
    let found = fitted
        .selected_with_tol(1e-8)
        .iter()
        .filter(|i| data.active_vars.contains(i))
        .count();
    println!(
        "support recovery at λ_l: {}/{} true actives selected",
        found,
        data.active_vars.len()
    );
    Ok(())
}
