//! Quickstart: fit a sparse-group lasso path with DFR screening on a small
//! synthetic problem and inspect what the screening did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A Table-A1-style synthetic problem, scaled down for a fast demo.
    let gen = SyntheticConfig {
        n: 120,
        p: 400,
        group_sparsity: 0.2,
        var_sparsity: 0.2,
        rho: 0.3,
        ..SyntheticConfig::default()
    };
    let data = gen.generate(42);
    println!(
        "dataset: p={}, n={}, m={} groups; {} truly active variables",
        data.dataset.p(),
        data.dataset.n(),
        data.dataset.m(),
        data.active_vars.len()
    );

    // 2. Fit a 30-point path with DFR-SGL screening.
    let cfg = PathConfig { path_len: 30, alpha: 0.95, ..PathConfig::default() };
    let fit = PathRunner::new(&data.dataset, cfg.clone()).rule(RuleKind::DfrSgl).run()?;

    println!("\n  λ-index   λ        |C_v|  |O_v|  |A_v|  KKT  iters");
    for (i, pt) in fit.metrics.points.iter().enumerate().step_by(3) {
        println!(
            "  {:>7}   {:<8.4} {:>5}  {:>5}  {:>5}  {:>3}  {:>5}",
            i, pt.lambda, pt.c_v, pt.o_v, pt.a_v, pt.kkt_violations, pt.solver_iterations
        );
    }
    println!(
        "\ninput proportion (mean |O_v|/p): {:.4}  — the solver only ever saw \
         {:.1}% of the design",
        fit.metrics.input_proportion(),
        100.0 * fit.metrics.input_proportion()
    );

    // 3. Verify against a no-screen fit: same solutions, less work.
    let cmp = dfr::path::compare_with_no_screen(&data.dataset, &cfg, RuleKind::DfrSgl)?;
    println!(
        "improvement factor vs no screening: {:.2}×  (ℓ₂ distance between solutions: {:.2e})",
        cmp.improvement_factor, cmp.l2_distance
    );

    // 4. Support recovery sanity: how much of the truth did the model find
    //    at the densest path point?
    let found = fit
        .betas
        .last()
        .unwrap()
        .iter()
        .enumerate()
        .filter(|(_, &b)| b != 0.0)
        .filter(|(i, _)| data.active_vars.contains(i))
        .count();
    println!(
        "support recovery at λ_l: {}/{} true actives selected",
        found,
        data.active_vars.len()
    );
    Ok(())
}
