//! Interaction detection (Table 1 of the paper): expand a grouped design
//! with all within-group order-2/order-3 interactions — the
//! dimensionality explosion where bi-level screening pays off most — and
//! compare DFR against the group-only sparsegl rule.
//!
//! ```bash
//! cargo run --release --example interaction_detection [-- --order 3]
//! ```

use dfr::bench_harness::BenchArgs;
use dfr::data::interactions::{expand_generated, expanded_p};
use dfr::data::synthetic::GroupSpec;
use dfr::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let order = match args.usize_or("--order", 2) {
        3 => InteractionOrder::Order3,
        _ => InteractionOrder::Order2,
    };

    // Paper's interaction design: p=400, n=80, m=52 groups of sizes [3,15]
    // (scaled slightly down by default so the demo finishes in seconds;
    // pass --full for the paper shape).
    let (p, n, lo, hi) = if args.has("--full") { (400, 80, 3, 15) } else { (200, 60, 3, 10) };
    let base = SyntheticConfig {
        n,
        p,
        groups: GroupSpec::Uneven { lo, hi },
        group_sparsity: 0.3,
        var_sparsity: 0.3,
        ..SyntheticConfig::default()
    }
    .generate(11);
    let sizes = base.dataset.groups.sizes();
    println!(
        "base design p={} in m={} groups; expanded p_O{} = {}",
        p,
        sizes.len(),
        if order == InteractionOrder::Order3 { 3 } else { 2 },
        expanded_p(&sizes, order)
    );

    // Expand with interactions carrying signal (active proportion 0.3).
    let expanded = expand_generated(&base, order, 0.3, 2.0, 99);
    println!("expanded dataset: p={}, n={}, m={}", expanded.p(), expanded.n(), expanded.m());

    let cfg = PathConfig { path_len: 20, ..PathConfig::default() };
    println!("\n{:<10} {:>12} {:>12} {:>10} {:>8}", "method", "IF", "input prop", "ℓ₂ dist", "KKT");
    for rule in [RuleKind::DfrAsgl, RuleKind::DfrSgl, RuleKind::Sparsegl] {
        let mut c = cfg.clone();
        if rule == RuleKind::DfrAsgl {
            c.adaptive = Some((0.1, 0.1));
        }
        let cmp = dfr::path::compare_with_no_screen(&expanded, &c, rule)?;
        println!(
            "{:<10} {:>11.2}× {:>12.4} {:>10.1e} {:>8}",
            rule.name(),
            cmp.improvement_factor,
            cmp.screened.metrics.input_proportion(),
            cmp.l2_distance,
            cmp.screened.metrics.total_kkt_violations()
        );
    }
    println!(
        "\nTable-1 shape check: DFR should beat sparsegl by an order of magnitude \
         here because sparsegl must pull in entire (now-huge) groups."
    );

    // Which interactions survive at the end of the path? Served through
    // the persistent fitter; the tolerance-aware support ignores stray
    // near-zero FISTA iterates that the exact-zero test would count.
    let model = SglModel {
        path: PathConfig { path_len: 20, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        ..SglModel::default()
    };
    let mut fitter = model.fitter();
    let sizes = expanded.groups.sizes();
    let fitted = fitter.fit_at(
        &Design::Matrix(expanded.x.dense()),
        &expanded.y,
        &sizes,
        expanded.response,
        19,
    )?;
    let exact = fitted.selected().len();
    let tol = fitted.selected_with_tol(1e-8).len();
    println!(
        "\nselected interactions at λ_l: {tol} (|β| > 1e-8; exact-zero test says {exact})"
    );
    Ok(())
}
