//! Minimal, dependency-free stand-in for the `anyhow` error crate.
//!
//! The build environment has no registry access, so this vendored shim
//! provides exactly the surface the `dfr` crate uses: [`Result`] with a
//! defaulted error type, a string-backed [`Error`] that converts from any
//! `std::error::Error` (enabling `?` on `io::Result` etc.), and the
//! `anyhow!` / `bail!` / `ensure!` macros. Messages are formatted eagerly;
//! no backtraces, no downcasting, no context chains — none of which the
//! crate relies on.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value.
///
/// Deliberately does **not** implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` conversion below cannot overlap with the
/// standard library's reflexive `From<T> for T` (the same trick the real
/// `anyhow` uses).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn io_bubbles() -> crate::Result<()> {
            std::fs::read("/definitely/not/a/path")?;
            Ok(())
        }
        assert!(io_bubbles().is_err());

        fn bails(x: i32) -> crate::Result<i32> {
            crate::ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                crate::bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(bails(5).unwrap(), 5);
        assert_eq!(bails(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(bails(200).unwrap_err().to_string(), "too big");

        let e = crate::anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
        assert_eq!(format!("{e:?}"), "code 7");
    }
}
