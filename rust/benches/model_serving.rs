//! **Model-serving bench**: the ROADMAP's "heavy traffic" scenario at the
//! API layer — repeated fit requests against one design, fresh
//! `SglModel::fit_at` per request (the pre-serving surface) vs a
//! persistent `SglFitter` at three reuse depths:
//!
//! * `fresh model`       — copy + standardize + solve, every request;
//! * `fitter (re-solve)` — prepared-dataset + workspace reuse, path cache
//!   cleared per request, so every request still solves;
//! * `fitter (warm)`     — full cache stack: requests only re-select a λ
//!   and unstandardize.
//!
//! Also prices batch prediction (`predict_into` one-matvec vs per-row
//! `predict_many`) and the sparse-CSC ingest — which, at this fixture's
//! ~10% density, now routes through the centered-implicit sparse solve
//! path under the default `SparseMode::Auto` (the dense-vs-sparse
//! comparison itself lives in the `sparse_path` bench). The speedup rows
//! land in
//! `target/bench_results/BENCH_model_serving.json` for the cross-PR
//! trajectory; the "path workspaces allocated" row must stay at 1.
#![allow(deprecated)] // the fresh-model baseline IS the deprecated shim

use dfr::bench_harness::{time_stat, BenchTable};
use dfr::linalg::CscMatrix;
use dfr::model_api::{Design, SglModel};
use dfr::path::PathConfig;
use dfr::rng::Rng;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (n, p, path_len) = if full { (200usize, 1000usize, 50usize) } else { (150, 400, 20) };
    let groups = 20usize;
    let setting = format!("{n}x{p}");
    let mut table = BenchTable::new("Model serving — repeated fits through the API layer");

    // Raw, unstandardized request payload (rows, as a client would send).
    let mut rng = Rng::new(4242);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..p).map(|j| 1.0 + (1.0 + j as f64 / 50.0) * rng.gauss()).collect())
        .collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| r.iter().step_by(7).sum::<f64>() + 0.5 * rng.gauss())
        .collect();
    let sizes = vec![p / groups; groups];
    let model = SglModel {
        path: PathConfig { path_len, ..PathConfig::default() },
        ..SglModel::default()
    };
    let sel = path_len - 1;
    let (warmup, reps) = (1, if full { 7 } else { 10 });

    // --- fresh model per request (the deprecated one-shot surface) ---
    let acc_fresh = time_stat(warmup, reps, || {
        let fit = model
            .fit_at(&rows, &y, &sizes, dfr::data::Response::Linear, sel)
            .expect("fresh fit failed");
        std::hint::black_box(fit.lambda);
    });
    table.push("fit_at seconds", &setting, "fresh model", acc_fresh.mean());

    // --- persistent fitter, path cache cleared (still solves) ---
    let mut fitter = model.fitter();
    let design = Design::rows(&rows);
    let acc_resolve = time_stat(warmup, reps, || {
        fitter.clear_path_cache();
        let fit = fitter
            .fit_at(&design, &y, &sizes, dfr::data::Response::Linear, sel)
            .expect("fitter re-solve failed");
        std::hint::black_box(fit.lambda);
    });
    table.push("fit_at seconds", &setting, "fitter (re-solve)", acc_resolve.mean());

    // --- persistent fitter, fully warm (cache-hit requests) ---
    let acc_warm = time_stat(warmup, reps, || {
        let fit = fitter
            .fit_at(&design, &y, &sizes, dfr::data::Response::Linear, sel)
            .expect("warm fit failed");
        std::hint::black_box(fit.lambda);
    });
    table.push("fit_at seconds", &setting, "fitter (warm)", acc_warm.mean());

    table.push(
        "serving speedup vs fresh model",
        &setting,
        "fitter (re-solve)",
        acc_fresh.median() / acc_resolve.median().max(1e-12),
    );
    table.push(
        "serving speedup vs fresh model",
        &setting,
        "fitter (warm)",
        acc_fresh.median() / acc_warm.median().max(1e-12),
    );
    // The no-new-allocation witness: one pooled path workspace, ever.
    table.push(
        "path workspaces allocated",
        &setting,
        "fitter (re-solve)",
        fitter.pool_slots() as f64,
    );
    assert_eq!(fitter.pool_slots(), 1, "serving pool grew past one workspace");
    assert_eq!(fitter.prepared_misses(), 1, "prepared-dataset cache was rebuilt");

    // --- batch prediction: one matvec vs per-row dots ---
    // The one-matvec branch needs a column-layout design (the Rows layout
    // falls back to row dots), so flatten the payload column-major once.
    let fitted = fitter
        .fit_at(&design, &y, &sizes, dfr::data::Response::Linear, sel)
        .expect("final fit failed");
    let mut cm = vec![0.0; n * p];
    for (i, r) in rows.iter().enumerate() {
        for (j, &v) in r.iter().enumerate() {
            cm[j * n + i] = v;
        }
    }
    let cm_design = Design::col_major(n, p, &cm);
    let mut out = vec![0.0; n];
    let acc_into = time_stat(2, 200, || {
        fitted.predict_into(&cm_design, &mut out);
        std::hint::black_box(out[0]);
    });
    table.push("batch predict seconds", &setting, "predict_into (one matvec)", acc_into.mean());
    let acc_many = time_stat(2, 200, || {
        std::hint::black_box(fitted.predict_many(&rows).len());
    });
    table.push("batch predict seconds", &setting, "predict_many (row dots)", acc_many.mean());
    table.push(
        "batch predict speedup",
        &setting,
        "predict_into (one matvec)",
        acc_many.median() / acc_into.median().max(1e-12),
    );

    // --- sparse-CSC ingest: dosage-style design served without copies ---
    let sparse_dense = dfr::linalg::Matrix::from_fn(n, p, |_, _| {
        if rng.bernoulli(0.1) { 1.0 + rng.uniform() } else { 0.0 }
    });
    let csc = CscMatrix::from_dense(&sparse_dense, 0.0);
    let y_sparse: Vec<f64> =
        (0..n).map(|i| sparse_dense.get(i, 0) - sparse_dense.get(i, 3) + rng.gauss()).collect();
    let mut csc_fitter = model.fitter();
    let acc_csc = time_stat(warmup, reps, || {
        csc_fitter.clear_path_cache();
        let fit = csc_fitter
            .fit_at(&Design::Csc(&csc), &y_sparse, &sizes, dfr::data::Response::Linear, sel)
            .expect("csc fit failed");
        std::hint::black_box(fit.lambda);
    });
    table.push("fit_at seconds", &setting, "fitter (csc re-solve)", acc_csc.mean());
    table.push("csc density", &setting, "fitter (csc re-solve)", csc.density());

    table.finish("model_serving");
}
