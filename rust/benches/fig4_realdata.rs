//! **Figure 4 + Figure 5 + Figures A12–A13 + Tables A38–A40**: the six
//! real-data studies — improvement factor, input proportion, and the
//! input-proportion-along-the-path series, on the Table A37 surrogate
//! datasets (see DESIGN.md §5 for the substitution).
//!
//! Paper shape: DFR beats sparsegl on every dataset; DFR-aSGL reaches
//! triple-digit factors on celiac/trust-experts; input proportion stays
//! low along the whole path for DFR while sparsegl's jumps whenever a big
//! pathway enters (Fig. 5).
//!
//! Default scale fits the bench budget; `DFR_BENCH_FULL=1` raises the
//! surrogate scale (full Table A37 sizes are hours of no-screen baseline —
//! exactly the paper's point).

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::real::{RealDatasetKind, SurrogateConfig};
use dfr::path::PathConfig;
use dfr::report;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let scale = if full { 0.25 } else { 0.04 };
    let path_len = if full { 100 } else { 15 };

    let mut table = BenchTable::new("Fig. 4 / A12 / Tables A38-A40 — six real-data surrogates");
    for kind in RealDatasetKind::ALL {
        for rep in 0..common::repeats().min(3) {
            let ds = SurrogateConfig { kind, scale, seed: 500 + rep as u64 }.generate();
            let cfg = PathConfig {
                path_len,
                path_end_ratio: 0.2, // real-data setting (Table A1)
                ..PathConfig::default()
            };
            common::run_cell(&mut table, kind.name(), &ds, &cfg, &common::STRONG_RULES);

            // Fig. 5 / A13 series: per-path-point input proportion CSV.
            if rep == 0 {
                for rule in common::STRONG_RULES {
                    let mut c = cfg.clone();
                    if rule == dfr::screen::RuleKind::DfrAsgl {
                        c.adaptive = Some((0.1, 0.1));
                    }
                    let fit = dfr::path::PathRunner::new(&ds, c).rule(rule).run().unwrap();
                    let csv = report::path_metrics_csv(&fit.metrics);
                    let path = format!(
                        "target/bench_results/fig5_path_{}_{}.csv",
                        kind.name(),
                        rule.name()
                    );
                    report::write_file(&path, &csv).ok();
                }
            }
        }
    }
    table.finish("fig4_realdata");
    println!("[series] per-path input-proportion CSVs under target/bench_results/fig5_path_*.csv");
}
