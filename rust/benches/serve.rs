//! **Serving bench**: the multi-tenant [`dfr::serve::FitterPool`] under
//! the traffic patterns `dfr serve` is built for:
//!
//! * `fit (cold)` — fresh pool per request: key + standardize + full
//!   pathwise solve, the first-request price every tenant pays once;
//! * `fit (warm)` — same pool, same content: prepared-dataset and path
//!   caches hit, requests only finalize a λ;
//! * `predict (sequential)` — K predict requests served one at a time,
//!   one matvec each;
//! * `predict (coalesced)` — the same K requests admitted as one batch
//!   and coalesced into a single stacked matvec.
//!
//! Rows land in `target/bench_results/BENCH_serve.json`; CI snapshots
//! that to the repo root via `scripts/bench_snapshot.sh serve` so the
//! cold-vs-warm and coalescing trajectories accumulate across PRs.

use dfr::bench_harness::{time_stat, BenchTable};
use dfr::model_api::SglModel;
use dfr::path::PathConfig;
use dfr::rng::Rng;
use dfr::serve::{FitRequest, FitterPool, PoolConfig, PredictRequest, Request};

fn fit_request(tenant: &str, x: &[Vec<f64>], y: &[f64], sizes: &[usize], sel: usize) -> FitRequest {
    FitRequest {
        id: None,
        tenant: tenant.to_string(),
        x: x.to_vec(),
        y: y.to_vec(),
        groups: sizes.to_vec(),
        response: dfr::data::Response::Linear,
        rule: None,
        alpha: None,
        path_len: None,
        lambda_idx: Some(sel),
    }
}

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (n, p, path_len) = if full { (200usize, 1000usize, 50usize) } else { (150, 400, 20) };
    let groups = 20usize;
    let setting = format!("{n}x{p}");
    let mut table = BenchTable::new("Multi-tenant serving — FitterPool cold/warm and coalescing");

    let mut rng = Rng::new(4242);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..p).map(|j| 1.0 + (1.0 + j as f64 / 50.0) * rng.gauss()).collect())
        .collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| r.iter().step_by(7).sum::<f64>() + 0.5 * rng.gauss())
        .collect();
    let sizes = vec![p / groups; groups];
    let model = SglModel {
        path: PathConfig { path_len, ..PathConfig::default() },
        ..SglModel::default()
    };
    let sel = path_len - 1;
    let (warmup, reps) = (1, if full { 7 } else { 10 });
    let pool_cfg = || PoolConfig { model: model.clone(), ..PoolConfig::default() };
    let req = fit_request("bench", &rows, &y, &sizes, sel);

    // --- cold fit: fresh pool, empty caches, full solve ---------------
    let acc_cold = time_stat(warmup, reps, || {
        let pool = FitterPool::new(pool_cfg());
        let out = pool.fit(&req).expect("cold fit failed");
        assert!(!out.path_cached, "cold fit somehow hit a cache");
        std::hint::black_box(out.lambda);
    });
    table.push("pool fit seconds", &setting, "fit (cold)", acc_cold.mean());

    // --- warm fit: shared pool, cache-hit requests --------------------
    let pool = FitterPool::new(pool_cfg());
    pool.fit(&req).expect("priming fit failed");
    let acc_warm = time_stat(warmup, reps, || {
        let out = pool.fit(&req).expect("warm fit failed");
        assert!(out.prepared_cached && out.path_cached, "warm fit missed");
        std::hint::black_box(out.lambda);
    });
    table.push("pool fit seconds", &setting, "fit (warm)", acc_warm.mean());
    table.push(
        "warm fit speedup vs cold",
        &setting,
        "fit (warm)",
        acc_cold.median() / acc_warm.median().max(1e-12),
    );

    // --- predict: K requests, sequential vs one coalesced batch -------
    let k = 16usize;
    let chunk = 8usize;
    let payloads: Vec<Vec<Vec<f64>>> =
        (0..k).map(|i| vec![rows[i % n].clone(); chunk]).collect();
    let acc_seq = time_stat(2, if full { 30 } else { 50 }, || {
        for c in &payloads {
            std::hint::black_box(pool.predict("bench", c).expect("predict failed").len());
        }
    });
    table.push("predict K=16 batch seconds", &setting, "predict (sequential)", acc_seq.mean());

    let acc_coal = time_stat(2, if full { 30 } else { 50 }, || {
        let batch: Vec<Request> = payloads
            .iter()
            .map(|c| {
                Request::Predict(PredictRequest {
                    id: None,
                    tenant: "bench".to_string(),
                    x: c.clone(),
                })
            })
            .collect();
        let replies = pool.submit_batch(batch);
        assert!(replies.iter().all(dfr::serve::Reply::is_ok), "coalesced predict failed");
        std::hint::black_box(replies.len());
    });
    table.push("predict K=16 batch seconds", &setting, "predict (coalesced)", acc_coal.mean());
    table.push(
        "predict rows/sec",
        &setting,
        "predict (sequential)",
        (k * chunk) as f64 / acc_seq.median().max(1e-12),
    );
    table.push(
        "predict rows/sec",
        &setting,
        "predict (coalesced)",
        (k * chunk) as f64 / acc_coal.median().max(1e-12),
    );
    table.push(
        "coalesced predict speedup",
        &setting,
        "predict (coalesced)",
        acc_seq.median() / acc_coal.median().max(1e-12),
    );

    table.finish("serve");
}
