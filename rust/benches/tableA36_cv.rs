//! **Table A36**: improvement factor of screening under 10-fold
//! cross-validation, linear and logistic models — the "expanded tuning
//! regimes" argument of §1.2 / Appendix D.7.
//!
//! Paper shape: screened CV is 2–4× faster end-to-end (smaller than the
//! single-path factors because fold fits share the λ path and the folds
//! amortize fixed costs), with DFR ahead of sparsegl.
//!
//! A second section prices the workspace-pooled grid-search engine against
//! the per-cell fresh-allocation reference (`grid_search_reference`): same
//! `(α × γ)` grid, same folds, same answers — the pooled engine shares one
//! fold plan and `threads` path workspaces across every cell while the
//! reference re-splits, re-standardizes, and re-allocates per cell. The
//! "path workspaces allocated" row is the no-per-fold-allocation witness:
//! it stays at the thread count no matter how many fold fits run.

mod common;

use dfr::bench_harness::{time_once, BenchTable};
use dfr::cv::{cross_validate, grid_search_reference, CvConfig, CvEngine};
use dfr::data::{Response, SyntheticConfig};
use dfr::screen::RuleKind;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len, folds) = if full { (1000, 200, 50, 10) } else { (250, 120, 10, 5) };

    let mut table = BenchTable::new("Table A36 — cross-validation improvement factor");
    for (resp, tag) in [(Response::Linear, "linear"), (Response::Logistic, "logistic")] {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, response: resp, ..SyntheticConfig::default() }
                .generate(9000 + rep as u64);
            let base = CvConfig {
                folds,
                path: common::bench_path_config(path_len),
                seed: 100 + rep as u64,
                ..CvConfig::default()
            };
            let no_screen = cross_validate(
                &data.dataset,
                &CvConfig { rule: RuleKind::NoScreen, ..base.clone() },
            )
            .expect("no-screen cv failed");
            for rule in [RuleKind::DfrAsgl, RuleKind::DfrSgl, RuleKind::Sparsegl] {
                let mut cfg = CvConfig { rule, ..base.clone() };
                if rule == RuleKind::DfrAsgl {
                    cfg.path.adaptive = Some((0.1, 0.1));
                }
                let cell = cross_validate(&data.dataset, &cfg).expect("cv failed");
                table.push(
                    "improvement factor",
                    tag,
                    rule.name(),
                    no_screen.seconds / cell.seconds.max(1e-12),
                );
                table.push("cv seconds", tag, rule.name(), cell.seconds);
                // CV must pick (nearly) the same λ regardless of screening.
                table.push(
                    "best-λ index drift vs no-screen",
                    tag,
                    rule.name(),
                    (cell.best_idx as f64 - no_screen.best_idx as f64).abs(),
                );
            }
        }
    }

    // --- Workspace-pooled vs per-cell-alloc grid search ---------------
    let data = SyntheticConfig { n, p, ..SyntheticConfig::default() }.generate(9900);
    let base = CvConfig {
        folds,
        path: common::bench_path_config(path_len),
        seed: 177,
        rule: RuleKind::DfrSgl,
        ..CvConfig::default()
    };
    let alphas = [0.5, 0.95];
    let gammas = [None, Some((0.1, 0.1))];
    let cells = alphas.len() * gammas.len();
    let setting = format!("{cells}-cell α×γ grid");
    let engine = CvEngine::new(base.threads);
    // Warm-up: grow the pooled workspaces to full size once, outside the
    // timed region (the reference path re-allocates by design, so a
    // warm-up run would not help it).
    engine
        .grid_search(&data.dataset, &base, &alphas, &gammas)
        .expect("warm-up grid search failed");
    let checkouts_before = engine.pool_checkouts();
    for _ in 0..common::repeats() {
        let (t_pool, pooled) = time_once(|| {
            engine
                .grid_search(&data.dataset, &base, &alphas, &gammas)
                .expect("pooled grid search failed")
        });
        let (t_ref, reference) = time_once(|| {
            grid_search_reference(&data.dataset, &base, &alphas, &gammas)
                .expect("reference grid search failed")
        });
        assert_eq!(pooled.1, reference.1, "pooled grid picked a different winner");
        table.push("grid-search seconds", &setting, "workspace-pooled", t_pool);
        table.push("grid-search seconds", &setting, "reference-alloc", t_ref);
        table.push(
            "grid improvement factor (ref / pooled)",
            &setting,
            "workspace-pooled",
            t_ref / t_pool.max(1e-12),
        );
    }
    let fits_per_run =
        (engine.pool_checkouts() - checkouts_before) as f64 / common::repeats() as f64;
    table.push(
        "path workspaces allocated",
        &setting,
        "workspace-pooled",
        engine.pool_slots() as f64,
    );
    table.push(
        "path fits served per grid search",
        &setting,
        "workspace-pooled",
        fits_per_run,
    );
    table.push(
        "path workspaces allocated",
        &setting,
        "reference-alloc",
        // One coordinator workspace per path fit, by construction.
        fits_per_run,
    );

    table.finish("tableA36_cv");
}
