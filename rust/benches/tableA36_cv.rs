//! **Table A36**: improvement factor of screening under 10-fold
//! cross-validation, linear and logistic models — the "expanded tuning
//! regimes" argument of §1.2 / Appendix D.7.
//!
//! Paper shape: screened CV is 2–4× faster end-to-end (smaller than the
//! single-path factors because fold fits share the λ path and the folds
//! amortize fixed costs), with DFR ahead of sparsegl.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::cv::{cross_validate, CvConfig};
use dfr::data::{Response, SyntheticConfig};
use dfr::screen::RuleKind;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len, folds) = if full { (1000, 200, 50, 10) } else { (250, 120, 10, 5) };

    let mut table = BenchTable::new("Table A36 — cross-validation improvement factor");
    for (resp, tag) in [(Response::Linear, "linear"), (Response::Logistic, "logistic")] {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, response: resp, ..SyntheticConfig::default() }
                .generate(9000 + rep as u64);
            let base = CvConfig {
                folds,
                path: common::bench_path_config(path_len),
                seed: 100 + rep as u64,
                ..CvConfig::default()
            };
            let no_screen = cross_validate(
                &data.dataset,
                &CvConfig { rule: RuleKind::NoScreen, ..base.clone() },
            )
            .expect("no-screen cv failed");
            for rule in [RuleKind::DfrAsgl, RuleKind::DfrSgl, RuleKind::Sparsegl] {
                let mut cfg = CvConfig { rule, ..base.clone() };
                if rule == RuleKind::DfrAsgl {
                    cfg.path.adaptive = Some((0.1, 0.1));
                }
                let cell = cross_validate(&data.dataset, &cfg).expect("cv failed");
                table.push(
                    "improvement factor",
                    tag,
                    rule.name(),
                    no_screen.seconds / cell.seconds.max(1e-12),
                );
                table.push("cv seconds", tag, rule.name(), cell.seconds);
                // CV must pick (nearly) the same λ regardless of screening.
                table.push(
                    "best-λ index drift vs no-screen",
                    tag,
                    rule.name(),
                    (cell.best_idx as f64 - no_screen.best_idx as f64).abs(),
                );
            }
        }
    }
    table.finish("tableA36_cv");
}
