//! **Figure 3 + Figure A4 + Tables A11–A16**: input proportion and
//! improvement factor as functions of (left) the within-group correlation
//! ρ and (right) the SGL mixing parameter α, linear model.
//!
//! Paper shape: DFR's reduction dominates sparsegl's, most visibly at low
//! correlation and at α near the conventional 0.95; screening efficiency
//! decreases roughly linearly as α → 0 (SGL keeps more variables per
//! active group, so the second layer matters less).

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::SyntheticConfig;
use dfr::path::PathConfig;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (300, 100, 15) };

    let mut t1 = BenchTable::new("Fig. 3 (left) / Tables A11-A13 — correlation sweep");
    let rhos: &[f64] = if full { &[0.0, 0.15, 0.3, 0.5, 0.7, 0.9] } else { &[0.0, 0.3, 0.7] };
    for &rho in rhos {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, rho, ..SyntheticConfig::default() }
                .generate(4000 + rep as u64);
            common::run_cell(
                &mut t1,
                &format!("rho={rho}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }
    t1.finish("fig3_correlation");

    let mut t2 = BenchTable::new("Fig. 3 (right) / Tables A14-A16 — alpha sweep");
    let alphas: &[f64] =
        if full { &[0.05, 0.2, 0.4, 0.6, 0.8, 0.95] } else { &[0.1, 0.5, 0.95] };
    for &alpha in alphas {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, ..SyntheticConfig::default() }
                .generate(5000 + rep as u64);
            let cfg = PathConfig { alpha, ..common::bench_path_config(path_len) };
            common::run_cell(
                &mut t2,
                &format!("alpha={alpha}"),
                &data.dataset,
                &cfg,
                &common::STRONG_RULES,
            );
        }
    }
    t2.finish("fig3_alpha");
}
