//! **Table 1 + Figure A5 + Tables A17–A19** (and with `--logistic`,
//! **Table A20 + Figure A7 + Tables A21–A23**): improvement factor for the
//! strong rules on within-group interaction expansions of order 2 and 3.
//!
//! Paper design: p=400, n=80, m=52 groups of sizes in [3,15] →
//! p_O2 ≈ 2111, p_O3 ≈ 7338, interaction active proportion 0.3 with the
//! marginal effects' signal, no hierarchy. Paper shape: DFR-aSGL > DFR-SGL
//! ≫ sparsegl, with sparsegl nearly useless at order 3 (it must pull in
//! entire, now-enormous, groups).

mod common;

use dfr::bench_harness::{BenchArgs, BenchTable};
use dfr::data::interactions::{expand_generated, InteractionOrder};
use dfr::data::synthetic::GroupSpec;
use dfr::data::{Response, SyntheticConfig};

fn main() {
    let args = BenchArgs::from_env();
    let logistic = args.has("--logistic");
    let full = dfr::bench_harness::full_scale();
    let (p, n, lo, hi, path_len) = if full { (400, 80, 3, 15, 50) } else { (150, 60, 3, 8, 12) };

    let title = if logistic {
        "Table A20 / Fig. A7 / Tables A21-A23 — interactions, logistic model"
    } else {
        "Table 1 / Fig. A5 / Tables A17-A19 — interactions, linear model"
    };
    let mut table = BenchTable::new(title);

    for order in [InteractionOrder::Order2, InteractionOrder::Order3] {
        for rep in 0..common::repeats() {
            let base = SyntheticConfig {
                n,
                p,
                groups: GroupSpec::Uneven { lo, hi },
                group_sparsity: 0.3,
                var_sparsity: 0.3,
                response: if logistic { Response::Logistic } else { Response::Linear },
                ..SyntheticConfig::default()
            }
            .generate(6000 + rep as u64);
            let expanded = expand_generated(&base, order, 0.3, 2.0, 60 + rep as u64);
            let setting = format!(
                "order {} (p={})",
                if order == InteractionOrder::Order3 { 3 } else { 2 },
                expanded.p()
            );
            common::run_cell(
                &mut table,
                &setting,
                &expanded,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }
    table.finish(if logistic { "tableA20_interactions_logistic" } else { "table1_interactions" });
}
