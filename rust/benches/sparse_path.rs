//! **Sparse solve path bench**: dense standardized kernels vs the
//! centered-implicit sparse kernels on genotype-like synthetic designs at
//! 1% / 5% / 20% density — the headline numbers for the `DesignOps`
//! sparse path (ROADMAP "Design-level sparse solver path").
//!
//! Each density level fits the same CSC design through two fitters that
//! differ only in `SparseMode` (Off → densified standardized matrix,
//! On → `CenteredSparse`), with the path cache cleared per repetition so
//! every request solves. Rows land in
//! `target/bench_results/BENCH_sparse_path.json`:
//!
//! * `pathwise fit seconds` per kernel,
//! * `dense/sparse speedup` (mean dense seconds / mean sparse seconds),
//! * `csc density` as fitted.

use dfr::bench_harness::{time_stat, BenchTable};
use dfr::linalg::CscMatrix;
use dfr::model_api::{Design, SglModel, SparseMode};
use dfr::path::PathConfig;
use dfr::rng::Rng;

/// Genotype-like CSC design at (approximately) the requested density:
/// dosages in {1, 2} at Bernoulli-sampled positions.
fn genotype(seed: u64, n: usize, p: usize, density: f64) -> CscMatrix {
    let mut rng = Rng::new(seed);
    // Two Bernoulli(maf) draws per cell → P(nonzero) = 1 − (1 − maf)².
    let maf = 1.0 - (1.0 - density).sqrt();
    let mut col_ptr = vec![0usize];
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..p {
        for i in 0..n {
            let dosage = (rng.bernoulli(maf) as u8 + rng.bernoulli(maf) as u8) as f64;
            if dosage > 0.0 {
                row_idx.push(i);
                values.push(dosage);
            }
        }
        col_ptr.push(row_idx.len());
    }
    CscMatrix::new(n, p, col_ptr, row_idx, values)
}

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (n, p, path_len) = if full { (400usize, 2000usize, 30usize) } else { (200, 800, 15) };
    let groups = 40usize;
    let sizes = vec![p / groups; groups];
    let mut table =
        BenchTable::new("Sparse solve path — dense vs centered-implicit kernels");
    let (warmup, reps) = (1, if full { 5 } else { 7 });

    for (di, density) in [0.01f64, 0.05, 0.20].into_iter().enumerate() {
        let geno = genotype(90 + di as u64, n, p, density);
        let mut rng = Rng::new(17 + di as u64);
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 37 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
        let y: Vec<f64> =
            geno.matvec(&beta_true).iter().map(|v| v + rng.normal(0.0, 0.3)).collect();
        let setting = format!("{n}x{p}@{density}");

        let model = SglModel {
            path: PathConfig { path_len, ..PathConfig::default() },
            ..SglModel::default()
        };
        let run = |mode: SparseMode, label: &str| {
            let mut fitter =
                SglModel { sparse: mode, ..model.clone() }.fitter();
            let acc = time_stat(warmup, reps, || {
                fitter.clear_path_cache();
                let fit = fitter
                    .fit_path(&Design::Csc(&geno), &y, &sizes, dfr::data::Response::Linear)
                    .expect("fit failed");
                std::hint::black_box(fit.lambdas.len());
            });
            assert_eq!(
                fitter.kernel_variant(),
                Some(label),
                "fitter did not resolve the expected kernel"
            );
            acc.mean()
        };
        let dense_s = run(SparseMode::Off, "dense");
        let sparse_s = run(SparseMode::On, "centered-sparse");

        table.push("pathwise fit seconds", &setting, "dense kernel", dense_s);
        table.push("pathwise fit seconds", &setting, "sparse kernel", sparse_s);
        table.push(
            "dense/sparse speedup",
            &setting,
            "sparse kernel",
            dense_s / sparse_s.max(1e-12),
        );
        table.push("csc density", &setting, "sparse kernel", geno.density());
    }

    table.finish("sparse_path");
}
