//! **§Perf microbenches** (EXPERIMENTS.md §Perf): the per-layer hot paths
//! behind every pathwise fit.
//!
//! * L3 native gradient `Xᵀr/n` — serial vs threaded (the dominant cost of
//!   screening + KKT checks),
//! * ε-norm solver, SGL prox, one full screening pass, one FISTA step —
//!   the L3 coordinator costs that must stay below the matvec,
//! * the full pathwise DFR fit — the headline number: persistent-workspace
//!   hot loop vs the fresh-allocation reference, plus the screening
//!   reduction stats (`C_v/p`, `O_v/p`) that explain it.
//!
//! `finish()` emits `target/bench_results/BENCH_perf_hotpath.json`
//! (median seconds per row), the cross-PR perf trajectory record.

mod common;

use dfr::bench_harness::{time_stat, BenchTable};
use dfr::data::SyntheticConfig;
use dfr::loss::{Loss, LossKind};
use dfr::norms::epsilon_norm;
use dfr::path::{PathConfig, PathRunner, PathWorkspace};
use dfr::penalty::Penalty;
use dfr::rng::Rng;
use dfr::screen::{screen, RuleKind, ScreenContext};
use dfr::solver::SolverWorkspace;

fn main() {
    let mut table = BenchTable::new("§Perf — hot-path microbenches (seconds per call)");
    let (n, p) = (200usize, 1000usize);
    let data = SyntheticConfig { n, p, ..SyntheticConfig::default() }.generate(77);
    let ds = &data.dataset;
    let loss = Loss::new(LossKind::Squared, &ds.x, &ds.y);
    let mut rng = Rng::new(1);
    let beta: Vec<f64> = rng.gauss_vec(p).iter().map(|v| v * 0.1).collect();
    let setting = format!("{n}x{p}");
    let (warm, reps) = (3, 30);

    // --- L3 native gradient ---
    let acc = time_stat(warm, reps, || {
        std::hint::black_box(loss.x.t_matvec(&loss.x.matvec(&beta)));
    });
    table.push("gradient (native, 1 thread)", &setting, "native", acc.mean());
    for threads in [2usize, 4, 8] {
        let acc = time_stat(warm, reps, || {
            let xb = loss.x.matvec(&beta);
            std::hint::black_box(loss.x.t_matvec_par(&xb, threads));
        });
        table.push(
            &format!("gradient (native, {threads} threads)"),
            &setting,
            "native",
            acc.mean(),
        );
    }

    // --- L3 coordinator pieces ---
    let grad = loss.gradient(&vec![0.0; p]);
    let pen = Penalty::sgl(ds.groups.clone(), 0.95);
    let lam1 = dfr::path::lambda_max(&pen, &grad);
    let acc = time_stat(warm, 200, || {
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam1,
            lambda_next: 0.9 * lam1,
            x: (&ds.x).into(),
            y: &ds.y,
            response: ds.response,
        };
        std::hint::black_box(screen(RuleKind::DfrSgl, &ctx));
    });
    table.push("one DFR screening pass", &setting, "dfr", acc.mean());

    let block: Vec<f64> = rng.gauss_vec(100);
    let acc = time_stat(warm, 2000, || {
        std::hint::black_box(epsilon_norm(&block, 0.37));
    });
    table.push("epsilon-norm (p_g=100)", &setting, "norms", acc.mean());

    let z: Vec<f64> = rng.gauss_vec(p);
    let mut out = vec![0.0; p];
    let acc = time_stat(warm, 2000, || {
        pen.prox_into(&z, 0.01, &mut out);
        std::hint::black_box(&out);
    });
    table.push("SGL prox (full p)", &setting, "penalty", acc.mean());

    // One warm FISTA solve on a screened-size problem (|O_v| ≈ 60).
    let keep: Vec<usize> = (0..60).map(|i| i * (p / 60)).collect();
    let x_red = ds.x.dense().gather_columns(&keep);
    let rpen = pen.restrict(&keep);
    let red_loss = Loss::new(LossKind::Squared, &x_red, &ds.y);
    let cfg = dfr::solver::SolverConfig::default();
    let acc = time_stat(warm, 20, || {
        std::hint::black_box(dfr::solver::solve(
            &red_loss,
            &rpen,
            0.3 * lam1,
            &vec![0.0; keep.len()],
            &cfg,
        ));
    });
    table.push("reduced FISTA solve (k=60)", &setting, "solver", acc.mean());

    // Same solve through a persistent workspace (zero allocations in the
    // iteration loop after warm-up).
    let mut sws = SolverWorkspace::new();
    let warm0 = vec![0.0; keep.len()];
    let acc = time_stat(warm, 20, || {
        std::hint::black_box(dfr::solver::solve_ws(
            &red_loss,
            &rpen,
            0.3 * lam1,
            &warm0,
            &cfg,
            &mut sws,
        ));
    });
    table.push("reduced FISTA solve (k=60, workspace)", &setting, "solver", acc.mean());

    // --- L3 pathwise fit: the headline perf_hotpath number ---
    // Persistent-workspace hot loop vs the fresh-allocation reference on
    // the same dims/rule; screening-reduction stats recorded alongside so
    // the JSON explains the speedup, not just states it.
    let path_cfg = PathConfig { path_len: 20, ..PathConfig::default() };
    let (path_warm, path_reps) = (1, 7);
    let mut pws = PathWorkspace::new(n, p, ds.m());
    let acc = time_stat(path_warm, path_reps, || {
        let fit = PathRunner::new(ds, path_cfg.clone())
            .rule(RuleKind::DfrSgl)
            .run_with_workspace(&mut pws)
            .unwrap();
        std::hint::black_box(fit.metrics.total_seconds);
    });
    table.push("pathwise DFR fit (L=20)", &setting, "dfr", acc.mean());

    let acc = time_stat(path_warm, path_reps, || {
        let fit = PathRunner::new(ds, path_cfg.clone())
            .rule(RuleKind::DfrSgl)
            .reference_alloc(true)
            .run()
            .unwrap();
        std::hint::black_box(fit.metrics.total_seconds);
    });
    table.push("pathwise DFR fit (L=20, fresh-alloc reference)", &setting, "dfr", acc.mean());

    let fit = PathRunner::new(ds, path_cfg.clone())
        .rule(RuleKind::DfrSgl)
        .run_with_workspace(&mut pws)
        .unwrap();
    let pts = fit.metrics.points.len() as f64;
    table.push(
        "pathwise C_v/p (candidate reduction)",
        &setting,
        "dfr",
        fit.metrics.points.iter().map(|pt| pt.c_v as f64).sum::<f64>() / (pts * p as f64),
    );
    table.push(
        "pathwise O_v/p (input proportion)",
        &setting,
        "dfr",
        fit.metrics.input_proportion(),
    );

    table.finish("perf_hotpath");
}
