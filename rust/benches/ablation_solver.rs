//! **Ablation** (DESIGN.md §6.4): screening gains must be
//! solver-independent — the paper stresses DFR "can be used with any
//! fitting algorithm". Runs the default synthetic workload under both
//! inner solvers (FISTA with the exact SGL prox; ATOS, the paper's
//! algorithm) × {DFR, sparsegl, no-screen}, plus the XLA-served engine
//! when artifacts exist.
//!
//! Expected: improvement factors agree across solvers within noise; the
//! absolute times differ (FISTA's exact prox usually converges in fewer
//! iterations); engine choice does not change solutions.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::SyntheticConfig;
use dfr::path::{PathConfig, PathRunner};
use dfr::runtime::XlaEngine;
use dfr::screen::RuleKind;
use dfr::solver::{SolverConfig, SolverKind};

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (300, 100, 15) };

    let mut table = BenchTable::new("Ablation — inner solver (FISTA vs ATOS) × screening rule");
    for (kind, tag) in [(SolverKind::Fista, "fista"), (SolverKind::Atos, "atos")] {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, ..SyntheticConfig::default() }
                .generate(11_000 + rep as u64);
            let cfg = PathConfig {
                path_len,
                solver: SolverConfig { kind, ..SolverConfig::default() },
                ..PathConfig::default()
            };
            common::run_cell(
                &mut table,
                tag,
                &data.dataset,
                &cfg,
                &[RuleKind::DfrSgl, RuleKind::Sparsegl],
            );
        }
    }

    // Engine ablation: native vs PJRT-served (gradients + bucketed solver)
    // on the Table A1 shape with artifacts present.
    if let Ok(eng) = XlaEngine::new("artifacts") {
        if eng.has_artifact("grad_sq_200x1000") {
            for rep in 0..common::repeats() {
                let data = SyntheticConfig { n: 200, p: 1000, ..SyntheticConfig::default() }
                    .generate(12_000 + rep as u64);
                let cfg = PathConfig { path_len: 20, ..PathConfig::default() };
                let native =
                    PathRunner::new(&data.dataset, cfg.clone()).rule(RuleKind::DfrSgl).run().unwrap();
                let xla = PathRunner::new(&data.dataset, cfg)
                    .rule(RuleKind::DfrSgl)
                    .engine(&eng)
                    .fixed_path(native.lambdas.clone())
                    .run()
                    .unwrap();
                table.push(
                    "path seconds",
                    "engine=native",
                    "DFR-SGL",
                    native.metrics.total_seconds,
                );
                table.push("path seconds", "engine=pjrt", "DFR-SGL", xla.metrics.total_seconds);
                table.push(
                    "l2 distance native vs pjrt",
                    "engine=pjrt",
                    "DFR-SGL",
                    xla.l2_distance_to(&native),
                );
            }
            let s = eng.stats();
            println!(
                "[pjrt] {} gradient calls, {} solver chunks, {} fallbacks",
                s.xla_gradient_calls, s.xla_solver_chunks, s.native_fallbacks
            );
        }
    }
    table.finish("ablation_solver");
}
