//! **Ablation** (DESIGN.md §6.4): screening gains must be
//! solver-independent — the paper stresses DFR "can be used with any
//! fitting algorithm". Runs the default synthetic workload under the
//! three inner solvers (FISTA with the exact SGL prox; ATOS, the paper's
//! algorithm; group-major BCD, the `sparsegl`-style block solver) ×
//! {DFR, sparsegl, no-screen}, plus a solver × kernel × group-regime
//! section (dense vs 5%-density centered-sparse, small vs large groups —
//! the regimes where block updates pay differently).
//!
//! Expected: improvement factors agree across solvers within noise; the
//! absolute times differ (FISTA's exact prox usually converges in fewer
//! iterations; BCD wins when few groups are active and on sparse column
//! blocks); kernel choice does not change solutions.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::{Dataset, Response, SyntheticConfig};
use dfr::linalg::{CenteredSparse, CscMatrix, DesignOps, Matrix};
use dfr::path::{PathConfig, PathRunner};
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::RuleKind;
use dfr::solver::{SolverConfig, SolverKind};

const SOLVERS: [(SolverKind, &str); 3] = [
    (SolverKind::Fista, "fista"),
    (SolverKind::Atos, "atos"),
    (SolverKind::Bcd, "bcd"),
];

/// One 5%-density problem as a dense-kernel and a sparse-kernel dataset
/// (same implied standardized design, same response, even groups of
/// `gsize`).
fn sparse_pair(seed: u64, n: usize, p: usize, gsize: usize) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed);
    let raw = Matrix::from_fn(n, p, |_, _| {
        if rng.bernoulli(0.05) {
            rng.gauss()
        } else {
            0.0
        }
    });
    let csc = CscMatrix::from_dense(&raw, 0.0);
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 11 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let y: Vec<f64> =
        raw.matvec(&beta_true).iter().map(|v| v + rng.normal(0.0, 0.3)).collect();
    let groups = Groups::even(p, gsize);
    let (dense_std, _) = csc.to_standardized_dense();
    let dense_ds = Dataset {
        x: dense_std.into(),
        y: y.clone(),
        groups: groups.clone(),
        response: Response::Linear,
        name: "sparse5-dense".into(),
    };
    let sparse_ds = Dataset {
        x: DesignOps::Sparse(CenteredSparse::from_csc(&csc)),
        y,
        groups,
        response: Response::Linear,
        name: "sparse5-sparse".into(),
    };
    (dense_ds, sparse_ds)
}

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (300, 100, 15) };

    let mut table =
        BenchTable::new("Ablation — inner solver (FISTA vs ATOS vs BCD) × screening rule");
    for (kind, tag) in SOLVERS {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, ..SyntheticConfig::default() }
                .generate(11_000 + rep as u64);
            let cfg = PathConfig {
                path_len,
                solver: SolverConfig { kind, ..SolverConfig::default() },
                ..PathConfig::default()
            };
            common::run_cell(
                &mut table,
                tag,
                &data.dataset,
                &cfg,
                &[RuleKind::DfrSgl, RuleKind::Sparsegl],
            );
        }
    }

    // Solver × kernel × group-regime ablation: the same 5%-density
    // problem solved through the dense and the centered-implicit sparse
    // kernels, with small groups (many blocks, cheap updates) and large
    // groups (few blocks, heavy updates) — the two regimes where BCD's
    // per-group block updates pay differently. The sparse fit reuses the
    // dense fit's λ path so seconds are directly comparable.
    let (n2, p2, path_len2) = if full { (400, 800, 30) } else { (120, 240, 10) };
    for (kind, tag) in SOLVERS {
        for (regime, gsize) in [("small-groups", 5usize), ("large-groups", 60usize)] {
            for rep in 0..common::repeats() {
                let (dense_ds, sparse_ds) =
                    sparse_pair(13_000 + rep as u64, n2, p2, gsize);
                let cfg = PathConfig {
                    path_len: path_len2,
                    solver: SolverConfig { kind, ..SolverConfig::default() },
                    ..PathConfig::default()
                };
                let setting = format!("{tag} {regime}");
                let dense_fit = PathRunner::new(&dense_ds, cfg.clone())
                    .rule(RuleKind::DfrSgl)
                    .run()
                    .expect("dense 5%-density fit failed");
                let sparse_fit = PathRunner::new(&sparse_ds, cfg)
                    .rule(RuleKind::DfrSgl)
                    .fixed_path(dense_fit.lambdas.clone())
                    .run()
                    .expect("sparse 5%-density fit failed");
                table.push(
                    "dense path seconds",
                    &setting,
                    "DFR-SGL",
                    dense_fit.metrics.total_seconds,
                );
                table.push(
                    "sparse path seconds",
                    &setting,
                    "DFR-SGL",
                    sparse_fit.metrics.total_seconds,
                );
                table.push(
                    "l2 distance sparse vs dense",
                    &setting,
                    "DFR-SGL",
                    sparse_fit.l2_distance_to(&dense_fit),
                );
            }
        }
    }

    table.finish("ablation_solver");
}
