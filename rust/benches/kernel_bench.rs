//! **§Perf kernel microbenches** (EXPERIMENTS.md §Perf): the dense and
//! centered-sparse compute kernels underneath every matvec in the
//! pathwise hot loop, timed per backend — the `scalar` reference against
//! the runtime-**dispatched** backend (AVX2+FMA where the CPU has it).
//!
//! Every operation reports three metrics: raw `seconds`, effective memory
//! bandwidth `GB/s`, and arithmetic throughput `GFLOP/s` (both derived
//! from the op's nominal byte/flop counts, so cross-backend ratios are
//! exact even though the absolute numbers are nominal). A final
//! `speedup dispatched/scalar` row gives the headline ratio for dense
//! `t_matvec` at n=2000 × p=4000 — the acceptance gate. On hardware
//! without AVX2+FMA the dispatched backend *is* scalar and that ratio
//! sits at ~1.0 by construction; the bench title records which backend
//! actually ran, so the JSON is self-describing either way.
//!
//! All kernels here are timed single-threaded (`*_into` serial forms) to
//! isolate the backend effect from row/column chunking.
//!
//! `finish()` emits `target/bench_results/BENCH_kernel_bench.json`.

use dfr::bench_harness::{time_stat, BenchTable};
use dfr::linalg::kernels::{self, Backend};
use dfr::linalg::{CenteredSparse, CscMatrix, Matrix};
use dfr::rng::Rng;

fn main() {
    // Captured before any override so the title names what `auto` picked.
    let dispatched = kernels::describe();
    let mut table =
        BenchTable::new(&format!("§Perf — kernel backends (dispatched = {dispatched})"));

    let (n, p) = (2000usize, 4000usize);
    let setting = format!("{n}x{p}");
    let mut rng = Rng::new(99);

    // Dense design + a ~5% dense-zeros design routed through the CSC
    // ingest (the centered-implicit sparse kernel path).
    let x = Matrix::from_fn(n, p, |_, _| rng.gauss());
    let xs_dense =
        Matrix::from_fn(n, p, |_, _| if rng.bernoulli(0.05) { rng.gauss() } else { 0.0 });
    let csc = CscMatrix::from_dense(&xs_dense, 0.0);
    let xs = CenteredSparse::from_csc(&csc);
    let nnz = csc.nnz();

    let r: Vec<f64> = rng.gauss_vec(n);
    let beta: Vec<f64> = rng.gauss_vec(p).iter().map(|v| 0.1 * v).collect();
    let vlen = 1usize << 20;
    let va: Vec<f64> = rng.gauss_vec(vlen);
    let vb: Vec<f64> = rng.gauss_vec(vlen);

    let mut out_p = vec![0.0; p];
    let mut out_n = vec![0.0; n];
    let mut vy = vec![0.0; vlen];

    // Per-mode mean seconds of dense t_matvec, for the speedup row.
    let mut tmv_secs = [f64::NAN; 2];

    let modes: [(&str, Option<Backend>); 2] =
        [("scalar", Some(Backend::Scalar)), ("dispatched", None)];
    for (mi, &(label, pin)) in modes.iter().enumerate() {
        kernels::set_backend_override(pin);

        // --- level-1 vector kernels (1M doubles) ---
        let (vbytes, vflops) = (8.0 * vlen as f64, vlen as f64);
        let acc = time_stat(3, 50, || {
            std::hint::black_box(kernels::dot(&va, &vb));
        });
        push3(&mut table, "dot (1M)", &setting, label, &acc, 2.0 * vbytes, 2.0 * vflops);

        let acc = time_stat(3, 50, || {
            kernels::axpy(1.0000001, &va, &mut vy);
            std::hint::black_box(&vy);
        });
        push3(&mut table, "axpy (1M)", &setting, label, &acc, 3.0 * vbytes, 2.0 * vflops);

        let acc = time_stat(3, 50, || {
            std::hint::black_box(kernels::norm1(&va));
        });
        push3(&mut table, "norm1 (1M)", &setting, label, &acc, vbytes, vflops);

        // --- dense design kernels ---
        let dense_bytes = 8.0 * (n * p) as f64;
        let dense_flops = 2.0 * (n * p) as f64;
        let acc = time_stat(2, 10, || {
            x.t_matvec_into(&r, &mut out_p);
            std::hint::black_box(&out_p);
        });
        tmv_secs[mi] = acc.mean();
        push3(&mut table, "dense t_matvec", &setting, label, &acc, dense_bytes, dense_flops);

        let acc = time_stat(2, 10, || {
            x.matvec_into(&beta, &mut out_n);
            std::hint::black_box(&out_n);
        });
        push3(&mut table, "dense matvec", &setting, label, &acc, dense_bytes, dense_flops);

        // --- centered-sparse design kernels (~5% density) ---
        // Nominal traffic: value + row index per nonzero, plus the
        // offset/scale/output vectors; flops: the fused
        // `(s − offset·Σr)/scale` costs ~3 per column on top of 2·nnz.
        let sp_bytes = 16.0 * nnz as f64 + 8.0 * (n + 3 * p) as f64;
        let sp_flops = 2.0 * nnz as f64 + 3.0 * p as f64;
        let acc = time_stat(2, 10, || {
            xs.t_matvec_into(&r, &mut out_p);
            std::hint::black_box(&out_p);
        });
        push3(&mut table, "sparse t_matvec (5%)", &setting, label, &acc, sp_bytes, sp_flops);

        let acc = time_stat(2, 10, || {
            xs.matvec_into(&beta, &mut out_n);
            std::hint::black_box(&out_n);
        });
        push3(&mut table, "sparse matvec (5%)", &setting, label, &acc, sp_bytes, sp_flops);
    }
    kernels::set_backend_override(None);

    // Headline ratio (the ≥2× acceptance gate on AVX2 hardware; ~1.0 when
    // the dispatched backend degrades to scalar).
    table.push(
        "speedup dispatched/scalar (dense t_matvec)",
        &setting,
        "dispatched",
        tmv_secs[0] / tmv_secs[1],
    );

    table.finish("kernel_bench");
}

/// Record seconds plus the derived bandwidth/throughput for one cell.
fn push3(
    table: &mut BenchTable,
    op: &str,
    setting: &str,
    method: &str,
    acc: &dfr::metrics::Accumulator,
    bytes: f64,
    flops: f64,
) {
    let s = acc.mean();
    table.push(&format!("{op} seconds"), setting, method, s);
    table.push(&format!("{op} GB/s"), setting, method, bytes / s / 1e9);
    table.push(&format!("{op} GFLOP/s"), setting, method, flops / s / 1e9);
}
