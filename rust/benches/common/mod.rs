//! Shared machinery for the paper-reproduction benches.
//!
//! Every bench follows the paper's §3 protocol: draw a dataset, fit the
//! full λ path with *no screening* (the timing baseline and the λ grid),
//! then fit each screening rule on the same path, recording the paper's
//! metrics per (setting, method): improvement factor, input proportion,
//! candidate/optimization/active cardinalities, KKT violations, failed
//! convergences and ℓ₂ distance to the no-screen solution. Repeats with
//! distinct seeds give the mean ± stderr the tables show.
//!
//! `cargo bench` runs a smoke scale (minutes); `DFR_BENCH_FULL=1` switches
//! to the paper scale (Table A1 sizes, 100-length repeats).

// Shared across all bench targets; each target uses a different subset.
#![allow(dead_code)]

use dfr::bench_harness::BenchTable;
use dfr::data::Dataset;
use dfr::path::{PathConfig, PathRunner};
use dfr::screen::RuleKind;

/// Repeats per setting: paper uses 100; smoke default keeps wall-clock low.
pub fn repeats() -> usize {
    if dfr::bench_harness::full_scale() {
        20
    } else {
        3
    }
}

/// The strong rules compared in most tables.
pub const STRONG_RULES: [RuleKind; 3] =
    [RuleKind::DfrAsgl, RuleKind::DfrSgl, RuleKind::Sparsegl];

/// Strong + safe rules (Fig. 1).
pub const ALL_RULES: [RuleKind; 6] = [
    RuleKind::DfrAsgl,
    RuleKind::DfrSgl,
    RuleKind::Sparsegl,
    RuleKind::GapSafeSeq,
    RuleKind::GapSafeDyn,
    RuleKind::Tlfre,
];

/// Run one (dataset, setting) cell: no-screen baseline plus every rule,
/// pushing all §3/§D.1 metrics into the table.
///
/// Pairing follows the paper: each screened fit is compared against the
/// no-screen fit of the *same model* — DFR-aSGL against an adaptive-SGL
/// baseline (its own λ path and timings), everything else against the
/// plain-SGL baseline.
pub fn run_cell(
    table: &mut BenchTable,
    setting: &str,
    ds: &Dataset,
    base_cfg: &PathConfig,
    rules: &[RuleKind],
) {
    let no_screen = PathRunner::new(ds, base_cfg.clone())
        .rule(RuleKind::NoScreen)
        .run()
        .expect("no-screen fit failed");
    table.push("no screen time (s)", setting, "no-screen", no_screen.metrics.total_seconds);

    // Lazy aSGL baseline (only when an adaptive rule is in the set).
    let mut asgl_baseline: Option<dfr::path::PathFit> = None;

    for &rule in rules {
        let mut cfg = base_cfg.clone();
        let adaptive = rule == RuleKind::DfrAsgl;
        if adaptive && cfg.adaptive.is_none() {
            cfg.adaptive = Some((0.1, 0.1));
        }
        let baseline: &dfr::path::PathFit = if adaptive {
            if asgl_baseline.is_none() {
                let b = PathRunner::new(ds, cfg.clone())
                    .rule(RuleKind::NoScreen)
                    .run()
                    .expect("aSGL no-screen fit failed");
                table.push(
                    "no screen time (s)",
                    setting,
                    "no-screen (aSGL)",
                    b.metrics.total_seconds,
                );
                asgl_baseline = Some(b);
            }
            asgl_baseline.as_ref().unwrap()
        } else {
            &no_screen
        };
        let t_base = baseline.metrics.total_seconds;
        let fit = PathRunner::new(ds, cfg)
            .rule(rule)
            .fixed_path(baseline.lambdas.clone())
            .run()
            .expect("screened fit failed");
        let m = &fit.metrics;
        let name = rule.name();
        table.push("improvement factor", setting, name, t_base / m.total_seconds.max(1e-12));
        table.push("input proportion (O_v/p)", setting, name, m.input_proportion());
        table.push("candidate proportion (C_v/p)", setting, name, m.candidate_proportion());
        table.push("group input proportion (O_g/m)", setting, name, m.group_input_proportion());
        table.push("screen time (s)", setting, name, m.total_seconds);
        table.push("KKT violations", setting, name, m.total_kkt_violations() as f64);
        // Safe rules record 0 by construction; strong rules pay per round.
        table.push("KKT re-entries", setting, name, m.total_kkt_reentries() as f64);
        table.push("max KKT residual", setting, name, m.max_kkt_residual());
        table.push("failed convergences", setting, name, m.failed_convergences() as f64);
        table.push("l2 distance to no screen", setting, name, fit.l2_distance_to(baseline));
        table.push("O_v / A_v", setting, name, m.ov_over_av());
        // Cardinality means (Tables A2/A3-style rows).
        let mean = |f: &dyn Fn(&dfr::metrics::PointMetrics) -> f64| {
            m.points.iter().map(|pt| f(pt)).sum::<f64>() / m.points.len() as f64
        };
        table.push("card A_v", setting, name, mean(&|pt| pt.a_v as f64));
        table.push("card C_v", setting, name, mean(&|pt| pt.c_v as f64));
        table.push("card O_v", setting, name, mean(&|pt| pt.o_v as f64));
        table.push("card A_g", setting, name, mean(&|pt| pt.a_g as f64));
        table.push("card C_g", setting, name, mean(&|pt| pt.c_g as f64));
        table.push("card O_g", setting, name, mean(&|pt| pt.o_g as f64));
    }
}

/// Default solver config for benches (paper Table A1 algorithm block).
pub fn bench_path_config(path_len: usize) -> PathConfig {
    PathConfig { path_len, ..PathConfig::default() }
}
