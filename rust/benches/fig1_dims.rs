//! **Figure 1 + Figure A2 + Tables A2–A4**: improvement factor and input
//! proportion of strong vs safe rules as a function of dimensionality `p`,
//! under even groups of size 20 (paper §3.1).
//!
//! Paper shape to reproduce: DFR's improvement factor grows with p and
//! dominates both GAP-safe variants and sparsegl; input proportions of DFR
//! and GAP safe are similar (Fig. A2) — the heuristic gets the exact rule's
//! reduction at a fraction of the overhead.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::synthetic::GroupSpec;
use dfr::data::SyntheticConfig;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let ps: &[usize] = if full { &[500, 1000, 2000, 5000] } else { &[200, 400, 800] };
    let n = if full { 200 } else { 100 };
    let path_len = if full { 50 } else { 20 };

    let mut table = BenchTable::new(
        "Fig. 1 / Fig. A2 / Tables A2-A4 — strong vs safe rules over dimensionality p \
         (even groups of 20)",
    );
    for &p in ps {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                groups: GroupSpec::Even(20),
                ..SyntheticConfig::default()
            }
            .generate(1000 + rep as u64);
            common::run_cell(
                &mut table,
                &format!("p={p}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::ALL_RULES,
            );
        }
    }
    table.finish("fig1_dims");
}
