//! **Figures A8–A11 + Tables A24–A35**: the synthetic sweeps of Figs. 2–3
//! repeated under the logistic model — sparsity proportion, signal
//! strength, correlation, and α.
//!
//! Paper shape: same ordering as the linear model (DFR > sparsegl) with
//! smaller absolute improvement factors (logistic fits are iteration-
//! bound, not purely matvec-bound).

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::{Response, SyntheticConfig};
use dfr::path::PathConfig;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (250, 120, 12) };

    let mut table = BenchTable::new(
        "Figs. A8-A11 / Tables A24-A35 — logistic-model sweeps \
         (sparsity, signal, correlation, alpha)",
    );

    let sparsities: &[f64] = if full { &[0.05, 0.2, 0.4, 0.8] } else { &[0.1, 0.5] };
    for &s in sparsities {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                group_sparsity: s,
                var_sparsity: s,
                response: Response::Logistic,
                ..SyntheticConfig::default()
            }
            .generate(8000 + rep as u64);
            common::run_cell(
                &mut table,
                &format!("sparsity={s}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }

    let signals: &[f64] = if full { &[0.5, 1.0, 2.0, 4.0] } else { &[0.5, 3.0] };
    for &s in signals {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                signal: s,
                response: Response::Logistic,
                ..SyntheticConfig::default()
            }
            .generate(8100 + rep as u64);
            common::run_cell(
                &mut table,
                &format!("signal={s}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }

    let rhos: &[f64] = if full { &[0.0, 0.3, 0.6, 0.9] } else { &[0.0, 0.6] };
    for &rho in rhos {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                rho,
                response: Response::Logistic,
                ..SyntheticConfig::default()
            }
            .generate(8200 + rep as u64);
            common::run_cell(
                &mut table,
                &format!("rho={rho}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }

    let alphas: &[f64] = if full { &[0.1, 0.4, 0.7, 0.95] } else { &[0.3, 0.95] };
    for &alpha in alphas {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                response: Response::Logistic,
                ..SyntheticConfig::default()
            }
            .generate(8300 + rep as u64);
            let cfg = PathConfig { alpha, ..common::bench_path_config(path_len) };
            common::run_cell(
                &mut table,
                &format!("alpha={alpha}"),
                &data.dataset,
                &cfg,
                &common::STRONG_RULES,
            );
        }
    }
    table.finish("figA8_logistic");
}
