//! **Out-of-core streaming bench**: the same DFR pathwise fit solved from
//! the in-memory dense standardized design and from a `.dfrpack` file
//! streamed in column blocks, on a wide design (n ≪ p — the biobank
//! shape the out-of-core store targets).
//!
//! Reported per scale:
//!   * pack seconds            — one-time `dfr pack` ingest cost
//!   * path seconds            — wall time of the full screened λ path
//!   * peak design MiB         — bytes of design resident at once
//!                               (dense: the whole n×p matrix; ooc: the
//!                               streaming-buffer high watermark)
//!   * process VmHWM MiB       — the kernel's peak-RSS witness
//!                               (/proc/self/status; 0 off Linux)
//!   * ℓ₂(ooc, dense)          — solution equivalence on the shared path
//!
//! Expected: identical solutions (ℓ₂ ≈ 1e-12); ooc pays a disk-read
//! multiple on wall time but holds two blocks instead of the full design.
//!
//! ```bash
//! cargo bench --bench ooc_path            # smoke scale
//! DFR_BENCH_FULL=1 cargo bench --bench ooc_path
//! DFR_OOC_BLOCK=256 cargo bench --bench ooc_path   # force narrow blocks
//! ```

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::{Dataset, Response};
use dfr::linalg::{ooc_peak_resident_bytes, ooc_reset_peak, DesignOps, Matrix};
use dfr::path::{PathConfig, PathRunner};
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::RuleKind;

/// Peak resident set size of this process in bytes (Linux VmHWM), 0 where
/// /proc is unavailable. A process-lifetime high watermark: it can only
/// ever grow, so the interesting comparison is against the dense design's
/// footprint, not between rows.
fn vm_hwm_bytes() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines().find(|l| l.starts_with("VmHWM:")).and_then(|l| {
                l.split_whitespace().nth(1).and_then(|kb| kb.parse::<usize>().ok())
            })
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

/// Wide raw design + sparse-signal response, grouped in tens.
fn workload(seed: u64, n: usize, p: usize) -> (Matrix, Vec<f64>, Groups) {
    let mut rng = Rng::new(seed);
    let raw = Matrix::from_fn(n, p, |_, _| rng.gauss());
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 97 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
    let mut y: Vec<f64> = raw.matvec(&beta_true).iter().map(|v| v + rng.normal(0.0, 0.3)).collect();
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    y.iter_mut().for_each(|v| *v -= mean);
    (raw, y, Groups::even(p, 10))
}

fn main() {
    let full = dfr::bench_harness::full_scale();
    // Wide shapes: the regime where the dense design dominates RAM.
    let scales: &[(usize, usize)] = if full {
        &[(200, 20_000), (200, 100_000)]
    } else {
        &[(100, 2_000), (200, 5_000)]
    };
    let path_len = if full { 30 } else { 10 };
    let mib = |bytes: usize| bytes as f64 / (1024.0 * 1024.0);

    let mut table = BenchTable::new("Out-of-core streaming vs in-memory dense — DFR-SGL path");
    for &(n, p) in scales {
        let setting = format!("n={n} p={p}");
        for rep in 0..common::repeats() {
            let (raw, y, groups) = workload(17_000 + rep as u64, n, p);
            let cfg = PathConfig { path_len, ..PathConfig::default() };

            let mut dense_std = raw.clone();
            dense_std.standardize_l2();
            let dense_ds = Dataset {
                x: dense_std.into(),
                y: y.clone(),
                groups: groups.clone(),
                response: Response::Linear,
                name: "ooc-bench-dense".into(),
            };
            let dense_fit = PathRunner::new(&dense_ds, cfg.clone())
                .rule(RuleKind::DfrSgl)
                .run()
                .expect("dense fit failed");
            table.push("path seconds", &setting, "dense", dense_fit.metrics.total_seconds);
            table.push("peak design MiB", &setting, "dense", mib(n * p * 8));

            let pack = std::env::temp_dir()
                .join(format!("dfr-bench-{}-{n}x{p}-{rep}.dfrpack", std::process::id()));
            let t0 = std::time::Instant::now();
            let ooc = dfr::linalg::ooc::pack_matrix(&raw, &pack).expect("pack failed");
            table.push("pack seconds", &setting, "ooc", t0.elapsed().as_secs_f64());
            // Free the in-memory copies so VmHWM reflects the streaming fit.
            drop(dense_ds);
            drop(raw);

            let ooc_ds = Dataset {
                x: DesignOps::Ooc(ooc.clone()),
                y,
                groups,
                response: Response::Linear,
                name: "ooc-bench-stream".into(),
            };
            ooc_reset_peak();
            let ooc_fit = PathRunner::new(&ooc_ds, cfg)
                .rule(RuleKind::DfrSgl)
                .fixed_path(dense_fit.lambdas.clone())
                .run()
                .expect("ooc fit failed");
            table.push("path seconds", &setting, "ooc", ooc_fit.metrics.total_seconds);
            table.push("peak design MiB", &setting, "ooc", mib(ooc_peak_resident_bytes()));
            table.push("block cols", &setting, "ooc", ooc.block_cols() as f64);
            table.push(
                "l2 distance ooc vs dense",
                &setting,
                "ooc",
                ooc_fit.l2_distance_to(&dense_fit),
            );
            table.push("process VmHWM MiB", &setting, "ooc", mib(vm_hwm_bytes()));
            let _ = std::fs::remove_file(&pack);
        }
    }
    table.finish("ooc_path");
}
