//! **Figure A6**: robustness of DFR-aSGL to the adaptive-weight exponents
//! γ₁ = γ₂, for linear (left) and logistic (right) models.
//!
//! Paper shape: improvement factor and input proportion are stable across
//! γ ∈ [0, 2] — the screening rule's γ_g/ε'_g machinery absorbs the weight
//! distribution.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::{Response, SyntheticConfig};
use dfr::path::PathConfig;
use dfr::screen::RuleKind;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (300, 100, 15) };
    let gammas: &[f64] = if full { &[0.05, 0.1, 0.5, 1.0, 2.0] } else { &[0.1, 0.5, 2.0] };

    let mut table = BenchTable::new("Fig. A6 — DFR-aSGL robustness in γ₁=γ₂");
    for (resp, tag) in [(Response::Linear, "linear"), (Response::Logistic, "logistic")] {
        for &g in gammas {
            for rep in 0..common::repeats() {
                let data = SyntheticConfig { n, p, response: resp, ..SyntheticConfig::default() }
                    .generate(7000 + rep as u64);
                let cfg = PathConfig {
                    adaptive: Some((g, g)),
                    ..common::bench_path_config(path_len)
                };
                common::run_cell(
                    &mut table,
                    &format!("{tag} γ={g}"),
                    &data.dataset,
                    &cfg,
                    &[RuleKind::DfrAsgl],
                );
            }
        }
    }
    table.finish("figA6_gamma");
}
