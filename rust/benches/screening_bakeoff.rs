//! **Screening bake-off**: every rule (strong + safe, including TLFre)
//! across a (correlation × group-regime × loss) grid, reporting the
//! candidate/optimization proportions, KKT re-entry counts, and wall time
//! per cell — the head-to-head contrast between heuristic rules that pay
//! for KKT repair and safe rules that certify their exclusions.
//!
//! Output lands in `BENCH_screening_bakeoff.json` (schema in
//! `docs/BENCHMARKS.md`). `cargo bench --bench screening_bakeoff` runs the
//! smoke grid CI exercises; `DFR_BENCH_FULL=1` widens it to paper scale.
//!
//! Reading the output: safe rules (TLFre, GAP-safe) must show exactly 0 in
//! the "KKT re-entries" row; strong rules trade nonzero repair rounds for
//! tighter candidate sets. On logistic cells TLFre falls back to no
//! screening (its dual projection is derived for the squared loss), so its
//! input proportion there is 1 — the honest cost of exactness.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::synthetic::GroupSpec;
use dfr::data::{Response, SyntheticConfig};
use dfr::path::PathConfig;

struct Scenario {
    name: &'static str,
    rho: f64,
    groups: GroupSpec,
    response: Response,
}

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (240, 80, 10) };
    let group_small = if full { 5 } else { 4 };
    let group_large = if full { 50 } else { 24 };

    // rule × correlation × group-regime × loss. The logistic leg only
    // varies the group regime at low correlation — the loss contrast, not
    // another full factorial, is what the table needs.
    let scenarios = [
        Scenario {
            name: "linear rho=0.1 small-groups",
            rho: 0.1,
            groups: GroupSpec::Even(group_small),
            response: Response::Linear,
        },
        Scenario {
            name: "linear rho=0.1 large-groups",
            rho: 0.1,
            groups: GroupSpec::Even(group_large),
            response: Response::Linear,
        },
        Scenario {
            name: "linear rho=0.7 small-groups",
            rho: 0.7,
            groups: GroupSpec::Even(group_small),
            response: Response::Linear,
        },
        Scenario {
            name: "linear rho=0.7 large-groups",
            rho: 0.7,
            groups: GroupSpec::Even(group_large),
            response: Response::Linear,
        },
        Scenario {
            name: "logistic rho=0.1 small-groups",
            rho: 0.1,
            groups: GroupSpec::Even(group_small),
            response: Response::Logistic,
        },
        Scenario {
            name: "logistic rho=0.1 large-groups",
            rho: 0.1,
            groups: GroupSpec::Even(group_large),
            response: Response::Logistic,
        },
    ];

    let mut table =
        BenchTable::new("Screening bake-off — rule × correlation × groups × loss");
    for (s_idx, sc) in scenarios.iter().enumerate() {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                rho: sc.rho,
                groups: sc.groups.clone(),
                response: sc.response,
                ..SyntheticConfig::default()
            }
            .generate(7000 + 100 * s_idx as u64 + rep as u64);
            let cfg = PathConfig { ..common::bench_path_config(path_len) };
            common::run_cell(&mut table, sc.name, &data.dataset, &cfg, &common::ALL_RULES);
        }
    }
    table.finish("screening_bakeoff");
}
