//! **Figure 2 + Figure A3 + Tables A5–A10**: improvement factor and input
//! proportion as functions of (left) the data sparsity proportion and
//! (right) the signal strength, linear model.
//!
//! Paper shape: screening pays most under very sparse signals and
//! converges across methods as the signal saturates; DFR is roughly flat
//! in signal strength and always above sparsegl.

mod common;

use dfr::bench_harness::BenchTable;
use dfr::data::SyntheticConfig;

fn main() {
    let full = dfr::bench_harness::full_scale();
    let (p, n, path_len) = if full { (1000, 200, 50) } else { (300, 100, 15) };

    // Left panel: sparsity proportion sweep (active proportion of groups
    // and of variables within active groups).
    let mut t1 = BenchTable::new("Fig. 2 (left) / Tables A5-A7 — sparsity proportion sweep");
    let sparsities: &[f64] = if full { &[0.05, 0.1, 0.2, 0.4, 0.6, 0.8] } else { &[0.1, 0.3, 0.7] };
    for &s in sparsities {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig {
                n,
                p,
                group_sparsity: s,
                var_sparsity: s,
                ..SyntheticConfig::default()
            }
            .generate(2000 + rep as u64);
            common::run_cell(
                &mut t1,
                &format!("sparsity={s}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }
    t1.finish("fig2_sparsity");

    // Right panel: signal strength sweep (β ∼ N(0, signal²)).
    let mut t2 = BenchTable::new("Fig. 2 (right) / Tables A8-A10 — signal strength sweep");
    let signals: &[f64] = if full { &[0.5, 1.0, 2.0, 4.0, 8.0] } else { &[0.5, 2.0, 6.0] };
    for &s in signals {
        for rep in 0..common::repeats() {
            let data = SyntheticConfig { n, p, signal: s, ..SyntheticConfig::default() }
                .generate(3000 + rep as u64);
            common::run_cell(
                &mut t2,
                &format!("signal={s}"),
                &data.dataset,
                &common::bench_path_config(path_len),
                &common::STRONG_RULES,
            );
        }
    }
    t2.finish("fig2_signal");
}
