//! Keyed LRU cache substrate for the serving layer.
//!
//! [`KeyedLru`] is the reusable form of the cache that used to live
//! inline in [`crate::model_api::SglFitter`] as a single `Option<_>`
//! slot: a recency-ordered map from content keys to cached values, with
//! an entry bound *and* an approximate byte bound. It backs
//!
//! * the fitter's prepared-dataset cache (capacity 1 by default, so the
//!   single-owner semantics of the original slot are preserved), and
//! * the multi-tenant caches of [`crate::serve::FitterPool`], where many
//!   tenants share prepared datasets, path fits, and CV cells keyed by
//!   content hashes.
//!
//! Keys are compared with `PartialEq` over a small `Vec` (no hashing):
//! serving caches hold a handful of entries — tens, not thousands — and a
//! linear probe over a dense `Vec` beats a hash map at that size while
//! dodging a `Hash` bound that `f64`-carrying keys cannot meet.
//!
//! Eviction policy: inserting beyond either bound evicts from the
//! least-recently-used end until the cache fits, but never evicts the
//! entry being inserted — a single oversized entry is retained (and the
//! next insert will push it out). Evicted pairs are handed back to the
//! caller so ownership-based accounting (per-tenant eviction counters)
//! stays possible.

/// One cached entry with its approximate size.
struct Slot<K, V> {
    key: K,
    value: V,
    bytes: usize,
}

/// A recency-ordered, doubly-bounded (entries and bytes) keyed cache.
///
/// Recency order is the `Vec` order: index 0 is least-recently used, the
/// last index most-recently used. `get`/`get_mut`/`insert` touch; `peek`
/// does not.
pub struct KeyedLru<K, V> {
    slots: Vec<Slot<K, V>>,
    max_entries: usize,
    max_bytes: usize,
    bytes: usize,
    evictions: u64,
}

impl<K: PartialEq, V> KeyedLru<K, V> {
    /// Cache bounded by `max_entries` (clamped to at least 1) and
    /// `max_bytes` (use `usize::MAX` for entry-bounded only).
    pub fn new(max_entries: usize, max_bytes: usize) -> Self {
        KeyedLru {
            slots: Vec::new(),
            max_entries: max_entries.max(1),
            max_bytes,
            bytes: 0,
            evictions: 0,
        }
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Sum of the `bytes` estimates of every cached entry.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Entry bound this cache was built with.
    pub fn max_entries(&self) -> usize {
        self.max_entries
    }

    /// Byte bound this cache was built with.
    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Number of bound-driven evictions so far (explicit `remove` /
    /// `retain` / `clear` and same-key replacement do not count).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    fn position(&self, key: &K) -> Option<usize> {
        self.slots.iter().position(|s| s.key == *key)
    }

    /// Look up `key`, marking the entry most-recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let i = self.position(key)?;
        let slot = self.slots.remove(i);
        self.slots.push(slot);
        self.slots.last().map(|s| &s.value)
    }

    /// Mutable lookup, marking the entry most-recently used on a hit.
    pub fn get_mut(&mut self, key: &K) -> Option<&mut V> {
        let i = self.position(key)?;
        let slot = self.slots.remove(i);
        self.slots.push(slot);
        self.slots.last_mut().map(|s| &mut s.value)
    }

    /// Recency-neutral lookup (no touch) — usable through `&self`.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.slots.iter().find(|s| s.key == *key).map(|s| &s.value)
    }

    /// Insert (or replace) `key → value` as most-recently used, then
    /// evict LRU entries until both bounds hold. The just-inserted entry
    /// is never evicted. Returns the evicted `(key, value)` pairs,
    /// LRU-first, so callers can attribute them (replacement of the same
    /// key is not an eviction and is not returned).
    pub fn insert(&mut self, key: K, value: V, bytes: usize) -> Vec<(K, V)> {
        if let Some(i) = self.position(&key) {
            let old = self.slots.remove(i);
            self.bytes -= old.bytes;
        }
        self.slots.push(Slot { key, value, bytes });
        self.bytes += bytes;
        let mut evicted = Vec::new();
        while self.slots.len() > 1
            && (self.slots.len() > self.max_entries || self.bytes > self.max_bytes)
        {
            let victim = self.slots.remove(0);
            self.bytes -= victim.bytes;
            self.evictions += 1;
            evicted.push((victim.key, victim.value));
        }
        evicted
    }

    /// Remove one entry by key, returning its value.
    pub fn remove(&mut self, key: &K) -> Option<V> {
        let i = self.position(key)?;
        let slot = self.slots.remove(i);
        self.bytes -= slot.bytes;
        Some(slot.value)
    }

    /// Keep only entries satisfying the predicate; returns how many were
    /// dropped (not counted as LRU evictions).
    pub fn retain(&mut self, mut f: impl FnMut(&K, &V) -> bool) -> usize {
        let before = self.slots.len();
        let mut kept_bytes = 0;
        self.slots.retain(|s| {
            let keep = f(&s.key, &s.value);
            if keep {
                kept_bytes += s.bytes;
            }
            keep
        });
        self.bytes = kept_bytes;
        before - self.slots.len()
    }

    /// Drop everything; returns how many entries were dropped.
    pub fn clear(&mut self) -> usize {
        let n = self.slots.len();
        self.slots.clear();
        self.bytes = 0;
        n
    }

    /// Iterate `(key, value)` pairs in recency order (LRU first).
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.slots.iter().map(|s| (&s.key, &s.value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_and_touch_order() {
        let mut c: KeyedLru<u32, &str> = KeyedLru::new(3, usize::MAX);
        assert!(c.insert(1, "a", 10).is_empty());
        assert!(c.insert(2, "b", 10).is_empty());
        assert!(c.insert(3, "c", 10).is_empty());
        assert_eq!(c.get(&1), Some(&"a")); // 1 becomes MRU; LRU is now 2
        let evicted = c.insert(4, "d", 10);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, 2);
        assert_eq!(c.len(), 3);
        assert_eq!(c.evictions(), 1);
        assert!(c.peek(&2).is_none());
        assert_eq!(c.peek(&1), Some(&"a"));
    }

    #[test]
    fn byte_bound_evicts_lru_first() {
        let mut c: KeyedLru<u32, u32> = KeyedLru::new(100, 100);
        c.insert(1, 1, 40);
        c.insert(2, 2, 40);
        let evicted = c.insert(3, 3, 40); // 120 > 100 → evict key 1
        assert_eq!(evicted.iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![1]);
        assert_eq!(c.bytes(), 80);
        // One oversized entry is retained (never evict the fresh insert).
        let evicted = c.insert(4, 4, 500);
        assert_eq!(evicted.len(), 2);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 500);
        assert_eq!(c.evictions(), 3);
    }

    #[test]
    fn replace_same_key_is_not_eviction() {
        let mut c: KeyedLru<u32, &str> = KeyedLru::new(2, usize::MAX);
        c.insert(1, "a", 5);
        let evicted = c.insert(1, "a2", 7);
        assert!(evicted.is_empty());
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 7);
        assert_eq!(c.evictions(), 0);
        assert_eq!(c.peek(&1), Some(&"a2"));
    }

    #[test]
    fn remove_retain_clear_adjust_bytes() {
        let mut c: KeyedLru<u32, u32> = KeyedLru::new(10, usize::MAX);
        for k in 0..5 {
            c.insert(k, k * k, 10);
        }
        assert_eq!(c.remove(&2), Some(4));
        assert_eq!(c.bytes(), 40);
        let dropped = c.retain(|k, _| *k < 3);
        assert_eq!(dropped, 2); // keys 3, 4
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 20);
        assert_eq!(c.clear(), 2);
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.evictions(), 0, "explicit removal never counts as eviction");
    }

    #[test]
    fn get_mut_touches_and_mutates() {
        let mut c: KeyedLru<u32, Vec<u32>> = KeyedLru::new(2, usize::MAX);
        c.insert(1, vec![1], 1);
        c.insert(2, vec![2], 1);
        if let Some(v) = c.get_mut(&1) {
            v.push(10);
        }
        let evicted = c.insert(3, vec![3], 1);
        assert_eq!(evicted[0].0, 2, "touched key 1 must survive");
        assert_eq!(c.peek(&1), Some(&vec![1, 10]));
    }

    #[test]
    fn capacity_clamped_to_one() {
        let mut c: KeyedLru<u32, u32> = KeyedLru::new(0, usize::MAX);
        assert_eq!(c.max_entries(), 1);
        c.insert(1, 1, 0);
        let evicted = c.insert(2, 2, 0);
        assert_eq!(evicted[0].0, 1);
        assert_eq!(c.len(), 1);
    }
}
