//! Structured input-validation errors for the `Design`/`SglFitter`
//! boundary and the CLI.
//!
//! Every rejection of caller input happens through a [`DfrError`] variant,
//! so callers (the CLI, a serving layer, tests) can match on *what* was
//! wrong instead of parsing a message string. `DfrError` implements
//! [`std::error::Error`], so it flows through the crate's `anyhow::Result`
//! plumbing via `?` unchanged — `downcast_ref::<DfrError>()`-style
//! recovery is not needed because validation happens before any fit work
//! starts.
//!
//! Degraded-but-recoverable conditions (divergence, stalls, screening-cap
//! escalation) are **not** errors: they surface as a
//! [`crate::solver::SolveStatus`] on an otherwise-successful fit. This
//! module is only for inputs that make the optimization problem itself
//! ill-posed.

/// A structured rejection of caller input.
#[derive(Clone, Debug, PartialEq)]
pub enum DfrError {
    /// A design entry is NaN or ±∞.
    NonFiniteDesign { row: usize, col: usize, value: f64 },
    /// A response entry is NaN or ±∞.
    NonFiniteResponse { index: usize, value: f64 },
    /// Every design column is constant: after centering the design is
    /// identically zero and no variable can ever enter the model.
    /// (Individual constant columns are benign — standardization pins
    /// them at zero — so only the all-constant design is rejected.)
    AllColumnsConstant { p: usize },
    /// A dimension disagreement between two inputs (e.g. `y.len() != n`).
    DimensionMismatch { what: &'static str, expected: usize, got: usize },
    /// Group sizes do not tile the coefficient vector.
    GroupMismatch { sum: usize, p: usize },
    /// A group of size zero.
    EmptyGroup { group: usize },
    /// The design has no rows or no columns.
    EmptyDesign { n: usize, p: usize },
    /// The response carries no information: constant `y` for a linear
    /// model, or a single class for a logistic one.
    DegenerateResponse { detail: String },
    /// A scalar hyperparameter violates its constraint (NaN, ∞, sign or
    /// range), e.g. α ∉ [0, 1] or a non-positive tolerance.
    InvalidParameter { name: &'static str, value: f64, constraint: &'static str },
}

impl std::fmt::Display for DfrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DfrError::NonFiniteDesign { row, col, value } => {
                write!(f, "design entry X[{row}, {col}] is not finite ({value})")
            }
            DfrError::NonFiniteResponse { index, value } => {
                write!(f, "response entry y[{index}] is not finite ({value})")
            }
            DfrError::AllColumnsConstant { p } => {
                write!(f, "all {p} design columns are constant (zero variance): no variable can enter the model")
            }
            DfrError::DimensionMismatch { what, expected, got } => {
                write!(f, "dimension mismatch in {what}: expected {expected}, got {got}")
            }
            DfrError::GroupMismatch { sum, p } => {
                write!(f, "group sizes sum to {sum} but the design has {p} columns")
            }
            DfrError::EmptyGroup { group } => {
                write!(f, "group {group} has size 0 (every group needs at least one variable)")
            }
            DfrError::EmptyDesign { n, p } => {
                write!(f, "empty design ({n} rows × {p} columns)")
            }
            DfrError::DegenerateResponse { detail } => {
                write!(f, "degenerate response: {detail}")
            }
            DfrError::InvalidParameter { name, value, constraint } => {
                write!(f, "invalid parameter {name} = {value}: must be {constraint}")
            }
        }
    }
}

impl std::error::Error for DfrError {}

/// Validate a scalar hyperparameter: finite, and within `[lo, hi]`.
pub fn check_range(
    name: &'static str,
    value: f64,
    lo: f64,
    hi: f64,
    constraint: &'static str,
) -> Result<(), DfrError> {
    if !value.is_finite() || value < lo || value > hi {
        return Err(DfrError::InvalidParameter { name, value, constraint });
    }
    Ok(())
}

/// Validate a strictly-positive finite scalar (tolerances, ratios).
pub fn check_positive(name: &'static str, value: f64) -> Result<(), DfrError> {
    if !value.is_finite() || value <= 0.0 {
        return Err(DfrError::InvalidParameter {
            name,
            value,
            constraint: "finite and > 0",
        });
    }
    Ok(())
}

/// Validate a finite non-negative scalar (adaptive γ exponents, λ values).
pub fn check_non_negative(name: &'static str, value: f64) -> Result<(), DfrError> {
    if !value.is_finite() || value < 0.0 {
        return Err(DfrError::InvalidParameter {
            name,
            value,
            constraint: "finite and ≥ 0",
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_name_the_offender() {
        let e = DfrError::NonFiniteDesign { row: 3, col: 7, value: f64::NAN };
        assert!(e.to_string().contains("X[3, 7]"));
        let e = DfrError::InvalidParameter {
            name: "alpha",
            value: 2.0,
            constraint: "in [0, 1]",
        };
        assert!(e.to_string().contains("alpha"));
        assert!(e.to_string().contains("[0, 1]"));
    }

    #[test]
    fn flows_through_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(DfrError::EmptyDesign { n: 0, p: 4 })?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("empty design"));
    }

    #[test]
    fn range_checks() {
        assert!(check_range("alpha", 0.5, 0.0, 1.0, "in [0, 1]").is_ok());
        assert!(check_range("alpha", f64::NAN, 0.0, 1.0, "in [0, 1]").is_err());
        assert!(check_range("alpha", 1.5, 0.0, 1.0, "in [0, 1]").is_err());
        assert!(check_positive("tol", 1e-5).is_ok());
        assert!(check_positive("tol", 0.0).is_err());
        assert!(check_positive("tol", f64::INFINITY).is_err());
        assert!(check_non_negative("gamma", 0.0).is_ok());
        assert!(check_non_negative("gamma", -0.1).is_err());
    }
}
