//! Experiment metrics (§3 and Appendix D.1 of the paper).
//!
//! Per path point we record the cardinalities of the active / candidate /
//! optimization sets at both the variable and group level, KKT violation
//! counts, solver iterations and convergence, and timing. Aggregations
//! reproduce the two headline metrics:
//!
//! * **improvement factor** = no-screen time / screen time,
//! * **input proportion** = `|O_v| / p` (and `|O_g| / m`).

use crate::solver::SolveStatus;

/// Metrics for one λ path point.
#[derive(Clone, Debug, Default)]
pub struct PointMetrics {
    pub lambda: f64,
    /// Active variables / groups at the solution.
    pub a_v: usize,
    pub a_g: usize,
    /// Candidate sets from screening.
    pub c_v: usize,
    pub c_g: usize,
    /// Optimization set actually fed to the solver.
    pub o_v: usize,
    pub o_g: usize,
    /// KKT violations encountered (variables added back).
    pub kkt_violations: usize,
    /// KKT re-entry rounds at this path point: how many times a violation
    /// forced a re-solve (strong rules only — safe rules take the
    /// no-recheck fast path and always record 0; the KKT-cap escalation's
    /// certifying full solve counts as one round).
    pub kkt_rounds: usize,
    /// Final stationarity residual of the accepted solution at this λ
    /// ([`crate::screen::kkt::stationarity_residual`]) — the per-point
    /// optimality certificate the KKT-audit harness asserts on.
    pub kkt_residual: f64,
    pub solver_iterations: usize,
    /// How the solve at this path point concluded (defaults to
    /// [`SolveStatus::Converged`], matching the synthesized null-model
    /// points).
    pub status: SolveStatus,
    /// Wall-clock seconds spent fitting this path point.
    pub fit_seconds: f64,
}

/// Metrics for a whole path fit.
#[derive(Clone, Debug, Default)]
pub struct PathMetrics {
    pub points: Vec<PointMetrics>,
    pub p: usize,
    pub m: usize,
    pub total_seconds: f64,
}

impl PathMetrics {
    /// Mean `|O_v| / p` over the path.
    pub fn input_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.o_v as f64 / self.p as f64))
    }

    /// Mean `|O_g| / m` over the path.
    pub fn group_input_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.o_g as f64 / self.m as f64))
    }

    /// Mean `|C_v| / p` over the path (screened candidate-set size — the
    /// per-cell reduction statistic the CV engine reports).
    pub fn candidate_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.c_v as f64 / self.p as f64))
    }

    /// Mean `|O_v| / |A_v|` (screening efficiency; low is better).
    pub fn ov_over_av(&self) -> f64 {
        mean(
            self.points
                .iter()
                .filter(|pt| pt.a_v > 0)
                .map(|pt| pt.o_v as f64 / pt.a_v as f64),
        )
    }

    /// Total KKT violations across the path.
    pub fn total_kkt_violations(&self) -> usize {
        self.points.iter().map(|pt| pt.kkt_violations).sum()
    }

    /// Total KKT re-entry rounds across the path — zero by construction
    /// for safe rules (`needs_kkt() == false`), the bake-off's headline
    /// contrast with the strong rules.
    pub fn total_kkt_reentries(&self) -> usize {
        self.points.iter().map(|pt| pt.kkt_rounds).sum()
    }

    /// Worst final stationarity residual along the path (0 for an empty
    /// path) — every rule must end every point KKT-clean up to solver
    /// tolerance, which `rust/tests/screening_safety.rs` asserts.
    pub fn max_kkt_residual(&self) -> f64 {
        self.points.iter().fold(0.0f64, |m, pt| m.max(pt.kkt_residual))
    }

    /// Number of path points whose solve did not succeed (anything worse
    /// than a fallback or a KKT-cap escalation that itself converged).
    pub fn failed_convergences(&self) -> usize {
        self.points.iter().filter(|pt| !pt.status.is_success()).count()
    }

    /// Mean solver iterations per path point.
    pub fn mean_iterations(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.solver_iterations as f64))
    }

    /// The worst per-point status along the path — the one-line summary a
    /// caller should act on (see the README troubleshooting table).
    pub fn worst_status(&self) -> SolveStatus {
        self.points
            .iter()
            .fold(SolveStatus::Converged, |s, pt| s.worst(pt.status))
    }
}

/// Online mean/stderr accumulator used by the bench harness and the
/// repeated-simulation reports ("averaged over 100 repeats, with standard
/// errors"). Raw samples are retained so order statistics (median) survive
/// into the machine-readable bench output.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: usize,
    mean: f64,
    m2: f64,
    samples: Vec<f64>,
}

impl Accumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }

    /// `mean ± stderr` formatted like the paper's tables.
    pub fn fmt(&self) -> String {
        format!("{:.3} ± {:.3}", self.mean(), self.stderr())
    }

    /// Median of the pushed samples (0 when empty; midpoint of the two
    /// central order statistics for even counts).
    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        // total_cmp: NaN samples sort high instead of panicking, so a
        // degenerate metric (e.g. a mean over zero points) cannot abort a
        // bench run at serialization time.
        s.sort_by(|a, b| a.total_cmp(b));
        let k = s.len();
        if k % 2 == 1 {
            s[k / 2]
        } else {
            0.5 * (s[k / 2 - 1] + s[k / 2])
        }
    }

    /// The raw samples, in push order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let mut s = 0.0;
    let mut n = 0usize;
    for x in it {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// Improvement factor between a no-screen fit and a screened fit.
pub fn improvement_factor(no_screen_seconds: f64, screen_seconds: f64) -> f64 {
    if screen_seconds <= 0.0 {
        f64::INFINITY
    } else {
        no_screen_seconds / screen_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_mean_and_stderr() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert!((a.mean() - 2.5).abs() < 1e-12);
        // sample sd = sqrt(5/3); stderr = sd/2.
        let sd = (5.0f64 / 3.0).sqrt();
        assert!((a.std_dev() - sd).abs() < 1e-12);
        assert!((a.stderr() - sd / 2.0).abs() < 1e-12);
    }

    #[test]
    fn accumulator_median() {
        let mut a = Accumulator::new();
        assert_eq!(a.median(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            a.push(x);
        }
        assert_eq!(a.median(), 3.0);
        a.push(100.0); // even count → midpoint, robust to the outlier
        assert_eq!(a.median(), 4.0);
        assert_eq!(a.samples(), &[5.0, 1.0, 3.0, 100.0]);
    }

    #[test]
    fn path_metrics_aggregate() {
        let mut pm = PathMetrics { p: 100, m: 10, ..Default::default() };
        pm.points.push(PointMetrics {
            o_v: 20,
            o_g: 2,
            a_v: 10,
            c_v: 10,
            status: SolveStatus::Converged,
            ..Default::default()
        });
        pm.points.push(PointMetrics {
            o_v: 40,
            o_g: 4,
            a_v: 20,
            c_v: 30,
            status: SolveStatus::MaxIters,
            kkt_violations: 3,
            kkt_rounds: 2,
            kkt_residual: 3e-8,
            ..Default::default()
        });
        assert!((pm.input_proportion() - 0.3).abs() < 1e-12);
        assert!((pm.group_input_proportion() - 0.3).abs() < 1e-12);
        assert!((pm.candidate_proportion() - 0.2).abs() < 1e-12);
        assert!((pm.ov_over_av() - 2.0).abs() < 1e-12);
        assert_eq!(pm.total_kkt_violations(), 3);
        assert_eq!(pm.total_kkt_reentries(), 2);
        assert!((pm.max_kkt_residual() - 3e-8).abs() < 1e-20);
        assert_eq!(pm.failed_convergences(), 1);
    }

    #[test]
    fn improvement_factor_ratio() {
        assert_eq!(improvement_factor(10.0, 2.0), 5.0);
        assert!(improvement_factor(1.0, 0.0).is_infinite());
    }
}
