//! Experiment metrics (§3 and Appendix D.1 of the paper).
//!
//! Per path point we record the cardinalities of the active / candidate /
//! optimization sets at both the variable and group level, KKT violation
//! counts, solver iterations and convergence, and timing. Aggregations
//! reproduce the two headline metrics:
//!
//! * **improvement factor** = no-screen time / screen time,
//! * **input proportion** = `|O_v| / p` (and `|O_g| / m`).

use crate::solver::SolveStatus;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Metrics for one λ path point.
#[derive(Clone, Debug, Default)]
pub struct PointMetrics {
    pub lambda: f64,
    /// Active variables / groups at the solution.
    pub a_v: usize,
    pub a_g: usize,
    /// Candidate sets from screening.
    pub c_v: usize,
    pub c_g: usize,
    /// Optimization set actually fed to the solver.
    pub o_v: usize,
    pub o_g: usize,
    /// KKT violations encountered (variables added back).
    pub kkt_violations: usize,
    /// KKT re-entry rounds at this path point: how many times a violation
    /// forced a re-solve (strong rules only — safe rules take the
    /// no-recheck fast path and always record 0; the KKT-cap escalation's
    /// certifying full solve counts as one round).
    pub kkt_rounds: usize,
    /// Final stationarity residual of the accepted solution at this λ
    /// ([`crate::screen::kkt::stationarity_residual`]) — the per-point
    /// optimality certificate the KKT-audit harness asserts on.
    pub kkt_residual: f64,
    pub solver_iterations: usize,
    /// How the solve at this path point concluded (defaults to
    /// [`SolveStatus::Converged`], matching the synthesized null-model
    /// points).
    pub status: SolveStatus,
    /// Wall-clock seconds spent fitting this path point.
    pub fit_seconds: f64,
}

/// Metrics for a whole path fit.
#[derive(Clone, Debug, Default)]
pub struct PathMetrics {
    pub points: Vec<PointMetrics>,
    pub p: usize,
    pub m: usize,
    pub total_seconds: f64,
    /// True when the requested screening rule silently degraded to no
    /// screening for this fit — the safe rules (TLFre, GAP-safe) carry
    /// squared-loss certificates only, so on a logistic response they
    /// return full candidate sets. Surfaced here (and echoed by `dfr
    /// fit`) instead of fitting silently unscreened.
    pub screening_fallback: bool,
}

impl PathMetrics {
    /// Mean `|O_v| / p` over the path.
    pub fn input_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.o_v as f64 / self.p as f64))
    }

    /// Mean `|O_g| / m` over the path.
    pub fn group_input_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.o_g as f64 / self.m as f64))
    }

    /// Mean `|C_v| / p` over the path (screened candidate-set size — the
    /// per-cell reduction statistic the CV engine reports).
    pub fn candidate_proportion(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.c_v as f64 / self.p as f64))
    }

    /// Mean `|O_v| / |A_v|` (screening efficiency; low is better).
    pub fn ov_over_av(&self) -> f64 {
        mean(
            self.points
                .iter()
                .filter(|pt| pt.a_v > 0)
                .map(|pt| pt.o_v as f64 / pt.a_v as f64),
        )
    }

    /// Total KKT violations across the path.
    pub fn total_kkt_violations(&self) -> usize {
        self.points.iter().map(|pt| pt.kkt_violations).sum()
    }

    /// Total KKT re-entry rounds across the path — zero by construction
    /// for safe rules (`needs_kkt() == false`), the bake-off's headline
    /// contrast with the strong rules.
    pub fn total_kkt_reentries(&self) -> usize {
        self.points.iter().map(|pt| pt.kkt_rounds).sum()
    }

    /// Worst final stationarity residual along the path (0 for an empty
    /// path) — every rule must end every point KKT-clean up to solver
    /// tolerance, which `rust/tests/screening_safety.rs` asserts.
    pub fn max_kkt_residual(&self) -> f64 {
        self.points.iter().fold(0.0f64, |m, pt| m.max(pt.kkt_residual))
    }

    /// Number of path points whose solve did not succeed (anything worse
    /// than a fallback or a KKT-cap escalation that itself converged).
    pub fn failed_convergences(&self) -> usize {
        self.points.iter().filter(|pt| !pt.status.is_success()).count()
    }

    /// Mean solver iterations per path point.
    pub fn mean_iterations(&self) -> f64 {
        mean(self.points.iter().map(|pt| pt.solver_iterations as f64))
    }

    /// The worst per-point status along the path — the one-line summary a
    /// caller should act on (see the README troubleshooting table).
    pub fn worst_status(&self) -> SolveStatus {
        self.points
            .iter()
            .fold(SolveStatus::Converged, |s, pt| s.worst(pt.status))
    }
}

/// Online mean/stderr accumulator used by the bench harness and the
/// repeated-simulation reports ("averaged over 100 repeats, with standard
/// errors"). Raw samples are retained so order statistics (median) survive
/// into the machine-readable bench output.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: usize,
    mean: f64,
    m2: f64,
    samples: Vec<f64>,
}

impl Accumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }

    /// `mean ± stderr` formatted like the paper's tables.
    pub fn fmt(&self) -> String {
        format!("{:.3} ± {:.3}", self.mean(), self.stderr())
    }

    /// Median of the pushed samples (0 when empty; midpoint of the two
    /// central order statistics for even counts).
    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        // total_cmp: NaN samples sort high instead of panicking, so a
        // degenerate metric (e.g. a mean over zero points) cannot abort a
        // bench run at serialization time.
        s.sort_by(|a, b| a.total_cmp(b));
        let k = s.len();
        if k % 2 == 1 {
            s[k / 2]
        } else {
            0.5 * (s[k / 2 - 1] + s[k / 2])
        }
    }

    /// The raw samples, in push order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let mut s = 0.0;
    let mut n = 0usize;
    for x in it {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// Number of buckets in a [`LatencyHistogram`]: bucket `i` covers
/// `[2^(i−1), 2^i)` microseconds (bucket 0 is `< 1 µs`), so the top
/// bucket absorbs everything from ~9 minutes up.
pub const LATENCY_BUCKETS: usize = 40;

/// Fixed-bucket latency histogram with lock-free recording — the
/// percentile substrate of the serving layer's per-verb stats.
///
/// Buckets are powers of two in microseconds; recording is one relaxed
/// atomic increment, so many worker threads can record into one shared
/// histogram without coordination, and readers ([`LatencyHistogram::quantile`])
/// need no lock either. Quantiles are bucket upper bounds — exact to
/// within a factor of 2, which is all a p50/p95/p99 dashboard needs.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
    count: AtomicU64,
    total_micros: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            total_micros: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index of a duration: `⌈log₂(µs)⌉`, clamped to the table.
    fn bucket_of(micros: u64) -> usize {
        ((u64::BITS - micros.leading_zeros()) as usize).min(LATENCY_BUCKETS - 1)
    }

    /// Record one observation (relaxed atomics; safe from any thread).
    pub fn record(&self, d: Duration) {
        let micros = u64::try_from(d.as_micros()).unwrap_or(u64::MAX);
        self.buckets[Self::bucket_of(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_micros.fetch_add(micros, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_seconds(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_micros.load(Ordering::Relaxed) as f64 * 1e-6 / n as f64
        }
    }

    /// The `q`-quantile in seconds (upper bound of the bucket holding the
    /// `⌈q·n⌉`-th observation; 0 when empty). `q` is clamped to `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // Bucket i covers [2^(i−1), 2^i) µs; report the upper bound.
                return (1u64 << i.min(63)) as f64 * 1e-6;
            }
        }
        (1u64 << (LATENCY_BUCKETS - 1)) as f64 * 1e-6
    }

    /// Median (bucketed).
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th percentile (bucketed).
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile (bucketed).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Improvement factor between a no-screen fit and a screened fit.
pub fn improvement_factor(no_screen_seconds: f64, screen_seconds: f64) -> f64 {
    if screen_seconds <= 0.0 {
        f64::INFINITY
    } else {
        no_screen_seconds / screen_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_mean_and_stderr() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert!((a.mean() - 2.5).abs() < 1e-12);
        // sample sd = sqrt(5/3); stderr = sd/2.
        let sd = (5.0f64 / 3.0).sqrt();
        assert!((a.std_dev() - sd).abs() < 1e-12);
        assert!((a.stderr() - sd / 2.0).abs() < 1e-12);
    }

    #[test]
    fn accumulator_median() {
        let mut a = Accumulator::new();
        assert_eq!(a.median(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            a.push(x);
        }
        assert_eq!(a.median(), 3.0);
        a.push(100.0); // even count → midpoint, robust to the outlier
        assert_eq!(a.median(), 4.0);
        assert_eq!(a.samples(), &[5.0, 1.0, 3.0, 100.0]);
    }

    #[test]
    fn path_metrics_aggregate() {
        let mut pm = PathMetrics { p: 100, m: 10, ..Default::default() };
        pm.points.push(PointMetrics {
            o_v: 20,
            o_g: 2,
            a_v: 10,
            c_v: 10,
            status: SolveStatus::Converged,
            ..Default::default()
        });
        pm.points.push(PointMetrics {
            o_v: 40,
            o_g: 4,
            a_v: 20,
            c_v: 30,
            status: SolveStatus::MaxIters,
            kkt_violations: 3,
            kkt_rounds: 2,
            kkt_residual: 3e-8,
            ..Default::default()
        });
        assert!((pm.input_proportion() - 0.3).abs() < 1e-12);
        assert!((pm.group_input_proportion() - 0.3).abs() < 1e-12);
        assert!((pm.candidate_proportion() - 0.2).abs() < 1e-12);
        assert!((pm.ov_over_av() - 2.0).abs() < 1e-12);
        assert_eq!(pm.total_kkt_violations(), 3);
        assert_eq!(pm.total_kkt_reentries(), 2);
        assert!((pm.max_kkt_residual() - 3e-8).abs() < 1e-20);
        assert_eq!(pm.failed_convergences(), 1);
    }

    #[test]
    fn improvement_factor_ratio() {
        assert_eq!(improvement_factor(10.0, 2.0), 5.0);
        assert!(improvement_factor(1.0, 0.0).is_infinite());
    }

    #[test]
    fn latency_histogram_quantiles_bucketed() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        // 90 fast observations (~100 µs) and 10 slow (~50 ms).
        for _ in 0..90 {
            h.record(Duration::from_micros(100));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(50));
        }
        assert_eq!(h.count(), 100);
        // p50 lands in the [64, 128) µs bucket → upper bound 128 µs.
        assert!((h.p50() - 128e-6).abs() < 1e-12, "p50 = {}", h.p50());
        // p95/p99 land in the [32.768, 65.536) ms bucket.
        assert!((h.p95() - 65.536e-3).abs() < 1e-9, "p95 = {}", h.p95());
        assert!((h.p99() - 65.536e-3).abs() < 1e-9);
        let mean = h.mean_seconds();
        assert!(mean > 100e-6 && mean < 50e-3, "mean = {mean}");
    }

    #[test]
    fn latency_histogram_concurrent_records() {
        let h = LatencyHistogram::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..250 {
                        h.record(Duration::from_micros(10));
                    }
                });
            }
        });
        assert_eq!(h.count(), 1000);
        assert!((h.p99() - 16e-6).abs() < 1e-12); // [8, 16) µs bucket
    }

    #[test]
    fn screening_fallback_flag_defaults_false() {
        let pm = PathMetrics::default();
        assert!(!pm.screening_fallback);
    }
}
