//! Runtime-dispatched compute backends for the dense kernel layer.
//!
//! One binary runs everywhere: the crate ships a [`scalar`] reference
//! backend (the exact historical kernels — `DFR_KERNEL=scalar` is the
//! bit-stability anchor), an AVX2+FMA backend (`x86_64` only, detected
//! once via `is_x86_feature_detected!`), and a NEON backend (`aarch64`
//! only — NEON is baseline on AArch64, so it is unconditionally
//! available there). The choice can be pinned three ways, in priority
//! order:
//!
//! 1. [`set_backend_override`] — programmatic (tests, benches);
//! 2. `DFR_KERNEL=auto|scalar|avx2|neon` — environment (read once,
//!    cached);
//! 3. auto-detection — the fastest backend the CPU supports.
//!
//! Requesting an unavailable backend (e.g. `avx2` on a machine without
//! it) degrades to `scalar` rather than failing: the dispatch layer is a
//! performance knob, never a correctness switch. All entry points come in
//! a dispatched form (`dot`, `axpy`, …) and an explicit-backend form
//! (`dot_with`, …) so equivalence tests can compare backends directly.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
pub(crate) mod scalar;

/// A compute backend for the dense vector kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference kernels — bitwise identical to the pre-dispatch
    /// implementations on every platform.
    Scalar,
    /// `std::arch` AVX2 + FMA intrinsics (`x86_64` with runtime support).
    Avx2,
    /// `std::arch` NEON intrinsics (`aarch64`, where NEON is baseline).
    Neon,
}

impl Backend {
    /// Lower-case display/parse name (`scalar` / `avx2` / `neon`).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Whether this backend can run on the current CPU (checked once and
    /// cached; `Scalar` is always available).
    pub fn is_available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Avx2 => avx2_ok(),
            Backend::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Clamp to an available backend (unavailable requests degrade to
    /// [`Backend::Scalar`]).
    #[inline]
    fn effective(self) -> Backend {
        if self.is_available() {
            self
        } else {
            Backend::Scalar
        }
    }
}

fn avx2_ok() -> bool {
    static OK: OnceLock<bool> = OnceLock::new();
    *OK.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Backends the current CPU can actually run, fastest last.
pub fn available() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    if Backend::Neon.is_available() {
        v.push(Backend::Neon);
    }
    if Backend::Avx2.is_available() {
        v.push(Backend::Avx2);
    }
    v
}

/// The fastest available backend (what `auto` resolves to).
pub fn best_available() -> Backend {
    if Backend::Avx2.is_available() {
        Backend::Avx2
    } else if Backend::Neon.is_available() {
        Backend::Neon
    } else {
        Backend::Scalar
    }
}

/// Parse a `DFR_KERNEL`-style choice: `auto` (or empty) means "detect",
/// a backend name pins it. Anything else is an error.
pub fn parse_choice(s: &str) -> Result<Option<Backend>, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(None),
        "scalar" => Ok(Some(Backend::Scalar)),
        "avx2" => Ok(Some(Backend::Avx2)),
        "neon" => Ok(Some(Backend::Neon)),
        other => {
            Err(format!("unknown kernel backend `{other}` (expected auto|scalar|avx2|neon)"))
        }
    }
}

/// Process-wide programmatic backend override (0 = unset; otherwise the
/// backend discriminant + 1). Mirrors `parallel::set_thread_override`.
static BACKEND_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Pin the dispatched backend programmatically (tests and benches; wins
/// over `DFR_KERNEL`). `None` restores env/auto selection. Pinning a
/// backend the CPU lacks degrades to scalar at dispatch time.
pub fn set_backend_override(b: Option<Backend>) {
    let code = match b {
        None => 0,
        Some(Backend::Scalar) => 1,
        Some(Backend::Avx2) => 2,
        Some(Backend::Neon) => 3,
    };
    BACKEND_OVERRIDE.store(code, Ordering::Relaxed);
}

/// The `DFR_KERNEL` choice, read and parsed once per process (invalid
/// values are treated as `auto` — the env knob degrades, never aborts).
fn env_choice() -> Option<Backend> {
    static ENV: OnceLock<Option<Backend>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DFR_KERNEL").ok().and_then(|v| parse_choice(&v).ok().flatten())
    })
}

/// The backend the dispatched kernels will run on right now:
/// programmatic override, then `DFR_KERNEL`, then auto-detection —
/// always clamped to what the CPU supports.
#[inline]
pub fn active() -> Backend {
    match BACKEND_OVERRIDE.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Avx2.effective(),
        3 => Backend::Neon.effective(),
        _ => match env_choice() {
            Some(b) => b.effective(),
            None => best_available(),
        },
    }
}

/// One-line description for CLI headers and bench JSON: the active
/// backend plus how it was chosen, e.g. `avx2 (auto)` or
/// `scalar (DFR_KERNEL)`.
pub fn describe() -> String {
    let source = match BACKEND_OVERRIDE.load(Ordering::Relaxed) {
        1 | 2 | 3 => "pinned",
        _ => match env_choice() {
            Some(_) => "DFR_KERNEL",
            None => "auto",
        },
    };
    format!("{} ({source})", active().name())
}

/// Dot product on an explicit backend.
#[inline]
pub fn dot_with(backend: Backend, a: &[f64], b: &[f64]) -> f64 {
    match backend.effective() {
        Backend::Scalar => scalar::dot(a, b),
        // SAFETY: `effective()` only yields `Avx2` after `is_available`
        // verified avx2+fma on this CPU.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::dot(a, b),
        // SAFETY: NEON is baseline on aarch64; `effective()` clamps the
        // variant away everywhere else.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot(a, b) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::dot(a, b),
    }
}

/// `y += a·x` on an explicit backend.
#[inline]
pub fn axpy_with(backend: Backend, a: f64, x: &[f64], y: &mut [f64]) {
    match backend.effective() {
        Backend::Scalar => scalar::axpy(a, x, y),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::axpy(a, x, y) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::axpy(a, x, y),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::axpy(a, x, y) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::axpy(a, x, y),
    }
}

/// ℓ₁ norm on an explicit backend.
#[inline]
pub fn norm1_with(backend: Backend, x: &[f64]) -> f64 {
    match backend.effective() {
        Backend::Scalar => scalar::norm1(x),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::norm1(x) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::norm1(x),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::norm1(x) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::norm1(x),
    }
}

/// ℓ∞ norm on an explicit backend.
#[inline]
pub fn norm_inf_with(backend: Backend, x: &[f64]) -> f64 {
    match backend.effective() {
        Backend::Scalar => scalar::norm_inf(x),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::norm_inf(x) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::norm_inf(x),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::norm_inf(x) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::norm_inf(x),
    }
}

/// Four dots against one shared `r` on an explicit backend. Per-lane
/// results are bitwise equal to [`dot_with`] on the same backend — the
/// invariant that makes register blocking transparent to chunk layout.
#[inline]
pub fn dot4_with(
    backend: Backend,
    c0: &[f64],
    c1: &[f64],
    c2: &[f64],
    c3: &[f64],
    r: &[f64],
) -> [f64; 4] {
    match backend.effective() {
        Backend::Scalar => scalar::dot4(c0, c1, c2, c3, r),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot4(c0, c1, c2, c3, r) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::dot4(c0, c1, c2, c3, r),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot4(c0, c1, c2, c3, r) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::dot4(c0, c1, c2, c3, r),
    }
}

/// Four accumulated axpys on an explicit backend; bitwise equal to four
/// sequential [`axpy_with`] calls on the same backend.
#[inline]
pub fn axpy4_with(
    backend: Backend,
    a: [f64; 4],
    x0: &[f64],
    x1: &[f64],
    x2: &[f64],
    x3: &[f64],
    y: &mut [f64],
) {
    match backend.effective() {
        Backend::Scalar => scalar::axpy4(a, x0, x1, x2, x3, y),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::axpy4(a, x0, x1, x2, x3, y) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => scalar::axpy4(a, x0, x1, x2, x3, y),
        // SAFETY: see `dot_with`.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::axpy4(a, x0, x1, x2, x3, y) },
        #[cfg(not(target_arch = "aarch64"))]
        Backend::Neon => scalar::axpy4(a, x0, x1, x2, x3, y),
    }
}

/// Indexed gather `dst[k] = src[idx[k]]` on an explicit backend — the
/// sparse-design and fold-split copy kernel (AVX2 uses hardware
/// `vgatherdpd`; scalar/NEON run an unrolled unchecked loop).
///
/// # Safety
///
/// Every `idx[k]` must be `< src.len()` and `idx.len() == dst.len()`;
/// callers bounds-check once up front so the per-element loop doesn't.
#[inline]
pub unsafe fn gather_with(backend: Backend, src: &[f64], idx: &[usize], dst: &mut [f64]) {
    debug_assert_eq!(idx.len(), dst.len());
    debug_assert!(idx.iter().all(|&i| i < src.len()));
    match backend.effective() {
        // SAFETY: forwarded contract — caller guarantees index bounds.
        Backend::Scalar => unsafe { scalar::gather(src, idx, dst) },
        // SAFETY: `effective()` verified avx2+fma; index bounds forwarded.
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::gather(src, idx, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Avx2 => unsafe { scalar::gather(src, idx, dst) },
        // NEON has no gather instruction; the unrolled scalar loop is the
        // fastest portable form on aarch64 too.
        Backend::Neon => unsafe { scalar::gather(src, idx, dst) },
    }
}

/// Indexed gather on the [`active`] backend (see [`gather_with`]).
///
/// # Safety
///
/// Same contract as [`gather_with`].
#[inline]
pub unsafe fn gather(src: &[f64], idx: &[usize], dst: &mut [f64]) {
    unsafe { gather_with(active(), src, idx, dst) }
}

/// Dot product on the [`active`] backend.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_with(active(), a, b)
}

/// `y += a·x` on the [`active`] backend.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    axpy_with(active(), a, x, y)
}

/// ℓ₁ norm on the [`active`] backend.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    norm1_with(active(), x)
}

/// ℓ∞ norm on the [`active`] backend.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    norm_inf_with(active(), x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = crate::rng::Rng::new(seed);
        (rng.gauss_vec(n), rng.gauss_vec(n))
    }

    #[test]
    fn parse_choice_accepts_the_documented_values() {
        assert_eq!(parse_choice("auto"), Ok(None));
        assert_eq!(parse_choice(""), Ok(None));
        assert_eq!(parse_choice("Scalar"), Ok(Some(Backend::Scalar)));
        assert_eq!(parse_choice(" AVX2 "), Ok(Some(Backend::Avx2)));
        assert!(parse_choice("sse9").is_err());
    }

    #[test]
    fn scalar_is_always_available_and_listed_first() {
        assert!(Backend::Scalar.is_available());
        let avail = available();
        assert_eq!(avail[0], Backend::Scalar);
        assert!(avail.contains(&best_available()));
        assert!(avail.contains(&active()), "active backend must be runnable");
    }

    #[test]
    fn every_available_backend_matches_scalar_within_tolerance() {
        // No override flips here: unit tests share one process with the
        // rest of the crate's tests, so we compare through the explicit
        // `_with` entry points only.
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 33, 100, 257] {
            let (a, b) = vecs(n, 40 + n as u64);
            let want_dot = scalar::dot(&a, &b);
            let want_n1 = scalar::norm1(&a);
            let want_ninf = scalar::norm_inf(&a);
            for bk in available() {
                let tol = 1e-12 * (1.0 + n as f64);
                assert!((dot_with(bk, &a, &b) - want_dot).abs() <= tol, "dot n={n} {bk:?}");
                assert!((norm1_with(bk, &a) - want_n1).abs() <= tol, "norm1 n={n} {bk:?}");
                assert!(
                    (norm_inf_with(bk, &a) - want_ninf).abs() <= tol,
                    "norm_inf n={n} {bk:?}"
                );
                let mut y = b.clone();
                axpy_with(bk, 0.7, &a, &mut y);
                for i in 0..n {
                    assert!((y[i] - (b[i] + 0.7 * a[i])).abs() <= 1e-12, "axpy n={n} {bk:?}");
                }
            }
        }
    }

    #[test]
    fn fused_lanes_match_their_unfused_kernels_bitwise() {
        // The blocking invariant: dot4 lane k ≡ dot(c_k, r) and axpy4 ≡
        // four sequential axpys, exactly, on every available backend.
        for n in [0usize, 1, 3, 4, 6, 8, 11, 64, 129] {
            let mut rng = crate::rng::Rng::new(70 + n as u64);
            let cols: Vec<Vec<f64>> = (0..4).map(|_| rng.gauss_vec(n)).collect();
            let r = rng.gauss_vec(n);
            let coef = [0.3, -1.2, 0.0, 2.5];
            for bk in available() {
                let fused = dot4_with(bk, &cols[0], &cols[1], &cols[2], &cols[3], &r);
                for k in 0..4 {
                    let lone = dot_with(bk, &cols[k], &r);
                    assert_eq!(fused[k].to_bits(), lone.to_bits(), "dot4 n={n} k={k} {bk:?}");
                }
                let mut y_fused = r.clone();
                axpy4_with(bk, coef, &cols[0], &cols[1], &cols[2], &cols[3], &mut y_fused);
                let mut y_seq = r.clone();
                for k in 0..4 {
                    axpy_with(bk, coef[k], &cols[k], &mut y_seq);
                }
                for i in 0..n {
                    assert_eq!(
                        y_fused[i].to_bits(),
                        y_seq[i].to_bits(),
                        "axpy4 n={n} i={i} {bk:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn gather_matches_indexed_copy_on_every_backend() {
        for n in [0usize, 1, 3, 4, 5, 8, 17, 100] {
            let mut rng = crate::rng::Rng::new(90 + n as u64);
            let src = rng.gauss_vec(n.max(1) * 2);
            let idx: Vec<usize> =
                (0..n).map(|k| (k * 7 + 3) % src.len()).collect();
            let want: Vec<f64> = idx.iter().map(|&i| src[i]).collect();
            for bk in available() {
                let mut dst = vec![0.0; n];
                // SAFETY: idx was built modulo src.len().
                unsafe { gather_with(bk, &src, &idx, &mut dst) };
                for k in 0..n {
                    assert_eq!(dst[k].to_bits(), want[k].to_bits(), "gather n={n} k={k} {bk:?}");
                }
            }
        }
    }

    #[test]
    fn describe_names_a_runnable_backend() {
        let d = describe();
        assert!(d.starts_with(active().name()), "{d}");
    }
}
