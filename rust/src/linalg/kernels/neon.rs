//! NEON backend (`aarch64` only, where NEON/ASIMD is baseline).
//!
//! Same structural rule as the AVX2 backend, scaled to 128-bit vectors: a
//! reduction walks four elements per step through **two** 2-lane
//! `float64x2_t` FMA accumulators (positions `4k`/`4k+1` in the first,
//! `4k+2`/`4k+3` in the second), is reduced as
//! `vaddvq(acc0) + vaddvq(acc1)`, and finishes with a *sequential scalar*
//! remainder loop. [`dot`] and each lane of [`dot4`] share that exact
//! structure, so per-column results stay bitwise independent of block
//! grouping and thread chunking — the invariant the
//! `kernel_equivalence` fused-lane pins assert on every available
//! backend, this one included.
//!
//! FMA contraction makes these results differ from the scalar backend in
//! the last ulps; the dispatched ≡ scalar gates (ℓ₂ ≤ 1e-12) bound the
//! drift exactly as they do for AVX2.

use core::arch::aarch64::*;

/// Dot product: two FMA accumulators + scalar remainder.
///
/// # Safety
/// aarch64 only (NEON is baseline there); behind `Backend::Neon` dispatch.
#[target_feature(enable = "neon")]
pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f64(0.0);
    let mut acc1 = vdupq_n_f64(0.0);
    for k in 0..chunks {
        let i = 4 * k;
        acc0 = vfmaq_f64(acc0, vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i)));
        acc1 = vfmaq_f64(acc1, vld1q_f64(ap.add(i + 2)), vld1q_f64(bp.add(i + 2)));
    }
    let mut s = vaddvq_f64(acc0) + vaddvq_f64(acc1);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Four dot products against one shared right-hand side, with `r` loaded
/// once per 4-row step. Each lane is structurally identical to [`dot`]
/// (own accumulator pair, same reduce, same scalar remainder), so
/// `dot4(..)[k] == dot(c_k, r)` bitwise.
///
/// # Safety
/// See [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], r: &[f64]) -> [f64; 4] {
    let n = r.len();
    debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
    let chunks = n / 4;
    let (p0, p1, p2, p3, pr) = (c0.as_ptr(), c1.as_ptr(), c2.as_ptr(), c3.as_ptr(), r.as_ptr());
    let mut a = [vdupq_n_f64(0.0); 8];
    for k in 0..chunks {
        let i = 4 * k;
        let rlo = vld1q_f64(pr.add(i));
        let rhi = vld1q_f64(pr.add(i + 2));
        a[0] = vfmaq_f64(a[0], vld1q_f64(p0.add(i)), rlo);
        a[1] = vfmaq_f64(a[1], vld1q_f64(p0.add(i + 2)), rhi);
        a[2] = vfmaq_f64(a[2], vld1q_f64(p1.add(i)), rlo);
        a[3] = vfmaq_f64(a[3], vld1q_f64(p1.add(i + 2)), rhi);
        a[4] = vfmaq_f64(a[4], vld1q_f64(p2.add(i)), rlo);
        a[5] = vfmaq_f64(a[5], vld1q_f64(p2.add(i + 2)), rhi);
        a[6] = vfmaq_f64(a[6], vld1q_f64(p3.add(i)), rlo);
        a[7] = vfmaq_f64(a[7], vld1q_f64(p3.add(i + 2)), rhi);
    }
    let mut s = [
        vaddvq_f64(a[0]) + vaddvq_f64(a[1]),
        vaddvq_f64(a[2]) + vaddvq_f64(a[3]),
        vaddvq_f64(a[4]) + vaddvq_f64(a[5]),
        vaddvq_f64(a[6]) + vaddvq_f64(a[7]),
    ];
    for i in 4 * chunks..n {
        s[0] += c0[i] * r[i];
        s[1] += c1[i] * r[i];
        s[2] += c2[i] * r[i];
        s[3] += c3[i] * r[i];
    }
    s
}

/// `y += a * x`: FMA main loop, scalar mul+add remainder.
///
/// # Safety
/// See [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let va = vdupq_n_f64(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        vst1q_f64(yp.add(i), vfmaq_f64(vld1q_f64(yp.add(i)), vld1q_f64(xp.add(i)), va));
        vst1q_f64(
            yp.add(i + 2),
            vfmaq_f64(vld1q_f64(yp.add(i + 2)), vld1q_f64(xp.add(i + 2)), va),
        );
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// Four accumulated axpys with `y` loaded and stored once per 4-row step,
/// FMAs chained in lane order so the result is bitwise identical to four
/// sequential [`axpy`] calls (elementwise ops don't care about the
/// 2-lane vector width; the remainder applies the same four separate
/// mul+adds per element).
///
/// # Safety
/// See [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn axpy4(a: [f64; 4], x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    let chunks = n / 4;
    let (va0, va1, va2, va3) =
        (vdupq_n_f64(a[0]), vdupq_n_f64(a[1]), vdupq_n_f64(a[2]), vdupq_n_f64(a[3]));
    let (p0, p1, p2, p3) = (x0.as_ptr(), x1.as_ptr(), x2.as_ptr(), x3.as_ptr());
    let yp = y.as_mut_ptr();
    for k in 0..chunks {
        for half in [4 * k, 4 * k + 2] {
            let mut vy = vld1q_f64(yp.add(half));
            vy = vfmaq_f64(vy, vld1q_f64(p0.add(half)), va0);
            vy = vfmaq_f64(vy, vld1q_f64(p1.add(half)), va1);
            vy = vfmaq_f64(vy, vld1q_f64(p2.add(half)), va2);
            vy = vfmaq_f64(vy, vld1q_f64(p3.add(half)), va3);
            vst1q_f64(yp.add(half), vy);
        }
    }
    for i in 4 * chunks..n {
        y[i] += a[0] * x0[i];
        y[i] += a[1] * x1[i];
        y[i] += a[2] * x2[i];
        y[i] += a[3] * x3[i];
    }
}

/// ℓ₁ norm: two |v| add-accumulators + scalar remainder.
///
/// # Safety
/// See [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn norm1(x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f64(0.0);
    let mut acc1 = vdupq_n_f64(0.0);
    for k in 0..chunks {
        let i = 4 * k;
        acc0 = vaddq_f64(acc0, vabsq_f64(vld1q_f64(xp.add(i))));
        acc1 = vaddq_f64(acc1, vabsq_f64(vld1q_f64(xp.add(i + 2))));
    }
    let mut s = vaddvq_f64(acc0) + vaddvq_f64(acc1);
    for v in &x[4 * chunks..] {
        s += v.abs();
    }
    s
}

/// ℓ∞ norm: two max-of-|v| accumulators + scalar remainder.
///
/// # Safety
/// See [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn norm_inf(x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let mut acc0 = vdupq_n_f64(0.0);
    let mut acc1 = vdupq_n_f64(0.0);
    for k in 0..chunks {
        let i = 4 * k;
        acc0 = vmaxq_f64(acc0, vabsq_f64(vld1q_f64(xp.add(i))));
        acc1 = vmaxq_f64(acc1, vabsq_f64(vld1q_f64(xp.add(i + 2))));
    }
    let mut m = vmaxvq_f64(vmaxq_f64(acc0, acc1));
    for v in &x[4 * chunks..] {
        m = m.max(v.abs());
    }
    m
}
