//! Scalar reference backend — the exact historical kernels.
//!
//! These are byte-for-byte the implementations `linalg.rs` shipped before
//! the dispatch layer existed: same accumulator structure, same remainder
//! handling, same reduction order. That is a *contract*, not an accident —
//! `DFR_KERNEL=scalar` must reproduce the pre-dispatch results bit for bit
//! (pinned by `rust/tests/kernel_equivalence.rs`), so any change here is a
//! numerics change for every solver, screening rule, and serving path.
//!
//! The 4-accumulator `dot` is written so LLVM can auto-vectorize without
//! needing `-ffast-math`-style reassociation permission; on machines
//! without AVX2 it is also the fastest portable form we have.

/// Dot product with 4 independent accumulators, reduced as
/// `(s0 + s1) + (s2 + s3)` with a sequential scalar remainder.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x`, one fused multiply-add-free pass (plain mul + add).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// ℓ₁ norm — sequential `|v|` sum.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm — sequential `max(|v|)` fold from 0.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Four simultaneous dot products against one shared right-hand side.
///
/// Each lane is *exactly* [`dot`] — same accumulators, same reduction —
/// so `dot4(..)[k] == dot(c_k, r)` bitwise. The fused form exists for the
/// register-blocked dense kernels; the scalar backend never takes those
/// paths, but the dispatch layer still needs a total implementation.
#[inline]
pub fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], r: &[f64]) -> [f64; 4] {
    [dot(c0, r), dot(c1, r), dot(c2, r), dot(c3, r)]
}

/// Four accumulated axpys `y += Σ_k a[k]·x_k`, applied in lane order so the
/// result is bitwise identical to four sequential [`axpy`] calls.
#[inline]
pub fn axpy4(a: [f64; 4], x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64], y: &mut [f64]) {
    axpy(a[0], x0, y);
    axpy(a[1], x1, y);
    axpy(a[2], x2, y);
    axpy(a[3], x3, y);
}

/// Indexed gather `dst[k] = src[idx[k]]`, 4-unrolled with unchecked
/// source reads (the bounds check lives at the caller, once, instead of
/// per element — that check is what made the old per-column copy loops
/// scalar-bound).
///
/// # Safety
/// Every `idx[k]` must be `< src.len()` and `idx.len() == dst.len()`.
#[inline]
pub unsafe fn gather(src: &[f64], idx: &[usize], dst: &mut [f64]) {
    debug_assert_eq!(idx.len(), dst.len());
    let n = idx.len();
    let chunks = n / 4;
    for k in 0..chunks {
        let i = 4 * k;
        // SAFETY: caller guarantees every index is in range for `src`,
        // and `i + 3 < n` holds for both `idx` and `dst` by construction.
        unsafe {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(*idx.get_unchecked(i));
            *dst.get_unchecked_mut(i + 1) = *src.get_unchecked(*idx.get_unchecked(i + 1));
            *dst.get_unchecked_mut(i + 2) = *src.get_unchecked(*idx.get_unchecked(i + 2));
            *dst.get_unchecked_mut(i + 3) = *src.get_unchecked(*idx.get_unchecked(i + 3));
        }
    }
    for i in 4 * chunks..n {
        // SAFETY: same contract as above.
        unsafe {
            *dst.get_unchecked_mut(i) = *src.get_unchecked(*idx.get_unchecked(i));
        }
    }
}
