//! AVX2 + FMA backend (`x86_64` only, selected at runtime).
//!
//! Every kernel here shares one structural rule: a reduction is a single
//! 4-lane `__m256d` accumulator advanced with FMA, horizontally summed as
//! `(l0 + l2) + (l1 + l3)`, followed by a *sequential scalar* remainder
//! loop. Because [`dot`] and each lane of [`dot4`] use that identical
//! structure, per-column results are bitwise independent of how columns
//! are grouped into blocks or split across thread chunks — which is what
//! keeps the "parallel ≡ serial" exactness tests meaningful on this
//! backend too.
//!
//! FMA contraction means these results differ from the scalar backend in
//! the last ulps; that cross-backend drift is bounded by the dispatched ≡
//! scalar gates in `rust/tests/kernel_equivalence.rs` (ℓ₂ ≤ 1e-12) and by
//! the existing solver/screening equivalence suites.

use core::arch::x86_64::*;

/// Horizontal sum of a 4-lane accumulator as `(l0 + l2) + (l1 + l3)`.
///
/// # Safety
/// Caller must have verified `avx2` support at runtime.
#[target_feature(enable = "avx2")]
unsafe fn hsum(v: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(v);
    let hi = _mm256_extractf128_pd(v, 1);
    let pair = _mm_add_pd(lo, hi); // [l0 + l2, l1 + l3]
    let swapped = _mm_unpackhi_pd(pair, pair);
    _mm_cvtsd_f64(_mm_add_sd(pair, swapped))
}

/// Dot product: one FMA accumulator + scalar remainder.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` support at runtime.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        let va = _mm256_loadu_pd(ap.add(i));
        let vb = _mm256_loadu_pd(bp.add(i));
        acc = _mm256_fmadd_pd(va, vb, acc);
    }
    let mut s = hsum(acc);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Four dot products against one shared right-hand side, with `r` loaded
/// once per 4-row step. Each lane is structurally identical to [`dot`]
/// (own accumulator, same hsum, same scalar remainder), so
/// `dot4(..)[k] == dot(c_k, r)` bitwise.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` support at runtime.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], r: &[f64]) -> [f64; 4] {
    let n = r.len();
    debug_assert!(c0.len() == n && c1.len() == n && c2.len() == n && c3.len() == n);
    let chunks = n / 4;
    let (p0, p1, p2, p3, pr) = (c0.as_ptr(), c1.as_ptr(), c2.as_ptr(), c3.as_ptr(), r.as_ptr());
    let mut a0 = _mm256_setzero_pd();
    let mut a1 = _mm256_setzero_pd();
    let mut a2 = _mm256_setzero_pd();
    let mut a3 = _mm256_setzero_pd();
    for k in 0..chunks {
        let i = 4 * k;
        let vr = _mm256_loadu_pd(pr.add(i));
        a0 = _mm256_fmadd_pd(_mm256_loadu_pd(p0.add(i)), vr, a0);
        a1 = _mm256_fmadd_pd(_mm256_loadu_pd(p1.add(i)), vr, a1);
        a2 = _mm256_fmadd_pd(_mm256_loadu_pd(p2.add(i)), vr, a2);
        a3 = _mm256_fmadd_pd(_mm256_loadu_pd(p3.add(i)), vr, a3);
    }
    let mut s = [hsum(a0), hsum(a1), hsum(a2), hsum(a3)];
    for i in 4 * chunks..n {
        s[0] += c0[i] * r[i];
        s[1] += c1[i] * r[i];
        s[2] += c2[i] * r[i];
        s[3] += c3[i] * r[i];
    }
    s
}

/// `y += a * x`: FMA main loop, scalar mul+add remainder.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` support at runtime.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let va = _mm256_set1_pd(a);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let vx = _mm256_loadu_pd(xp.add(i));
        let vy = _mm256_loadu_pd(yp.add(i));
        _mm256_storeu_pd(yp.add(i), _mm256_fmadd_pd(va, vx, vy));
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// Four accumulated axpys with `y` loaded and stored once per 4-row step:
/// `y += a0·x0 + a1·x1 + a2·x2 + a3·x3`, chained in lane order so the
/// result is bitwise identical to four sequential [`axpy`] calls (the
/// vector body chains FMAs in the same order; the remainder applies the
/// same four mul+adds per element).
///
/// # Safety
/// Caller must have verified `avx2` and `fma` support at runtime.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy4(a: [f64; 4], x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    let chunks = n / 4;
    let (va0, va1, va2, va3) =
        (_mm256_set1_pd(a[0]), _mm256_set1_pd(a[1]), _mm256_set1_pd(a[2]), _mm256_set1_pd(a[3]));
    let (p0, p1, p2, p3) = (x0.as_ptr(), x1.as_ptr(), x2.as_ptr(), x3.as_ptr());
    let yp = y.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let mut vy = _mm256_loadu_pd(yp.add(i));
        vy = _mm256_fmadd_pd(va0, _mm256_loadu_pd(p0.add(i)), vy);
        vy = _mm256_fmadd_pd(va1, _mm256_loadu_pd(p1.add(i)), vy);
        vy = _mm256_fmadd_pd(va2, _mm256_loadu_pd(p2.add(i)), vy);
        vy = _mm256_fmadd_pd(va3, _mm256_loadu_pd(p3.add(i)), vy);
        _mm256_storeu_pd(yp.add(i), vy);
    }
    for i in 4 * chunks..n {
        y[i] += a[0] * x0[i];
        y[i] += a[1] * x1[i];
        y[i] += a[2] * x2[i];
        y[i] += a[3] * x3[i];
    }
}

/// Indexed gather `dst[k] = src[idx[k]]` through the hardware
/// `vgatherqpd` instruction (4 indices loaded as one `__m256i`, scale 8),
/// with an unchecked scalar remainder.
///
/// # Safety
/// Caller must have verified `avx2` support at runtime; every `idx[k]`
/// must be `< src.len()` and `idx.len() == dst.len()`. (usize is 64-bit
/// on every `x86_64` target, so indices load directly as i64 lanes.)
#[target_feature(enable = "avx2")]
pub unsafe fn gather(src: &[f64], idx: &[usize], dst: &mut [f64]) {
    debug_assert_eq!(idx.len(), dst.len());
    let n = idx.len();
    let chunks = n / 4;
    let base = src.as_ptr();
    let ip = idx.as_ptr();
    let dp = dst.as_mut_ptr();
    for k in 0..chunks {
        let i = 4 * k;
        let vi = _mm256_loadu_si256(ip.add(i).cast());
        _mm256_storeu_pd(dp.add(i), _mm256_i64gather_pd::<8>(base, vi));
    }
    for i in 4 * chunks..n {
        *dp.add(i) = *base.add(*ip.add(i));
    }
}

/// ℓ₁ norm: 4-lane |v| accumulator + scalar remainder.
///
/// # Safety
/// Caller must have verified `avx2` support at runtime.
#[target_feature(enable = "avx2")]
pub unsafe fn norm1(x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    // Clearing the sign bit is |v| for every f64 including ±0 and ±inf.
    let abs_mask = _mm256_castsi256_pd(_mm256_set1_epi64x(0x7fff_ffff_ffff_ffff));
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let v = _mm256_and_pd(_mm256_loadu_pd(xp.add(4 * k)), abs_mask);
        acc = _mm256_add_pd(acc, v);
    }
    let mut s = hsum(acc);
    for v in &x[4 * chunks..] {
        s += v.abs();
    }
    s
}

/// ℓ∞ norm: 4-lane max-of-|v| accumulator + scalar remainder.
///
/// # Safety
/// Caller must have verified `avx2` support at runtime.
#[target_feature(enable = "avx2")]
pub unsafe fn norm_inf(x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let abs_mask = _mm256_castsi256_pd(_mm256_set1_epi64x(0x7fff_ffff_ffff_ffff));
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for k in 0..chunks {
        let v = _mm256_and_pd(_mm256_loadu_pd(xp.add(4 * k)), abs_mask);
        acc = _mm256_max_pd(acc, v);
    }
    let lo = _mm256_castpd256_pd128(acc);
    let hi = _mm256_extractf128_pd(acc, 1);
    let pair = _mm_max_pd(lo, hi);
    let swapped = _mm_unpackhi_pd(pair, pair);
    let mut m = _mm_cvtsd_f64(_mm_max_sd(pair, swapped));
    for v in &x[4 * chunks..] {
        m = m.max(v.abs());
    }
    m
}
