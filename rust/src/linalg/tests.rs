use super::*;

fn small() -> Matrix {
    // [[1, 4], [2, 5], [3, 6]]
    Matrix::from_columns(3, &[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
}

#[test]
fn matvec_matches_hand_computation() {
    let m = small();
    assert_eq!(m.matvec(&[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
}

#[test]
fn t_matvec_matches_hand_computation() {
    let m = small();
    assert_eq!(m.t_matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
}

#[test]
fn parallel_t_matvec_matches_serial() {
    let mut rng = crate::rng::Rng::new(1);
    let m = Matrix::from_fn(37, 501, |_, _| rng.gauss());
    let r = rng.gauss_vec(37);
    let a = m.t_matvec(&r);
    let b = m.t_matvec_par(&r, 4);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-12);
    }
}

#[test]
fn gather_columns_picks_right_columns() {
    let m = small();
    let g = m.gather_columns(&[1]);
    assert_eq!(g.ncols(), 1);
    assert_eq!(g.col(0), &[4.0, 5.0, 6.0]);
}

#[test]
fn parallel_t_matvec_into_matches_allocating_form() {
    let mut rng = crate::rng::Rng::new(5);
    let m = Matrix::from_fn(23, 301, |_, _| rng.gauss());
    let r = rng.gauss_vec(23);
    let a = m.t_matvec_par(&r, 3);
    let mut b = vec![1.0; 301]; // non-zero garbage: must be overwritten
    m.t_matvec_par_into(&r, 3, &mut b);
    assert_eq!(a, b);
}

#[test]
fn truncate_and_push_cols_roundtrip() {
    let mut m = small();
    m.truncate_cols(1);
    assert_eq!(m.ncols(), 1);
    assert_eq!(m.col(0), &[1.0, 2.0, 3.0]);
    m.push_col(&[7.0, 8.0, 9.0]);
    assert_eq!(m.ncols(), 2);
    assert_eq!(m.col(1), &[7.0, 8.0, 9.0]);
}

#[test]
fn reduced_design_matches_fresh_gather() {
    let mut rng = crate::rng::Rng::new(6);
    let x = Matrix::from_fn(11, 14, |_, _| rng.gauss());
    let mut rd = ReducedDesign::new();
    for idx in [
        vec![1usize, 3, 5],
        vec![1, 3, 6, 7],    // shares the [1, 3] prefix
        vec![1, 3, 6, 7],    // identical → cache hit
        vec![0, 3, 6],       // no shared prefix → rebuild
        vec![0, 3, 6, 9, 12], // append-only growth
    ] {
        let got = rd.update(&x, &idx).as_dense().unwrap().clone();
        assert_eq!(got, x.gather_columns(&idx), "idx {idx:?}");
        assert_eq!(rd.indices(), idx.as_slice());
    }
    assert_eq!(rd.hits, 1);
    assert!(rd.kept_cols >= 2, "prefix reuse never happened");
}

#[test]
fn reduced_design_detects_matrix_change() {
    let mut rng = crate::rng::Rng::new(7);
    let a = Matrix::from_fn(9, 6, |_, _| rng.gauss());
    let b = Matrix::from_fn(9, 6, |_, _| rng.gauss());
    let mut rd = ReducedDesign::new();
    rd.update(&a, &[0, 2, 4]);
    let got = rd.update(&b, &[0, 2, 4]).as_dense().unwrap().clone();
    assert_eq!(got, b.gather_columns(&[0, 2, 4]), "stale columns served");
}

#[test]
fn reduced_design_update_grouped_records_offsets() {
    let mut rng = crate::rng::Rng::new(8);
    let x = Matrix::from_fn(9, 10, |_, _| rng.gauss());
    let groups = crate::groups::Groups::from_sizes(&[3, 3, 4]); // 0-2 | 3-5 | 6-9
    let mut rd = ReducedDesign::new();
    // vars {1, 2} ⊂ g0, {4} ⊂ g1, {6, 9} ⊂ g2 → blocks at 0, 2, 3.
    rd.update_grouped(&x, &[1, 2, 4, 6, 9], &groups);
    assert_eq!(rd.group_offsets(), &[0, 2, 3, 5]);
    let (restricted, _) = groups.restrict(&[1, 2, 4, 6, 9]);
    assert_eq!(rd.group_offsets(), restricted.offsets());
    // Incremental growth keeps the offsets in sync with the new set.
    rd.update_grouped(&x, &[1, 2, 4, 5, 6, 9], &groups);
    assert_eq!(rd.group_offsets(), &[0, 2, 4, 6]);
}

#[test]
fn block_kernels_match_whole_design_kernels() {
    let mut rng = crate::rng::Rng::new(9);
    let x = Matrix::from_fn(12, 9, |_, _| rng.gauss());
    let cols = 3..7usize;
    let coeffs = rng.gauss_vec(4);
    let r = rng.gauss_vec(12);

    // block_axpy == matvec of a vector supported on the block.
    let mut full_beta = vec![0.0; 9];
    full_beta[cols.clone()].copy_from_slice(&coeffs);
    let expect = x.matvec(&full_beta);
    let mut got = vec![0.0; 12];
    x.block_axpy_into(cols.clone(), &coeffs, &mut got);
    for (a, b) in got.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-14);
    }

    // block_t_matvec == the block slice of Xᵀr.
    let full = x.t_matvec(&r);
    let mut block = vec![0.0; 4];
    x.block_t_matvec_into(cols.clone(), &r, &mut block);
    for (a, b) in block.iter().zip(&full[cols]) {
        assert!((a - b).abs() < 1e-14);
    }

    // col_sq_norms == col_norms².
    let mut sq = vec![0.0; 9];
    x.col_sq_norms_into(&mut sq);
    for (a, b) in sq.iter().zip(&x.col_norms()) {
        assert!((a - b * b).abs() < 1e-12);
    }
}

#[test]
fn sparse_block_kernels_match_dense_block_kernels() {
    let (dense, csc) = sparse_fixture();
    let sparse = CenteredSparse::from_csc(&csc);
    let dense_std = sparse.to_dense(); // implied standardized matrix
    let mut rng = crate::rng::Rng::new(10);
    let cols = 2..6usize;
    let coeffs = rng.gauss_vec(4);
    let r = rng.gauss_vec(dense.nrows());
    let n = dense.nrows();

    let mut a = rng.gauss_vec(n); // nonzero accumulator: += semantics
    let mut b = a.clone();
    dense_std.block_axpy_into(cols.clone(), &coeffs, &mut a);
    sparse.block_axpy_into(cols.clone(), &coeffs, &mut b);
    for (x1, x2) in a.iter().zip(&b) {
        assert!((x1 - x2).abs() < 1e-12, "block_axpy drift");
    }

    let mut da = vec![0.0; 4];
    let mut db = vec![0.0; 4];
    dense_std.block_t_matvec_into(cols.clone(), &r, &mut da);
    sparse.block_t_matvec_into(cols.clone(), &r, &mut db);
    for (x1, x2) in da.iter().zip(&db) {
        assert!((x1 - x2).abs() < 1e-12, "block_t_matvec drift");
    }

    let mut sa = vec![0.0; dense.ncols()];
    let mut sb = vec![0.0; dense.ncols()];
    dense_std.col_sq_norms_into(&mut sa);
    sparse.col_sq_norms_into(&mut sb);
    for (x1, x2) in sa.iter().zip(&sb) {
        assert!((x1 - x2).abs() < 1e-12, "col_sq_norms drift");
    }
}

#[test]
fn gather_rows_picks_right_rows() {
    let m = small();
    let g = m.gather_rows(&[2, 0]);
    assert_eq!(g.get(0, 0), 3.0);
    assert_eq!(g.get(1, 1), 4.0);
}

#[test]
fn standardize_gives_zero_mean_unit_norm() {
    let mut rng = crate::rng::Rng::new(2);
    let mut m = Matrix::from_fn(50, 10, |_, _| rng.normal(3.0, 2.0));
    m.standardize_l2();
    for j in 0..10 {
        let c = m.col(j);
        let mean: f64 = c.iter().sum::<f64>() / 50.0;
        assert!(mean.abs() < 1e-12);
        assert!((norm2(c) - 1.0).abs() < 1e-12);
    }
}

#[test]
fn op_norm_est_close_to_true_on_diagonal_case() {
    // X = diag-ish: columns orthogonal with norms 1, 2, 3 → ‖X‖₂² = 9.
    let mut m = Matrix::zeros(3, 3);
    m.set(0, 0, 1.0);
    m.set(1, 1, 2.0);
    m.set(2, 2, 3.0);
    let est = m.op_norm_sq_est(50, 7);
    assert!((est - 9.0).abs() < 1e-6, "est {est}");
}

fn sparse_fixture() -> (Matrix, CscMatrix) {
    // Sparse-ish matrix with exact zeros, a dense column, and an
    // all-zero column.
    let mut rng = crate::rng::Rng::new(11);
    let dense = Matrix::from_fn(13, 7, |i, j| {
        if j == 3 {
            rng.gauss() // fully dense column
        } else if j == 5 {
            0.0 // empty column
        } else if (i + j) % 3 == 0 {
            rng.gauss()
        } else {
            0.0
        }
    });
    let csc = CscMatrix::from_dense(&dense, 0.0);
    (dense, csc)
}

#[test]
fn csc_round_trips_through_dense() {
    let (dense, csc) = sparse_fixture();
    assert_eq!(csc.to_dense(), dense);
    assert!(csc.nnz() < 13 * 7);
    assert!((csc.density() - csc.nnz() as f64 / 91.0).abs() < 1e-15);
}

#[test]
fn csc_matvec_and_t_matvec_match_dense() {
    let (dense, csc) = sparse_fixture();
    let mut rng = crate::rng::Rng::new(12);
    let beta = rng.gauss_vec(7);
    let r = rng.gauss_vec(13);
    for (a, b) in csc.matvec(&beta).iter().zip(&dense.matvec(&beta)) {
        assert!((a - b).abs() < 1e-14);
    }
    for (a, b) in csc.t_matvec(&r).iter().zip(&dense.t_matvec(&r)) {
        assert!((a - b).abs() < 1e-14);
    }
}

#[test]
fn csc_col_stats_match_dense() {
    let (dense, csc) = sparse_fixture();
    for (a, b) in csc.col_norms().iter().zip(&dense.col_norms()) {
        assert!((a - b).abs() < 1e-12);
    }
    for (j, m) in csc.col_means().iter().enumerate() {
        let want = dense.col(j).iter().sum::<f64>() / 13.0;
        assert!((m - want).abs() < 1e-12);
    }
}

#[test]
fn csc_standardized_dense_matches_dense_standardization() {
    let (dense, csc) = sparse_fixture();
    let mut want = dense.clone();
    let want_stats = want.standardize_l2();
    let (got, got_stats) = csc.to_standardized_dense();
    for j in 0..7 {
        let (wm, ws) = want_stats[j];
        let (gm, gs) = got_stats[j];
        assert!((wm - gm).abs() < 1e-12, "col {j} mean");
        assert!((ws - gs).abs() < 1e-12, "col {j} scale");
        for i in 0..13 {
            assert!(
                (want.get(i, j) - got.get(i, j)).abs() < 1e-12,
                "entry ({i}, {j})"
            );
        }
    }
}

#[test]
fn csc_fingerprint_distinguishes_content_and_structure() {
    let (_, csc) = sparse_fixture();
    let fp = csc.fingerprint();
    let mut other = csc.clone();
    // Perturb one stored value: the fingerprint must move.
    let perturbed = CscMatrix::new(
        other.nrows(),
        other.ncols(),
        other.col_ptr.clone(),
        other.row_idx.clone(),
        {
            other.values[0] += 1.0;
            other.values.clone()
        },
    );
    assert_ne!(fp, perturbed.fingerprint());
}

#[test]
#[should_panic(expected = "strictly increasing")]
fn csc_rejects_unsorted_rows() {
    CscMatrix::new(3, 1, vec![0, 2], vec![1, 0], vec![1.0, 2.0]);
}

#[test]
fn csc_from_dense_preserves_nan() {
    let mut m = Matrix::zeros(3, 2);
    m.set(1, 0, f64::NAN);
    m.set(2, 1, 5.0);
    let csc = CscMatrix::from_dense(&m, 0.0);
    assert_eq!(csc.nnz(), 2, "NaN entry must be stored, not dropped");
    assert!(csc.to_dense().get(1, 0).is_nan());
}

#[test]
fn centered_sparse_kernels_match_dense_standardized() {
    let (_, csc) = sparse_fixture();
    let cs = CenteredSparse::from_csc(&csc);
    let (dense_std, stats) = csc.to_standardized_dense();
    assert_eq!(cs.centers(), stats);
    let mut rng = crate::rng::Rng::new(21);
    let beta = rng.gauss_vec(7);
    let r = rng.gauss_vec(13);
    for (a, b) in cs.matvec(&beta).iter().zip(&dense_std.matvec(&beta)) {
        assert!((a - b).abs() < 1e-12, "matvec {a} vs {b}");
    }
    for (a, b) in cs.t_matvec(&r).iter().zip(&dense_std.t_matvec(&r)) {
        assert!((a - b).abs() < 1e-12, "t_matvec {a} vs {b}");
    }
    let mut par = vec![9.0; 7];
    cs.t_matvec_par_into(&r, 3, &mut par);
    for (a, b) in par.iter().zip(&cs.t_matvec(&r)) {
        assert!((a - b).abs() < 1e-14, "par t_matvec");
    }
    for (a, b) in cs.col_norms().iter().zip(&dense_std.col_norms()) {
        assert!((a - b).abs() < 1e-12, "col norm {a} vs {b}");
    }
    for m in cs.col_means() {
        assert!(m.abs() < 1e-12, "implied mean {m}");
    }
    let (est_s, est_d) = (cs.op_norm_sq_est(60, 7), dense_std.op_norm_sq_est(60, 7));
    assert!((est_s - est_d).abs() < 1e-6 * (1.0 + est_d), "{est_s} vs {est_d}");
}

#[test]
fn centered_sparse_gather_rows_matches_dense() {
    let (_, csc) = sparse_fixture();
    let cs = CenteredSparse::from_csc(&csc);
    let dense_std = cs.to_dense();
    for rows in [vec![0usize, 3, 7, 12], vec![5, 1, 1, 9]] {
        let got = cs.gather_rows(&rows).to_dense();
        let want = dense_std.gather_rows(&rows);
        for j in 0..7 {
            for i in 0..rows.len() {
                assert!(
                    (got.get(i, j) - want.get(i, j)).abs() < 1e-12,
                    "rows {rows:?}, entry ({i}, {j})"
                );
            }
        }
    }
}

#[test]
fn centered_sparse_restandardize_matches_dense() {
    // Gather fold rows, then re-standardize: the sparse affine
    // recomposition must track the dense two-pass standardization of
    // the same implied rows (the CV fold-plan contract).
    let (_, csc) = sparse_fixture();
    let cs = CenteredSparse::from_csc(&csc);
    let rows: Vec<usize> = (0..13).filter(|i| i % 3 != 0).collect();
    let mut sub_sparse = cs.gather_rows(&rows);
    let mut sub_dense = cs.to_dense().gather_rows(&rows);
    let got_centers = sub_sparse.standardize_l2();
    let want_centers = sub_dense.standardize_l2();
    for j in 0..7 {
        let ((gm, gs), (wm, ws)) = (got_centers[j], want_centers[j]);
        assert!((gm - wm).abs() < 1e-10, "col {j} mean {gm} vs {wm}");
        assert!((gs - ws).abs() < 1e-10, "col {j} scale {gs} vs {ws}");
    }
    let got = sub_sparse.to_dense();
    for j in 0..7 {
        for i in 0..rows.len() {
            assert!(
                (got.get(i, j) - sub_dense.get(i, j)).abs() < 1e-10,
                "entry ({i}, {j})"
            );
        }
    }
}

#[test]
fn reduced_design_serves_sparse_sources() {
    let (_, csc) = sparse_fixture();
    let cs = CenteredSparse::from_csc(&csc);
    let dense_std = cs.to_dense();
    let mut rd = ReducedDesign::new();
    for idx in [
        vec![0usize, 2, 4],
        vec![0, 2, 5, 6], // shares the [0, 2] prefix
        vec![0, 2, 5, 6], // identical → cache hit
        vec![1, 3],       // no shared prefix → rebuild
    ] {
        let got = match rd.update(&cs, &idx) {
            DesignRef::Sparse(s) => s.to_dense(),
            DesignRef::Dense(_) => panic!("sparse source produced a dense gather"),
        };
        let want = dense_std.gather_columns(&idx);
        assert_eq!(got, want, "idx {idx:?}");
        assert_eq!(rd.indices(), idx.as_slice());
    }
    assert_eq!(rd.hits, 1);
    assert!(rd.kept_cols >= 2, "sparse prefix reuse never happened");
    // Switching to a dense source invalidates and serves dense.
    let got = rd.update(&dense_std, &[1, 3]).as_dense().unwrap().clone();
    assert_eq!(got, dense_std.gather_columns(&[1, 3]));
}

#[test]
fn dense_materialization_counter_ticks_on_densify_only() {
    let (_, csc) = sparse_fixture();
    let cs = CenteredSparse::from_csc(&csc);
    let before = dense_materializations();
    let mut out = vec![0.0; 13];
    cs.matvec_into(&[0.1; 7], &mut out);
    cs.t_matvec(&[0.1; 13]);
    cs.col_norms();
    assert_eq!(dense_materializations(), before, "kernels must not densify");
    let _ = cs.to_dense();
    let _ = csc.to_standardized_dense();
    assert_eq!(dense_materializations(), before + 2);
}

#[test]
fn dot_handles_remainders() {
    let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
    assert_eq!(dot(&a, &a), 91.0);
}

#[test]
fn l2_distance_zero_iff_equal() {
    let a = [1.0, 2.0];
    assert_eq!(l2_distance(&a, &a), 0.0);
    assert!((l2_distance(&a, &[1.0, 4.0]) - 2.0).abs() < 1e-15);
}
