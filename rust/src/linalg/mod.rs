//! Dense linear-algebra substrate.
//!
//! No BLAS binding is available offline, so the crate carries its own
//! column-major dense matrix with the handful of kernels the pathwise SGL
//! stack needs: `Xᵀr` (gradient), `Xβ` (predictions), column gathers (for
//! screening-reduced designs), Gram products and standardization.
//!
//! The vector primitives live in [`kernels`], behind runtime CPU-feature
//! dispatch: a scalar reference backend (bitwise identical to the
//! pre-dispatch kernels — pin it with `DFR_KERNEL=scalar`), an AVX2+FMA
//! backend selected automatically on `x86_64`, and a NEON backend on
//! `aarch64`. On the SIMD backends the dense matvecs are additionally
//! register-blocked (four columns per pass over a row tile, so `r`/`out`
//! traffic amortizes over the column loads) and both `Xβ` and `Xᵀr` can
//! fan out over a thread scope; the scalar backend keeps the exact
//! historical loop structure so existing results are reproducible bit
//! for bit.
//!
//! Designs too large for RAM live in the [`ooc`] module: a chunk-file-
//! backed column-major store streamed in fixed column blocks, the third
//! variant of the [`DesignRef`]/[`DesignOps`] kernel contract.

use crate::parallel;

pub mod kernels;
pub mod ooc;
#[cfg(test)]
mod tests;

pub use ooc::{
    ooc_peak_resident_bytes, ooc_reset_peak, ooc_resident_bytes, set_ooc_block_override,
    OocDesign,
};

use kernels::Backend;

/// Row-tile length of the blocked dense `Xβ` scatter: the `out` tile
/// (8 KiB) stays resident in L1 while every active column streams over it
/// once per 4-column block.
const ROW_TILE: usize = 1024;

/// Column-major dense matrix of `f64`.
///
/// Column-major is the natural layout for pathwise screening: the gradient
/// `Xᵀr` is one contiguous dot product per column, and gathering the
/// optimization set into a reduced design is a set of `memcpy`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    p: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix with `n` rows and `p` columns.
    pub fn zeros(n: usize, p: usize) -> Self {
        Matrix { n, p, data: vec![0.0; n * p] }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(n: usize, p: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                m.data[j * n + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data (length must be `n * p`).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major data length mismatch");
        Matrix { n, p, data }
    }

    /// Build from a list of columns, each of length `n`.
    pub fn from_columns(n: usize, cols: &[Vec<f64>]) -> Self {
        let p = cols.len();
        let mut data = Vec::with_capacity(n * p);
        for c in cols {
            assert_eq!(c.len(), n);
            data.extend_from_slice(c);
        }
        Matrix { n, p, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Raw column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.p);
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = X β`, reusing the output buffer (hot-loop form).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        self.matvec_rows_into(0..self.n, beta, out);
    }

    /// `out = X β` fanned out over row chunks — each worker owns a
    /// disjoint slice of `out`, so no accumulator races. Per-row results
    /// see the columns in the same order as the serial form (on the
    /// scalar backend they are bitwise identical to it).
    pub fn matvec_par_into(&self, beta: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        if threads <= 1 || self.n * self.p < parallel::par_grain() {
            self.matvec_rows_into(0..self.n, beta, out);
            return;
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            self.matvec_rows_into(start..start + chunk.len(), beta, chunk);
        });
    }

    /// Blocked `Xβ` scatter over a row range (`out.len() == rows.len()`).
    ///
    /// Scalar backend: the historical serial column-axpy loop, restricted
    /// to the row window — bit-stable at any chunking. SIMD backend:
    /// row-tiled 4-column register blocks ([`ROW_TILE`]), flushing
    /// remainder columns with single axpys.
    fn matvec_rows_into(&self, rows: std::ops::Range<usize>, beta: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), rows.len());
        out.fill(0.0);
        let backend = kernels::active();
        if backend == Backend::Scalar {
            for (j, &b) in beta.iter().enumerate() {
                if b != 0.0 {
                    kernels::scalar::axpy(b, &self.col(j)[rows.clone()], out);
                }
            }
            return;
        }
        let mut tile_start = 0;
        while tile_start < out.len() {
            let tile_end = (tile_start + ROW_TILE).min(out.len());
            let (lo, hi) = (rows.start + tile_start, rows.start + tile_end);
            let tile = &mut out[tile_start..tile_end];
            let mut pend_j = [0usize; 4];
            let mut pend_c = [0.0f64; 4];
            let mut pending = 0;
            for (j, &b) in beta.iter().enumerate() {
                if b == 0.0 {
                    continue;
                }
                pend_j[pending] = j;
                pend_c[pending] = b;
                pending += 1;
                if pending == 4 {
                    kernels::axpy4_with(
                        backend,
                        pend_c,
                        &self.col(pend_j[0])[lo..hi],
                        &self.col(pend_j[1])[lo..hi],
                        &self.col(pend_j[2])[lo..hi],
                        &self.col(pend_j[3])[lo..hi],
                        tile,
                    );
                    pending = 0;
                }
            }
            for t in 0..pending {
                kernels::axpy_with(backend, pend_c[t], &self.col(pend_j[t])[lo..hi], tile);
            }
            tile_start = tile_end;
        }
    }

    /// `g = Xᵀ r` (length p). Single-threaded.
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = Xᵀ r`, reusing the output buffer.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        self.t_matvec_cols_into(0, r, out);
    }

    /// `out[k] = X[:, first + k]ᵀ r` for `out.len()` consecutive columns.
    ///
    /// Scalar backend: the historical per-column dot loop. SIMD backend:
    /// four columns per pass over `r` ([`kernels::dot4_with`]), whose
    /// lanes are bitwise identical to single dots — so results do not
    /// depend on how a caller chunks the column range (serial, parallel,
    /// or block-sliced all agree exactly).
    fn t_matvec_cols_into(&self, first: usize, r: &[f64], out: &mut [f64]) {
        let backend = kernels::active();
        if backend == Backend::Scalar {
            for (k, o) in out.iter_mut().enumerate() {
                *o = kernels::scalar::dot(self.col(first + k), r);
            }
            return;
        }
        let len = out.len();
        let mut k = 0;
        while k + 4 <= len {
            let j = first + k;
            let d = kernels::dot4_with(
                backend,
                self.col(j),
                self.col(j + 1),
                self.col(j + 2),
                self.col(j + 3),
                r,
            );
            out[k..k + 4].copy_from_slice(&d);
            k += 4;
        }
        for (kk, o) in out.iter_mut().enumerate().skip(k) {
            *o = kernels::dot_with(backend, self.col(first + kk), r);
        }
    }

    /// `Xᵀ r` fanned out across a thread scope — the gradient hot path
    /// for large `p`.
    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_par_into(r, threads, &mut out);
        out
    }

    /// `out = Xᵀ r` fanned out across a thread scope, reusing the output
    /// buffer (the allocation-free hot-loop form).
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        // Scoped-thread spawn costs ~50–100 µs per worker and the matvec
        // is memory-bandwidth bound, so threading only breaks even once
        // the matrix itself is far larger than L2 (measured in
        // benches/perf_hotpath.rs — see EXPERIMENTS.md §Perf). The
        // break-even point is the shared `DFR_PAR_GRAIN` tunable.
        if threads <= 1 || self.n * self.p < parallel::par_grain() {
            self.t_matvec_into(r, out);
            return;
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            self.t_matvec_cols_into(start, r, chunk);
        });
    }

    /// `out += Σ_k coeffs[k] · X[:, cols.start + k]` — the group-block
    /// matvec `X_g β_g` accumulated into a carried fitted-values buffer
    /// (the BCD residual-carried block update). Zero coefficients are
    /// skipped, so updating an inactive block costs nothing.
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(coeffs.len(), cols.len());
        debug_assert_eq!(out.len(), self.n);
        let backend = kernels::active();
        if backend == Backend::Scalar {
            for (k, &c) in coeffs.iter().enumerate() {
                if c != 0.0 {
                    kernels::scalar::axpy(c, self.col(cols.start + k), out);
                }
            }
            return;
        }
        // 4-column register blocks over the nonzero coefficients; `out`
        // is loaded/stored once per block instead of once per column.
        let mut pend_j = [0usize; 4];
        let mut pend_c = [0.0f64; 4];
        let mut pending = 0;
        for (k, &c) in coeffs.iter().enumerate() {
            if c == 0.0 {
                continue;
            }
            pend_j[pending] = cols.start + k;
            pend_c[pending] = c;
            pending += 1;
            if pending == 4 {
                kernels::axpy4_with(
                    backend,
                    pend_c,
                    self.col(pend_j[0]),
                    self.col(pend_j[1]),
                    self.col(pend_j[2]),
                    self.col(pend_j[3]),
                    out,
                );
                pending = 0;
            }
        }
        for t in 0..pending {
            kernels::axpy_with(backend, pend_c[t], self.col(pend_j[t]), out);
        }
    }

    /// `out[k] = X[:, cols.start + k]ᵀ r` — the group-block transpose
    /// matvec `X_gᵀ r`, written into the block slice of a gradient buffer.
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), cols.len());
        debug_assert_eq!(r.len(), self.n);
        self.t_matvec_cols_into(cols.start, r, out);
    }

    /// [`Matrix::block_t_matvec_into`] with a caller-carried residual sum.
    /// The dense kernels never need `Σᵢrᵢ`, so `_rsum` is ignored — the
    /// parameter exists so the [`DesignRef`] contract can hand the carried
    /// sum to the centered-sparse kernels without a variant branch at
    /// every call site.
    pub fn block_t_matvec_with_rsum_into(
        &self,
        cols: std::ops::Range<usize>,
        r: &[f64],
        _rsum: f64,
        out: &mut [f64],
    ) {
        self.block_t_matvec_into(cols, r, out);
    }

    /// Squared ℓ₂ norm of every column, written into `out` (length p) —
    /// the per-column cache behind the BCD block-Lipschitz seeds.
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.p);
        let backend = kernels::active();
        for (j, o) in out.iter_mut().enumerate() {
            let c = self.col(j);
            *o = kernels::dot_with(backend, c, c);
        }
    }

    /// Gather the given columns into a new (n × idx.len()) matrix — used to
    /// build the screening-reduced design for the inner solver. Pathwise
    /// callers should prefer [`ReducedDesign`], which reuses its backing
    /// buffer and diffs consecutive index sets.
    pub fn gather_columns(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(self.n * idx.len());
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        Matrix { n: self.n, p: idx.len(), data }
    }

    /// Drop all but the first `k` columns in place (capacity is retained,
    /// so subsequent [`Matrix::push_col`] calls do not reallocate).
    pub fn truncate_cols(&mut self, k: usize) {
        assert!(k <= self.p, "truncate_cols past the end");
        self.data.truncate(self.n * k);
        self.p = k;
    }

    /// Append one column (length must be `n`).
    pub fn push_col(&mut self, col: &[f64]) {
        assert_eq!(col.len(), self.n);
        self.data.extend_from_slice(col);
        self.p += 1;
    }

    /// Reserve backing storage for `extra` additional columns.
    pub fn reserve_cols(&mut self, extra: usize) {
        self.data.reserve(self.n * extra);
    }

    /// ℓ₂ norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| norm2(self.col(j))).collect()
    }

    /// Spectral-norm upper bound via `max_j ‖X e_j‖₂ · √p` is far too loose;
    /// instead run a few power iterations on `XᵀX` to estimate `‖X‖₂²`,
    /// which upper-bounds the gradient Lipschitz constant of the squared
    /// loss (divided by n). One shared implementation serves every kernel
    /// variant ([`DesignRef::op_norm_sq_est`]), so the dense and sparse
    /// Lipschitz estimates can never drift apart algorithmically.
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        DesignRef::Dense(self).op_norm_sq_est(iters, seed)
    }

    /// Center each column to mean zero and scale to unit ℓ₂ norm (the
    /// paper's "ℓ₂ standardization"). Returns per-column (mean, norm) so
    /// coefficients can be mapped back to the original scale. Constant
    /// columns get norm 1 (they stay zero after centering).
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n;
        (0..self.p)
            .map(|j| {
                let col = self.col_mut(j);
                let mean = col.iter().sum::<f64>() / n as f64;
                col.iter_mut().for_each(|x| *x -= mean);
                let nrm = norm2(col);
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                col.iter_mut().for_each(|x| *x /= scale);
                (mean, scale)
            })
            .collect()
    }

    /// Horizontal concatenation (same row count).
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { n: self.n, p: self.p + other.p, data }
    }

    /// Select a subset of rows (used by the CV fold splitter). The
    /// per-column copies run on the dispatched [`kernels::gather`]; one
    /// upfront bounds check covers every column.
    pub fn gather_rows(&self, rows: &[usize]) -> Matrix {
        assert!(rows.iter().all(|&i| i < self.n), "gather_rows: row index out of range");
        let backend = kernels::active();
        let mut m = Matrix::zeros(rows.len(), self.p);
        for j in 0..self.p {
            let src = self.col(j);
            let dst = m.col_mut(j);
            // SAFETY: every index in `rows` was bounds-checked above.
            unsafe { kernels::gather_with(backend, src, rows, dst) };
        }
        m
    }
}

/// Compressed-sparse-column matrix of `f64` — the input format for sparse
/// designs (genotype dosage matrices, one-hot expansions) accepted by the
/// model API's `Design::Csc` variant.
///
/// Storage is the classic CSC triplet: `col_ptr` (length `p + 1`) delimits
/// each column's slice of `row_idx`/`values`. Row indices are strictly
/// increasing within a column. The pathwise solver stack runs on the dense
/// [`Matrix`] (ℓ₂ standardization destroys sparsity anyway — centering
/// fills every zero), so the sparse type's job is (a) sparse-aware
/// `matvec`/`t_matvec`/`col_norms` for prediction and screening-style
/// passes over *raw* designs, and (b) one-pass standardization straight
/// into a dense standardized matrix, computing the per-column (mean, norm)
/// from the nonzeros alone — no intermediate dense unstandardized copy.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n: usize,
    p: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from raw CSC parts. Validates shape invariants (monotone
    /// `col_ptr`, in-range strictly-increasing row indices per column).
    pub fn new(
        n: usize,
        p: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(col_ptr.len(), p + 1, "col_ptr must have p + 1 entries");
        assert_eq!(col_ptr[0], 0, "col_ptr must start at 0");
        assert_eq!(col_ptr[p], row_idx.len(), "col_ptr end ≠ nnz");
        assert_eq!(row_idx.len(), values.len(), "row_idx / values length mismatch");
        for j in 0..p {
            assert!(col_ptr[j] <= col_ptr[j + 1], "col_ptr must be monotone");
            let rows = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            assert!(
                rows.windows(2).all(|w| w[0] < w[1]),
                "row indices must be strictly increasing within column {j}"
            );
            if let Some(&last) = rows.last() {
                assert!(last < n, "row index {last} out of range in column {j}");
            }
        }
        CscMatrix { n, p, col_ptr, row_idx, values }
    }

    /// Compress a dense matrix, keeping entries with `|x| > drop_tol`
    /// (use `0.0` to keep every nonzero exactly). NaN entries are always
    /// kept, so a poisoned input poisons the sparse fit the same way it
    /// poisons a dense one instead of silently becoming an implicit zero.
    pub fn from_dense(x: &Matrix, drop_tol: f64) -> Self {
        let (n, p) = (x.nrows(), x.ncols());
        let mut col_ptr = Vec::with_capacity(p + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..p {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v.abs() > drop_tol || v.is_nan() {
                    row_idx.push(i);
                    values.push(v);
                }
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n, p, col_ptr, row_idx, values }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Number of explicitly stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `nnz / (n · p)` — the fill fraction.
    pub fn density(&self) -> f64 {
        if self.n == 0 || self.p == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n * self.p) as f64
    }

    /// Column `j`'s stored `(row, value)` pairs.
    #[inline]
    pub fn col_entries(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let r = self.col_ptr[j]..self.col_ptr[j + 1];
        self.row_idx[r.clone()].iter().copied().zip(self.values[r].iter().copied())
    }

    /// `out = X β` touching only stored entries (O(nnz)).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                for (i, v) in self.col_entries(j) {
                    out[i] += b * v;
                }
            }
        }
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = Xᵀ r` touching only stored entries (O(nnz)).
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for (j, o) in out.iter_mut().enumerate() {
            let mut s = 0.0;
            for (i, v) in self.col_entries(j) {
                s += v * r[i];
            }
            *o = s;
        }
    }

    /// `g = Xᵀ r` (length p).
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// ℓ₂ norm of each column from the stored entries alone.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p)
            .map(|j| self.col_entries(j).map(|(_, v)| v * v).sum::<f64>().sqrt())
            .collect()
    }

    /// Mean of each column (implicit zeros included).
    pub fn col_means(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| self.col_entries(j).map(|(_, v)| v).sum::<f64>() / n)
            .collect()
    }

    /// Per-column `(mean, scale)` of the ℓ₂ standardization (zero mean,
    /// unit ℓ₂ norm), computed sparse-aware in two passes over the stored
    /// entries: mean first, then the centered norm as
    /// `√(Σ_nz (v − mean)² + (n − nnz_j)·mean²)`. The shifted second pass
    /// avoids the catastrophic cancellation of the textbook
    /// `Σv² − n·mean²` form (large mean, tiny spread), so the stats track
    /// the dense two-pass [`Matrix::standardize_l2`] (near-constant
    /// columns get scale 1).
    pub fn standardize_stats(&self) -> Vec<(f64, f64)> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let mut sum = 0.0;
                let mut nnz_j = 0usize;
                for (_, v) in self.col_entries(j) {
                    sum += v;
                    nnz_j += 1;
                }
                let mean = sum / n;
                let mut centered_sumsq = (n - nnz_j as f64) * mean * mean;
                for (_, v) in self.col_entries(j) {
                    let d = v - mean;
                    centered_sumsq += d * d;
                }
                let nrm = centered_sumsq.sqrt();
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                (mean, scale)
            })
            .collect()
    }

    /// Materialize the ℓ₂-standardized design as a dense [`Matrix`] in one
    /// pass (fill each column with `−mean/scale`, overwrite the stored
    /// entries with `(v − mean)/scale`), returning the per-column
    /// `(mean, scale)` used — the sparse entry point into the dense
    /// pathwise stack.
    pub fn to_standardized_dense(&self) -> (Matrix, Vec<(f64, f64)>) {
        note_dense_materialization();
        let stats = self.standardize_stats();
        let mut m = Matrix::zeros(self.n, self.p);
        for (j, &(mean, scale)) in stats.iter().enumerate() {
            let dst = m.col_mut(j);
            dst.fill(-mean / scale);
            for (i, v) in self.col_entries(j) {
                dst[i] = (v - mean) / scale;
            }
        }
        (m, stats)
    }

    /// Densify without standardizing (tests / small problems).
    pub fn to_dense(&self) -> Matrix {
        note_dense_materialization();
        let mut m = Matrix::zeros(self.n, self.p);
        for j in 0..self.p {
            let dst = m.col_mut(j);
            for (i, v) in self.col_entries(j) {
                dst[i] = v;
            }
        }
        m
    }

    /// Full content hash over values, row indices, and column pointers —
    /// the sparse leg of the model API's prepared-design cache key. Every
    /// stored entry participates, so any change to the matrix changes the
    /// hash (up to 64-bit collision odds).
    pub fn fingerprint(&self) -> u64 {
        let mut h = content_hash(&self.values);
        h ^= content_hash_usizes(&self.row_idx).wrapping_mul(0x9e3779b97f4a7c15);
        h ^= content_hash_usizes(&self.col_ptr).rotate_left(17);
        h
    }
}

thread_local! {
    /// Per-thread count of sparse→dense materializations (see
    /// [`dense_materializations`]).
    static DENSE_MATERIALIZATIONS: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// Number of times *this thread* has materialized a sparse design as a
/// dense matrix ([`CscMatrix::to_dense`], [`CscMatrix::to_standardized_dense`],
/// [`CenteredSparse::to_dense`]). The sparse solve path's acceptance
/// witness: a fit through the centered-implicit kernels must leave this
/// counter untouched (`rust/tests/sparse_equivalence.rs`). Thread-local so
/// concurrently running tests cannot alias each other's counts.
pub fn dense_materializations() -> u64 {
    DENSE_MATERIALIZATIONS.with(|c| c.get())
}

fn note_dense_materialization() {
    DENSE_MATERIALIZATIONS.with(|c| c.set(c.get() + 1));
}

/// ℓ₂-standardized sparse design held in centered-implicit form: the raw
/// CSC nonzeros plus per-column `(offset, scale)` such that the matrix the
/// kernels *evaluate* is
///
/// ```text
///     X̃[:, j] = (X[:, j] − offset_j · 1) / scale_j ,
/// ```
///
/// which is **never materialized dense** — centering would fill every
/// implicit zero with `−offset_j / scale_j`, destroying sparsity, so the
/// kernels carry the rank-one correction instead (the trick production SGL
/// solvers like `sparsegl` use):
///
/// * `X̃β  = X(β ⊘ s) − (Σ_j β_j μ_j / s_j) · 1` — one sparse matvec plus a
///   scalar shift, O(nnz + n);
/// * `X̃ᵀr = (Xᵀr − μ · Σᵢ rᵢ) ⊘ s` — one sparse transpose-matvec plus a
///   rank-one correction, O(nnz + n).
///
/// Built from a [`CscMatrix`] via [`CenteredSparse::from_csc`] (offsets =
/// column means, scales = centered column ℓ₂ norms, computed from the
/// nonzeros alone), this is the drop-in sparse counterpart of a dense
/// standardized [`Matrix`] everywhere the solve path only needs the
/// [`DesignRef`] kernel contract.
#[derive(Clone, Debug, PartialEq)]
pub struct CenteredSparse {
    n: usize,
    p: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
    /// Per-column centering offset μ_j (the raw column mean at build time).
    offsets: Vec<f64>,
    /// Per-column divisor s_j (the centered column norm at build time).
    scales: Vec<f64>,
}

impl CenteredSparse {
    /// Empty design with `n` rows and no columns (grow-only buffer seed
    /// for the reduced-design cache).
    pub fn empty(n: usize) -> Self {
        CenteredSparse {
            n,
            p: 0,
            col_ptr: vec![0],
            row_idx: Vec::new(),
            values: Vec::new(),
            offsets: Vec::new(),
            scales: Vec::new(),
        }
    }

    /// Standardized view of a raw CSC design: offsets/scales are the
    /// per-column `(mean, centered ℓ₂ norm)` from
    /// [`CscMatrix::standardize_stats`], so the implied matrix equals
    /// [`CscMatrix::to_standardized_dense`]'s output without the `n × p`
    /// allocation.
    pub fn from_csc(csc: &CscMatrix) -> Self {
        let stats = csc.standardize_stats();
        let (offsets, scales) = stats.into_iter().unzip();
        CenteredSparse {
            n: csc.n,
            p: csc.p,
            col_ptr: csc.col_ptr.clone(),
            row_idx: csc.row_idx.clone(),
            values: csc.values.clone(),
            offsets,
            scales,
        }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Number of stored raw nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fill fraction of the *raw* nonzeros (the implied standardized
    /// matrix is dense by construction; this measures the kernel cost).
    pub fn density(&self) -> f64 {
        if self.n == 0 || self.p == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n * self.p) as f64
    }

    /// Per-column `(offset, scale)` — the standardization centers callers
    /// use to map coefficients back to the raw scale.
    pub fn centers(&self) -> Vec<(f64, f64)> {
        self.offsets.iter().copied().zip(self.scales.iter().copied()).collect()
    }

    /// `out = X̃ β` touching only stored entries plus one rank-one shift.
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        let mut shift = 0.0;
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                let bs = b / self.scales[j];
                shift += bs * self.offsets[j];
                for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                    out[self.row_idx[k]] += bs * self.values[k];
                }
            }
        }
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    /// `y = X̃ β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out[k] = X̃[:, first + k]ᵀ r` for `out.len()` consecutive columns,
    /// with the residual sum `sr = Σᵢ rᵢ` supplied by the caller — the one
    /// shared inner loop behind every sparse transpose-matvec form
    /// (serial, parallel-chunked, block, carried-sum).
    fn t_matvec_cols_with_rsum(&self, first: usize, r: &[f64], sr: f64, out: &mut [f64]) {
        for (k, o) in out.iter_mut().enumerate() {
            let j = first + k;
            let mut s = 0.0;
            for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                s += self.values[t] * r[self.row_idx[t]];
            }
            *o = (s - self.offsets[j] * sr) / self.scales[j];
        }
    }

    /// `out = X̃ᵀ r`: sparse column dots corrected by `μ_j · Σᵢ rᵢ`.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        let sr: f64 = r.iter().sum();
        self.t_matvec_cols_with_rsum(0, r, sr, out);
    }

    /// `g = X̃ᵀ r` (length p).
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = X̃ᵀ r` fanned out across a thread scope. The sparse kernel is
    /// O(nnz), so the break-even point (the shared `DFR_PAR_GRAIN`
    /// tunable) is on stored entries, not `n·p`.
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        if threads <= 1 || self.nnz() + self.n < parallel::par_grain() {
            self.t_matvec_into(r, out);
            return;
        }
        let sr: f64 = r.iter().sum();
        parallel::for_each_chunk(out, threads, |start, chunk| {
            self.t_matvec_cols_with_rsum(start, r, sr, chunk);
        });
    }

    /// `out = X̃ β` fanned out over *row* chunks: each worker rebuilds its
    /// disjoint slice of `out` by binary-searching every active column's
    /// row window (row indices are strictly increasing per column), so no
    /// two workers touch the same output row and results are bitwise
    /// identical to [`CenteredSparse::matvec_into`] at any thread count.
    pub fn matvec_par_into(&self, beta: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        if threads <= 1 || self.nnz() + self.n < parallel::par_grain() {
            self.matvec_into(beta, out);
            return;
        }
        // The rank-one shift is row-independent: accumulate it once, in
        // the same column order as the serial kernel.
        let mut shift = 0.0;
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                shift += (b / self.scales[j]) * self.offsets[j];
            }
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            let (lo, hi) = (start, start + chunk.len());
            chunk.fill(0.0);
            for (j, &b) in beta.iter().enumerate() {
                if b == 0.0 {
                    continue;
                }
                let bs = b / self.scales[j];
                let base = self.col_ptr[j];
                let rows = &self.row_idx[base..self.col_ptr[j + 1]];
                let s = rows.partition_point(|&i| i < lo);
                let e = s + rows[s..].partition_point(|&i| i < hi);
                for t in s..e {
                    chunk[rows[t] - lo] += bs * self.values[base + t];
                }
            }
            if shift != 0.0 {
                chunk.iter_mut().for_each(|v| *v -= shift);
            }
        });
    }

    /// `out += Σ_k coeffs[k] · X̃[:, cols.start + k]` — the centered-
    /// implicit group-block matvec: sparse per-column axpys plus **one**
    /// rank-one centering shift over the whole block, O(nnz_block + n).
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(coeffs.len(), cols.len());
        debug_assert_eq!(out.len(), self.n);
        let mut shift = 0.0;
        for (k, &c) in coeffs.iter().enumerate() {
            if c != 0.0 {
                let j = cols.start + k;
                let bs = c / self.scales[j];
                shift += bs * self.offsets[j];
                for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                    out[self.row_idx[t]] += bs * self.values[t];
                }
            }
        }
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    /// `out[k] = X̃[:, cols.start + k]ᵀ r` — sparse block column dots with
    /// the rank-one centering correction (`Σᵢ rᵢ` computed once per block).
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), cols.len());
        debug_assert_eq!(r.len(), self.n);
        let sr: f64 = r.iter().sum();
        self.t_matvec_cols_with_rsum(cols.start, r, sr, out);
    }

    /// [`CenteredSparse::block_t_matvec_into`] with the residual sum
    /// `rsum = Σᵢ rᵢ` carried by the caller — skips the per-block O(n)
    /// pass entirely. The BCD solver computes the sum once per residual
    /// refresh (fused into the loss's residual pass) and reuses it across
    /// every block update against that residual.
    pub fn block_t_matvec_with_rsum_into(
        &self,
        cols: std::ops::Range<usize>,
        r: &[f64],
        rsum: f64,
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), cols.len());
        debug_assert_eq!(r.len(), self.n);
        self.t_matvec_cols_with_rsum(cols.start, r, rsum, out);
    }

    /// Squared ℓ₂ norm of every *implied standardized* column into `out`
    /// (the sparse leg of the BCD block-Lipschitz cache) — computed from
    /// the stored entries alone, like [`CenteredSparse::col_norms`] without
    /// the square root. Columns are independent, so large designs fan the
    /// loop out over the default thread pool (per-column results are
    /// unchanged by the chunking).
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.p);
        let threads = parallel::default_threads();
        if threads <= 1 || self.nnz() + self.n < parallel::par_grain() {
            self.col_sq_norms_cols(0, out);
            return;
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            self.col_sq_norms_cols(start, chunk);
        });
    }

    /// Per-column squared norms for `out.len()` consecutive columns
    /// starting at `first` (the chunk body of
    /// [`CenteredSparse::col_sq_norms_into`]).
    fn col_sq_norms_cols(&self, first: usize, out: &mut [f64]) {
        let n = self.n as f64;
        for (k, o) in out.iter_mut().enumerate() {
            let j = first + k;
            let (mu, s) = (self.offsets[j], self.scales[j]);
            let mut nnz_j = 0usize;
            let mut sumsq = 0.0;
            for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                let d = (self.values[t] - mu) / s;
                sumsq += d * d;
                nnz_j += 1;
            }
            let z = mu / s;
            *o = sumsq + (n - nnz_j as f64) * z * z;
        }
    }

    /// ℓ₂ norm of each *implied standardized* column:
    /// `√(Σ_nz ((v − μ)/s)² + (n − nnz_j)·(μ/s)²)` — 1 by construction for
    /// non-degenerate columns.
    pub fn col_norms(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let (mu, s) = (self.offsets[j], self.scales[j]);
                let mut nnz_j = 0usize;
                let mut sumsq = 0.0;
                for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                    let d = (self.values[k] - mu) / s;
                    sumsq += d * d;
                    nnz_j += 1;
                }
                let z = mu / s;
                (sumsq + (n - nnz_j as f64) * z * z).sqrt()
            })
            .collect()
    }

    /// Mean of each implied standardized column — `(mean_raw − μ)/s`,
    /// zero by construction right after [`CenteredSparse::from_csc`].
    pub fn col_means(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let raw: f64 =
                    self.values[self.col_ptr[j]..self.col_ptr[j + 1]].iter().sum();
                (raw / n - self.offsets[j]) / self.scales[j]
            })
            .collect()
    }

    /// Power-iteration estimate of `‖X̃‖₂²` — the shared
    /// [`DesignRef::op_norm_sq_est`] run through the implicit kernels.
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        DesignRef::Sparse(self).op_norm_sq_est(iters, seed)
    }

    /// Row subset (CV folds): gathers the *raw* nonzeros and keeps the
    /// per-column `(offset, scale)`, so the implied matrix of the result is
    /// exactly the row-gather of this design's implied matrix. Arbitrary
    /// row order (and repeats) are supported, matching
    /// [`Matrix::gather_rows`].
    pub fn gather_rows(&self, rows: &[usize]) -> CenteredSparse {
        let mut positions: Vec<Vec<usize>> = vec![Vec::new(); self.n];
        for (k, &i) in rows.iter().enumerate() {
            assert!(i < self.n, "row index {i} out of range");
            positions[i].push(k);
        }
        let mut out = CenteredSparse::empty(rows.len());
        out.offsets = self.offsets.clone();
        out.scales = self.scales.clone();
        out.p = self.p;
        let backend = kernels::active();
        // Sort (new row, source position) pairs, then bulk-gather the
        // values through the dispatched kernel — the value copy is the
        // hot half of the fold build, the index shuffle is cheap.
        let mut col: Vec<(usize, usize)> = Vec::new();
        let mut src_pos: Vec<usize> = Vec::new();
        for j in 0..self.p {
            col.clear();
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                for &new_i in &positions[self.row_idx[k]] {
                    col.push((new_i, k));
                }
            }
            col.sort_unstable_by_key(|&(i, _)| i);
            let base = out.row_idx.len();
            out.row_idx.extend(col.iter().map(|&(i, _)| i));
            src_pos.clear();
            src_pos.extend(col.iter().map(|&(_, k)| k));
            out.values.resize(base + col.len(), 0.0);
            // SAFETY: every source position came from this matrix's own
            // col_ptr ranges, so all are < values.len().
            unsafe {
                kernels::gather_with(backend, &self.values, &src_pos, &mut out.values[base..])
            };
            out.col_ptr.push(out.values.len());
        }
        out
    }

    /// Re-standardize the *implied* matrix in place (zero mean, unit ℓ₂
    /// norm per column) and return the per-column `(mean, scale)` of the
    /// implied columns — the sparse counterpart of
    /// [`Matrix::standardize_l2`], used by the CV fold planner on sparse
    /// training subsets.
    ///
    /// The composition stays affine, so only the offsets/scales move:
    /// with current `(μ, s)` and implied-column stats `(m', s')`,
    /// `((x − μ)/s − m')/s' = (x − mean_raw)/(s·s')` where
    /// `mean_raw = μ + s·m'` is the raw column mean over these rows.
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let r = self.col_ptr[j]..self.col_ptr[j + 1];
                let nnz_j = r.len();
                let sum: f64 = self.values[r.clone()].iter().sum();
                let mean_raw = sum / n;
                // Shifted two-pass centered norm (see
                // `CscMatrix::standardize_stats` for the cancellation
                // rationale).
                let mut centered_sumsq = (n - nnz_j as f64) * mean_raw * mean_raw;
                for k in r {
                    let d = self.values[k] - mean_raw;
                    centered_sumsq += d * d;
                }
                let (mu, s) = (self.offsets[j], self.scales[j]);
                let m_prime = (mean_raw - mu) / s;
                let nrm = centered_sumsq.sqrt() / s;
                let s_prime = if nrm > 1e-12 { nrm } else { 1.0 };
                self.offsets[j] = mean_raw;
                self.scales[j] = s * s_prime;
                (m_prime, s_prime)
            })
            .collect()
    }

    /// Materialize the implied standardized matrix (tests / diagnostics
    /// only — counts as a dense materialization for the sparse-path
    /// witness counter).
    pub fn to_dense(&self) -> Matrix {
        note_dense_materialization();
        let mut m = Matrix::zeros(self.n, self.p);
        for j in 0..self.p {
            let (mu, s) = (self.offsets[j], self.scales[j]);
            let dst = m.col_mut(j);
            dst.fill(-mu / s);
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                dst[self.row_idx[k]] = (self.values[k] - mu) / s;
            }
        }
        m
    }

    /// Drop all but the first `k` columns in place (grow-only buffers, for
    /// the reduced-design cache).
    pub(crate) fn truncate_cols(&mut self, k: usize) {
        assert!(k <= self.p, "truncate_cols past the end");
        let nnz = self.col_ptr[k];
        self.col_ptr.truncate(k + 1);
        self.row_idx.truncate(nnz);
        self.values.truncate(nnz);
        self.offsets.truncate(k);
        self.scales.truncate(k);
        self.p = k;
    }

    /// Append a copy of `src`'s column `j` (raw entries + its center).
    pub(crate) fn push_col_from(&mut self, src: &CenteredSparse, j: usize) {
        debug_assert_eq!(self.n, src.n);
        let r = src.col_ptr[j]..src.col_ptr[j + 1];
        self.row_idx.extend_from_slice(&src.row_idx[r.clone()]);
        self.values.extend_from_slice(&src.values[r]);
        self.offsets.push(src.offsets[j]);
        self.scales.push(src.scales[j]);
        self.col_ptr.push(self.values.len());
        self.p += 1;
    }
}

/// Kernel-variant display/cache-key name of the dense path — the single
/// source of the string shared by [`DesignRef::kernel_name`],
/// [`DesignOps::kernel_name`], and the model API's kernel resolution.
pub const DENSE_KERNEL: &str = "dense";

/// Kernel-variant name of the centered-implicit sparse path (see
/// [`DENSE_KERNEL`]).
pub const SPARSE_KERNEL: &str = "centered-sparse";

/// Kernel-variant name of the out-of-core column-block streaming path
/// (see [`DENSE_KERNEL`]).
pub const OOC_KERNEL: &str = "ooc-stream";

/// Borrowed view of a design the solve path can run its kernels on — the
/// kernel contract shared by every layer of the pathwise stack (loss
/// gradients, FISTA/ATOS matvecs, GAP-safe screening, power-iteration
/// Lipschitz estimates).
///
/// Three variants: [`DesignRef::Dense`] delegates to the exact same
/// [`Matrix`] kernels as before (dense results stay bit-stable),
/// [`DesignRef::Sparse`] serves the centered-implicit kernels of
/// [`CenteredSparse`], and [`DesignRef::Ooc`] streams a chunk-file-backed
/// [`OocDesign`] in column blocks without ever holding the design in RAM.
/// `Copy`, so it threads through call stacks like the `&Matrix` it
/// replaces.
#[derive(Clone, Copy, Debug)]
pub enum DesignRef<'a> {
    Dense(&'a Matrix),
    Sparse(&'a CenteredSparse),
    Ooc(&'a OocDesign),
}

impl<'a> DesignRef<'a> {
    #[inline]
    pub fn nrows(self) -> usize {
        match self {
            DesignRef::Dense(m) => m.nrows(),
            DesignRef::Sparse(s) => s.nrows(),
            DesignRef::Ooc(o) => o.nrows(),
        }
    }

    #[inline]
    pub fn ncols(self) -> usize {
        match self {
            DesignRef::Dense(m) => m.ncols(),
            DesignRef::Sparse(s) => s.ncols(),
            DesignRef::Ooc(o) => o.ncols(),
        }
    }

    /// The dense matrix behind this view, if any (column gathers into
    /// dense buffers are dense-only).
    #[inline]
    pub fn as_dense(self) -> Option<&'a Matrix> {
        match self {
            DesignRef::Dense(m) => Some(m),
            DesignRef::Sparse(_) | DesignRef::Ooc(_) => None,
        }
    }

    /// Kernel variant name for reports and cache keys.
    pub fn kernel_name(self) -> &'static str {
        match self {
            DesignRef::Dense(_) => DENSE_KERNEL,
            DesignRef::Sparse(_) => SPARSE_KERNEL,
            DesignRef::Ooc(_) => OOC_KERNEL,
        }
    }

    pub fn matvec_into(self, beta: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.matvec_into(beta, out),
            DesignRef::Sparse(s) => s.matvec_into(beta, out),
            DesignRef::Ooc(o) => o.matvec_into(beta, out),
        }
    }

    pub fn matvec(self, beta: &[f64]) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.matvec(beta),
            DesignRef::Sparse(s) => s.matvec(beta),
            DesignRef::Ooc(o) => o.matvec(beta),
        }
    }

    /// `out = Xβ` fanned out over row chunks (dense: blocked row tiles;
    /// sparse: binary-searched row windows per column) — both sides gate
    /// on the `DFR_PAR_GRAIN` break-even, so small problems stay serial.
    pub fn matvec_par_into(self, beta: &[f64], threads: usize, out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.matvec_par_into(beta, threads, out),
            DesignRef::Sparse(s) => s.matvec_par_into(beta, threads, out),
            DesignRef::Ooc(o) => o.matvec_par_into(beta, threads, out),
        }
    }

    pub fn t_matvec_into(self, r: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.t_matvec_into(r, out),
            DesignRef::Sparse(s) => s.t_matvec_into(r, out),
            DesignRef::Ooc(o) => o.t_matvec_into(r, out),
        }
    }

    pub fn t_matvec(self, r: &[f64]) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.t_matvec(r),
            DesignRef::Sparse(s) => s.t_matvec(r),
            DesignRef::Ooc(o) => o.t_matvec(r),
        }
    }

    pub fn t_matvec_par(self, r: &[f64], threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.ncols()];
        self.t_matvec_par_into(r, threads, &mut out);
        out
    }

    pub fn t_matvec_par_into(self, r: &[f64], threads: usize, out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.t_matvec_par_into(r, threads, out),
            DesignRef::Sparse(s) => s.t_matvec_par_into(r, threads, out),
            DesignRef::Ooc(o) => o.t_matvec_par_into(r, threads, out),
        }
    }

    pub fn col_norms(self) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.col_norms(),
            DesignRef::Sparse(s) => s.col_norms(),
            DesignRef::Ooc(o) => o.col_norms(),
        }
    }

    /// Group-block matvec: `out += Σ_k coeffs[k] · X[:, cols.start + k]`
    /// (dense axpys / centered-implicit sparse axpys + one rank-one
    /// shift). The kernel contract of the BCD solver's residual-carried
    /// block updates.
    pub fn block_axpy_into(self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.block_axpy_into(cols, coeffs, out),
            DesignRef::Sparse(s) => s.block_axpy_into(cols, coeffs, out),
            DesignRef::Ooc(o) => o.block_axpy_into(cols, coeffs, out),
        }
    }

    /// Group-block transpose matvec: `out[k] = X[:, cols.start + k]ᵀ r`.
    pub fn block_t_matvec_into(self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.block_t_matvec_into(cols, r, out),
            DesignRef::Sparse(s) => s.block_t_matvec_into(cols, r, out),
            DesignRef::Ooc(o) => o.block_t_matvec_into(cols, r, out),
        }
    }

    /// Group-block transpose matvec with a caller-carried residual sum
    /// `rsum = Σᵢ rᵢ`: the sparse kernel skips its per-block O(n) pass,
    /// the dense kernel ignores the sum. Callers that already hold the
    /// sum (the BCD residual refresh) use this across every block update
    /// against one residual.
    pub fn block_t_matvec_with_rsum_into(
        self,
        cols: std::ops::Range<usize>,
        r: &[f64],
        rsum: f64,
        out: &mut [f64],
    ) {
        match self {
            DesignRef::Dense(m) => m.block_t_matvec_with_rsum_into(cols, r, rsum, out),
            DesignRef::Sparse(s) => s.block_t_matvec_with_rsum_into(cols, r, rsum, out),
            DesignRef::Ooc(o) => o.block_t_matvec_with_rsum_into(cols, r, rsum, out),
        }
    }

    /// Squared ℓ₂ norm of every column of the design the kernels evaluate
    /// (per-group block-Lipschitz seeds).
    pub fn col_sq_norms_into(self, out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.col_sq_norms_into(out),
            DesignRef::Sparse(s) => s.col_sq_norms_into(out),
            DesignRef::Ooc(o) => o.col_sq_norms_into(out),
        }
    }

    /// Column means of the design the kernels evaluate (adaptive-weight
    /// PCA centering).
    pub fn col_means(self) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => {
                let n = m.nrows() as f64;
                (0..m.ncols()).map(|j| m.col(j).iter().sum::<f64>() / n).collect()
            }
            DesignRef::Sparse(s) => s.col_means(),
            DesignRef::Ooc(o) => o.col_means(),
        }
    }

    /// Power-iteration estimate of `‖X‖₂²` on whichever kernel variant
    /// this view holds — the single implementation behind
    /// [`Matrix::op_norm_sq_est`] and [`CenteredSparse::op_norm_sq_est`]
    /// (for the dense arm this runs the exact historical algorithm through
    /// the delegating kernels, so dense results are unchanged).
    pub fn op_norm_sq_est(self, iters: usize, seed: u64) -> f64 {
        let p = self.ncols();
        let n = self.nrows();
        let mut v: Vec<f64> = {
            let mut rng = crate::rng::Rng::new(seed);
            (0..p).map(|_| rng.gauss()).collect()
        };
        let nv = norm2(&v).max(1e-300);
        v.iter_mut().for_each(|x| *x /= nv);
        let mut lam;
        let mut xb = vec![0.0; n];
        for _ in 0..iters.max(1) {
            self.matvec_into(&v, &mut xb);
            let w = self.t_matvec(&xb);
            lam = norm2(&w);
            if lam <= 0.0 {
                return 0.0;
            }
            v = w.iter().map(|x| x / lam).collect();
        }
        // One extra Rayleigh quotient for a tighter estimate.
        self.matvec_into(&v, &mut xb);
        dot(&xb, &xb) / dot(&v, &v)
    }
}

impl<'a> From<&'a Matrix> for DesignRef<'a> {
    fn from(m: &'a Matrix) -> Self {
        DesignRef::Dense(m)
    }
}

impl<'a> From<&'a CenteredSparse> for DesignRef<'a> {
    fn from(s: &'a CenteredSparse) -> Self {
        DesignRef::Sparse(s)
    }
}

impl<'a> From<&'a OocDesign> for DesignRef<'a> {
    fn from(o: &'a OocDesign) -> Self {
        DesignRef::Ooc(o)
    }
}

impl<'a> From<&'a DesignOps> for DesignRef<'a> {
    fn from(d: &'a DesignOps) -> Self {
        d.view()
    }
}

/// Owned design in whichever kernel representation the solve should run:
/// a dense standardized [`Matrix`] (today's exact code path), a
/// [`CenteredSparse`] centered-implicit design (sparse end-to-end), or an
/// out-of-core [`OocDesign`] streamed from disk (the handle is an `Arc`
/// over the open pack file, so this variant is as cheap to clone as the
/// sparse one is to borrow). This is what a [`crate::data::Dataset`]
/// carries; the compute layers see it through the borrowed [`DesignRef`]
/// kernel contract.
#[derive(Clone, Debug)]
pub enum DesignOps {
    Dense(Matrix),
    Sparse(CenteredSparse),
    Ooc(OocDesign),
}

impl DesignOps {
    /// Borrowed kernel view.
    #[inline]
    pub fn view(&self) -> DesignRef<'_> {
        match self {
            DesignOps::Dense(m) => DesignRef::Dense(m),
            DesignOps::Sparse(s) => DesignRef::Sparse(s),
            DesignOps::Ooc(o) => DesignRef::Ooc(o),
        }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.view().nrows()
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.view().ncols()
    }

    /// Kernel variant name ("dense" / "centered-sparse").
    pub fn kernel_name(&self) -> &'static str {
        self.view().kernel_name()
    }

    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.view().matvec(beta)
    }

    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        self.view().matvec_into(beta, out)
    }

    /// Row-parallel `Xβ` (see [`DesignRef::matvec_par_into`]).
    pub fn matvec_par_into(&self, beta: &[f64], threads: usize, out: &mut [f64]) {
        self.view().matvec_par_into(beta, threads, out)
    }

    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        self.view().t_matvec(r)
    }

    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        self.view().t_matvec_par(r, threads)
    }

    pub fn col_norms(&self) -> Vec<f64> {
        self.view().col_norms()
    }

    /// Group-block matvec (see [`DesignRef::block_axpy_into`]).
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        self.view().block_axpy_into(cols, coeffs, out)
    }

    /// Group-block transpose matvec (see [`DesignRef::block_t_matvec_into`]).
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        self.view().block_t_matvec_into(cols, r, out)
    }

    /// Carried-sum group-block transpose matvec (see
    /// [`DesignRef::block_t_matvec_with_rsum_into`]).
    pub fn block_t_matvec_with_rsum_into(
        &self,
        cols: std::ops::Range<usize>,
        r: &[f64],
        rsum: f64,
        out: &mut [f64],
    ) {
        self.view().block_t_matvec_with_rsum_into(cols, r, rsum, out)
    }

    /// Per-column squared norms (see [`DesignRef::col_sq_norms_into`]).
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        self.view().col_sq_norms_into(out)
    }

    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        self.view().op_norm_sq_est(iters, seed)
    }

    /// The dense matrix inside. Panics on a centered-sparse design — for
    /// dense-only construction/inspection paths (data generators,
    /// interaction expansion, tests); the solve path never calls it.
    pub fn dense(&self) -> &Matrix {
        match self {
            DesignOps::Dense(m) => m,
            DesignOps::Sparse(_) => {
                panic!("dense() called on a centered-sparse design")
            }
            DesignOps::Ooc(_) => {
                panic!("dense() called on an out-of-core design")
            }
        }
    }

    /// Mutable access to the dense matrix inside (panics when sparse or
    /// out-of-core).
    pub fn dense_mut(&mut self) -> &mut Matrix {
        match self {
            DesignOps::Dense(m) => m,
            DesignOps::Sparse(_) => {
                panic!("dense_mut() called on a centered-sparse design")
            }
            DesignOps::Ooc(_) => {
                panic!("dense_mut() called on an out-of-core design")
            }
        }
    }

    /// ℓ₂-standardize in place (dense: [`Matrix::standardize_l2`]; sparse:
    /// affine recomposition of the offsets/scales), returning the
    /// per-column `(mean, scale)` on the *current* implied scale. Panics
    /// on an out-of-core design: its standardization stats are computed
    /// once at pack time and the file is immutable (the model API hands
    /// them out directly instead of calling this).
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        match self {
            DesignOps::Dense(m) => m.standardize_l2(),
            DesignOps::Sparse(s) => s.standardize_l2(),
            DesignOps::Ooc(_) => {
                panic!("standardize_l2() called on an out-of-core design (stats are pack-time)")
            }
        }
    }

    /// Row subset with the variant preserved (CV folds stay sparse on the
    /// sparse path). Panics on an out-of-core design: CV folds require
    /// row gathers + re-standardization, which the streaming store does
    /// not support — the model API rejects `cv` on `--ooc` before this
    /// can be reached.
    pub fn gather_rows(&self, rows: &[usize]) -> DesignOps {
        match self {
            DesignOps::Dense(m) => DesignOps::Dense(m.gather_rows(rows)),
            DesignOps::Sparse(s) => DesignOps::Sparse(s.gather_rows(rows)),
            DesignOps::Ooc(_) => {
                panic!("gather_rows() called on an out-of-core design")
            }
        }
    }
}

impl From<Matrix> for DesignOps {
    fn from(m: Matrix) -> Self {
        DesignOps::Dense(m)
    }
}

impl From<CenteredSparse> for DesignOps {
    fn from(s: CenteredSparse) -> Self {
        DesignOps::Sparse(s)
    }
}

impl From<OocDesign> for DesignOps {
    fn from(o: OocDesign) -> Self {
        DesignOps::Ooc(o)
    }
}

/// Incremental cache of a screening-reduced design `X[:, idx]`.
///
/// The pathwise coordinator re-gathers the optimization set every λ step
/// and every KKT re-entry round; consecutive sets overlap heavily (the
/// active set persists, KKT rounds only add variables). This cache keeps
/// one grow-only backing buffer across the whole path and, on each update,
/// keeps the longest common prefix of the sorted index lists in place —
/// identical sets cost nothing, append-only growth copies only the new
/// columns, and even a full rebuild reuses the allocation.
///
/// The source design is identified by variant + pointer + length + a
/// strided content fingerprint, so reusing one cache across datasets (CV
/// folds, bench repeats) detects a swapped design even when the allocator
/// hands the new matrix the old one's address. Contract: source designs
/// are immutable between updates (true everywhere in this crate — designs
/// never change after construction); an *in-place* mutation of the same
/// allocation can dodge the 64 sampled positions, so callers mutating a
/// design must call [`ReducedDesign::invalidate`] themselves.
///
/// Both kernel variants are served: a dense source gathers into a dense
/// grow-only [`Matrix`] exactly as before, and a [`CenteredSparse`] source
/// gathers into a reduced *centered-sparse* design (raw column slices plus
/// their `(offset, scale)` pairs) with the same prefix-diff reuse — the
/// sparse solve path never densifies its reduced problems.
#[derive(Clone, Debug)]
pub struct ReducedDesign {
    idx: Vec<usize>,
    mat: Matrix,
    smat: CenteredSparse,
    /// Source identity: variant tag (0 dense, 1 sparse, 2 ooc) + address
    /// + length + content fingerprint (ooc: the pack file's full hash).
    key: Option<(u8, usize, usize, u64)>,
    /// Column staging buffer for the out-of-core gather arm (one
    /// standardized column read from disk, then pushed into `mat`).
    colbuf: Vec<f64>,
    /// Group-block offsets of the last [`ReducedDesign::update_grouped`]
    /// gather: start of each maximal run of columns drawn from one
    /// original group, plus the `idx.len()` sentinel.
    gstarts: Vec<usize>,
    /// Updates answered with zero copying (identical index set).
    pub hits: usize,
    /// Columns kept in place across updates (common sorted prefix).
    pub kept_cols: usize,
    /// Columns memcpy'd from the source matrix.
    pub copied_cols: usize,
}

impl ReducedDesign {
    pub fn new() -> Self {
        ReducedDesign {
            idx: Vec::new(),
            mat: Matrix::zeros(0, 0),
            smat: CenteredSparse::empty(0),
            key: None,
            colbuf: Vec::new(),
            gstarts: Vec::new(),
            hits: 0,
            kept_cols: 0,
            copied_cols: 0,
        }
    }

    /// Point the cache at `x[:, idx]` (sorted indices), reusing any columns
    /// already in place, and return the reduced design in the source's
    /// kernel variant.
    pub fn update<'s, 'x>(
        &'s mut self,
        src: impl Into<DesignRef<'x>>,
        idx: &[usize],
    ) -> DesignRef<'s> {
        match src.into() {
            DesignRef::Dense(x) => {
                let key = (
                    0u8,
                    x.as_slice().as_ptr() as usize,
                    x.as_slice().len(),
                    fingerprint(x.as_slice()),
                );
                if self.key != Some(key) {
                    self.key = Some(key);
                    self.idx.clear();
                    // Drop any columns gathered from a previous sparse
                    // source so the cross-variant accessors never serve a
                    // stale design.
                    self.smat.truncate_cols(0);
                    if self.mat.nrows() == x.nrows() {
                        self.mat.truncate_cols(0);
                    } else {
                        self.mat = Matrix::zeros(x.nrows(), 0);
                    }
                }
                if self.idx == idx {
                    self.hits += 1;
                    return DesignRef::Dense(&self.mat);
                }
                let keep =
                    self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
                self.mat.truncate_cols(keep);
                self.idx.truncate(keep);
                self.mat.reserve_cols(idx.len() - keep);
                for &j in &idx[keep..] {
                    self.mat.push_col(x.col(j));
                }
                self.idx.extend_from_slice(&idx[keep..]);
                self.kept_cols += keep;
                self.copied_cols += idx.len() - keep;
                DesignRef::Dense(&self.mat)
            }
            DesignRef::Sparse(s) => {
                let key = (
                    1u8,
                    s.values.as_ptr() as usize,
                    s.values.len(),
                    fingerprint(&s.values)
                        ^ fingerprint(&s.offsets).rotate_left(17)
                        ^ fingerprint(&s.scales).rotate_left(31),
                );
                if self.key != Some(key) {
                    self.key = Some(key);
                    self.idx.clear();
                    // Symmetric to the dense branch: a stale dense gather
                    // from a previous source must not survive.
                    self.mat.truncate_cols(0);
                    if self.smat.nrows() == s.nrows() {
                        self.smat.truncate_cols(0);
                    } else {
                        self.smat = CenteredSparse::empty(s.nrows());
                    }
                }
                if self.idx == idx {
                    self.hits += 1;
                    return DesignRef::Sparse(&self.smat);
                }
                let keep =
                    self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
                self.smat.truncate_cols(keep);
                self.idx.truncate(keep);
                for &j in &idx[keep..] {
                    self.smat.push_col_from(s, j);
                }
                self.idx.extend_from_slice(&idx[keep..]);
                self.kept_cols += keep;
                self.copied_cols += idx.len() - keep;
                DesignRef::Sparse(&self.smat)
            }
            DesignRef::Ooc(o) => {
                // The gather IS the out-of-core design's RAM boundary:
                // active columns are pulled off disk (already
                // standardized) into the dense grow-only buffer, so the
                // reduced solve runs on the exact in-memory machinery —
                // with the same prefix-diff reuse, a persistent active
                // set costs zero reads per λ step. Identity is the pack
                // file's full content hash (stable across re-opens of
                // the same data, O(1) here).
                let key = (2u8, o.nrows(), o.ncols(), o.content_hash());
                if self.key != Some(key) {
                    self.key = Some(key);
                    self.idx.clear();
                    self.smat.truncate_cols(0);
                    if self.mat.nrows() == o.nrows() {
                        self.mat.truncate_cols(0);
                    } else {
                        self.mat = Matrix::zeros(o.nrows(), 0);
                    }
                }
                if self.idx == idx {
                    self.hits += 1;
                    return DesignRef::Dense(&self.mat);
                }
                let keep =
                    self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
                self.mat.truncate_cols(keep);
                self.idx.truncate(keep);
                self.mat.reserve_cols(idx.len() - keep);
                self.colbuf.resize(o.nrows(), 0.0);
                for &j in &idx[keep..] {
                    o.read_standardized_col_into(j, &mut self.colbuf);
                    self.mat.push_col(&self.colbuf);
                }
                self.idx.extend_from_slice(&idx[keep..]);
                self.kept_cols += keep;
                self.copied_cols += idx.len() - keep;
                DesignRef::Dense(&self.mat)
            }
        }
    }

    /// [`ReducedDesign::update`] plus group-block bookkeeping: records the
    /// offsets at which the gathered columns change original group under
    /// `groups`, so a block-coordinate solver running on the reduced
    /// design sees exactly the blocks of the restricted penalty
    /// ([`crate::groups::Groups::restrict`] renumbers the same runs).
    /// Offsets are recomputed in O(|idx|) per update; the column gather
    /// itself keeps all of [`ReducedDesign::update`]'s prefix-diff reuse.
    pub fn update_grouped<'s, 'x>(
        &'s mut self,
        src: impl Into<DesignRef<'x>>,
        idx: &[usize],
        groups: &crate::groups::Groups,
    ) -> DesignRef<'s> {
        self.gstarts.clear();
        self.gstarts.push(0);
        for (k, w) in idx.windows(2).enumerate() {
            if groups.group_of(w[0]) != groups.group_of(w[1]) {
                self.gstarts.push(k + 1);
            }
        }
        self.gstarts.push(idx.len());
        self.update(src, idx)
    }

    /// Group-block offsets recorded by the last
    /// [`ReducedDesign::update_grouped`] (block `g` spans columns
    /// `offsets[g]..offsets[g+1]` of the reduced design). Empty until the
    /// first grouped update.
    pub fn group_offsets(&self) -> &[usize] {
        &self.gstarts
    }

    /// The cached dense reduced matrix (columns of the last dense
    /// `update`; empty if the last source was sparse).
    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }

    /// The column indices currently cached.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Force the next update to rebuild from scratch (buffers retained).
    pub fn invalidate(&mut self) {
        self.idx.clear();
        self.key = None;
        self.mat.truncate_cols(0);
        self.smat.truncate_cols(0);
        self.gstarts.clear();
    }
}

impl Default for ReducedDesign {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-style fingerprint over up to 64 strided samples — cheap identity
/// check for "is this the same array as last time", used by the
/// [`ReducedDesign`] cache key.
pub(crate) fn fingerprint(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let n = data.len();
    let stride = (n / 64).max(1);
    let mut i = 0;
    while i < n {
        h ^= data[i].to_bits();
        h = h.wrapping_mul(0x100000001b3);
        i += stride;
    }
    h
}

/// Full-content FNV hash over every entry — the sound (collision-odds
/// only, no sampling gaps) identity key for caches that must never serve
/// stale results for genuinely different data, e.g. the model API's
/// prepared-design cache. O(len), which is still far cheaper than the
/// copy + standardization a hit skips.
pub(crate) fn content_hash(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in data {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// [`content_hash`] over a `usize` slice (CSC structure arrays).
pub(crate) fn content_hash_usizes(data: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &v in data {
        h ^= v as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Dot product on the active [`kernels`] backend (scalar: 4 independent
/// accumulators, bitwise the historical kernel; AVX2: FMA lanes).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    kernels::dot(a, b)
}

/// `y += a * x` on the active [`kernels`] backend.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    kernels::axpy(a, x, y)
}

/// Euclidean norm (`√(x·x)` through the dispatched dot, so `norm2` on the
/// scalar backend is bitwise the historical value).
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    kernels::dot(x, x).sqrt()
}

/// ℓ₁ norm on the active [`kernels`] backend.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    kernels::norm1(x)
}

/// ℓ∞ norm on the active [`kernels`] backend.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    kernels::norm_inf(x)
}

/// ‖a − b‖₂ — used for the paper's "ℓ₂ distance to no screen" metric.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Elementwise subtraction `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
pub fn scale(x: &mut [f64], s: f64) {
    x.iter_mut().for_each(|v| *v *= s);
}

