//! Out-of-core column-block streaming designs (`.dfrpack` files).
//!
//! The whole point of DFR-style screening (PAPER.md) is that the only
//! pass which ever needs the *full* design is the screening / KKT
//! gradient scan — a streaming reduction — while the optimization runs
//! on a tiny gathered subproblem. [`OocDesign`] exploits exactly that:
//! the design lives on disk in a column-major chunk file, kernels walk
//! it in fixed column blocks (`DFR_OOC_BLOCK` columns per chunk, default
//! sized to an L3-ish byte budget), and the implied ℓ₂-standardized
//! matrix is evaluated with the same rank-one centering trick as
//! `CenteredSparse`:
//!
//! ```text
//! X̃[:, j] = (X[:, j] − μ_j·1) / s_j
//! X̃ᵀr     = (Xᵀr − μ · Σᵢ rᵢ) ⊘ s        (one streaming pass)
//! X̃β      = X(β ⊘ s) − (Σ_j β_j μ_j / s_j)·1   (support blocks only)
//! ```
//!
//! so no standardized (or even raw) copy of the full n×p design ever
//! exists in memory. Peak design residency is bounded by the block
//! buffers alone — two blocks on the serial double-buffered prefetch
//! path, one block per worker on the block-parallel reduction path —
//! and is *witnessed* at runtime by [`ooc_peak_resident_bytes`], the
//! out-of-core analog of `dense_materializations()` (pinned by
//! `rust/tests/ooc_equivalence.rs`).
//!
//! ## Pack file format (`DFRPACK1`, little-endian)
//!
//! ```text
//! offset 0   magic  b"DFRPACK1"
//! offset 8   n      u64  rows
//! offset 16  p      u64  columns
//! offset 24  hash   u64  FNV-1a over all f64 bits, column-major order
//! offset 32  stats  p × (offset f64, scale f64) — ℓ₂-standardization
//!            pairs computed once at ingest (mean, centered ℓ₂ norm
//!            with the same `> 1e-12` clamp as `Matrix::standardize_l2`)
//! offset 32 + 16p   data: column-major f64, column j at 32+16p+8·n·j
//! ```
//!
//! Files are produced by [`pack_matrix`] (in-memory ingest: tests,
//! benches) or [`pack_csv`] (`dfr pack` — a bounded-memory two-pass
//! CSV converter that never holds the design either). Ingest validates
//! entries (non-finite rejection, all-constant rejection) so kernels
//! can stream without re-checking.
//!
//! IO errors during a kernel pass (disk yanked mid-solve) panic with
//! the file path: the `DesignRef` kernel contract has no error channel,
//! and [`OocDesign::open`] has already validated shape, stats, and file
//! length up front.

use std::fs::{File, OpenOptions};
use std::io::{self, BufRead, BufReader, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};

use crate::error::DfrError;
use crate::parallel::{for_each_chunk, par_grain};

use super::{kernels, norm2, Matrix};

/// Pack-file magic ("DFRPACK" + format version 1).
const MAGIC: &[u8; 8] = b"DFRPACK1";

/// Fixed header bytes before the per-column stats block.
const HEADER_BASE: u64 = 32;

/// Default block-buffer byte budget when `DFR_OOC_BLOCK` is unset: an
/// L3-cache-ish 8 MiB, so a streamed block's columns are still warm when
/// the per-column reductions re-walk them.
pub const DEFAULT_OOC_BLOCK_BYTES: usize = 8 << 20;

// ---------------------------------------------------------------------------
// Block-size knob (mirrors `parallel::par_grain`)
// ---------------------------------------------------------------------------

/// Process-wide programmatic block-width override (0 = unset), in
/// *columns per block*. Wins over `DFR_OOC_BLOCK`.
static OOC_BLOCK_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin the streaming block width (columns per chunk) programmatically —
/// tests force chunk boundaries through active groups; benches sweep it.
/// `None` restores `DFR_OOC_BLOCK` / default resolution. Block width only
/// picks a streaming schedule; every kernel is exact at any width.
pub fn set_ooc_block_override(n: Option<usize>) {
    OOC_BLOCK_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// The `DFR_OOC_BLOCK` choice (columns per block), read once per process.
fn env_block_cols() -> Option<usize> {
    static ENV: OnceLock<Option<usize>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DFR_OOC_BLOCK").ok().and_then(|v| v.parse::<usize>().ok()).map(|n| n.max(1))
    })
}

// ---------------------------------------------------------------------------
// Materialization witness
// ---------------------------------------------------------------------------

/// Block-buffer bytes currently alive across all threads.
static OOC_RESIDENT_BYTES: AtomicUsize = AtomicUsize::new(0);

/// High-water mark of [`OOC_RESIDENT_BYTES`] since the last reset.
static OOC_PEAK_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Design bytes currently resident in streaming block buffers. Process-
/// global (reader threads and block-parallel workers all count).
pub fn ooc_resident_bytes() -> usize {
    OOC_RESIDENT_BYTES.load(Ordering::Relaxed)
}

/// Peak block-buffer residency since the last [`ooc_reset_peak`] — the
/// materialization witness: a full solve on an [`OocDesign`] must keep
/// this at ≤ 2 serial blocks (or ≤ `threads` blocks on the parallel
/// reduction legs), never the full n×p design.
pub fn ooc_peak_resident_bytes() -> usize {
    OOC_PEAK_BYTES.load(Ordering::Relaxed)
}

/// Reset the peak-residency watermark to the current residency.
pub fn ooc_reset_peak() {
    OOC_PEAK_BYTES.store(OOC_RESIDENT_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// A witness-tracked streaming buffer: registers its capacity in
/// [`OOC_RESIDENT_BYTES`] on allocation and unregisters on drop, so the
/// peak watermark accounts for every byte of design data the kernels
/// ever hold.
struct BlockBuf {
    data: Vec<f64>,
}

impl BlockBuf {
    fn new(elems: usize) -> Self {
        let bytes = elems * 8;
        let cur = OOC_RESIDENT_BYTES.fetch_add(bytes, Ordering::Relaxed) + bytes;
        OOC_PEAK_BYTES.fetch_max(cur, Ordering::Relaxed);
        BlockBuf { data: vec![0.0; elems] }
    }
}

impl Drop for BlockBuf {
    fn drop(&mut self) {
        OOC_RESIDENT_BYTES.fetch_sub(self.data.capacity() * 8, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Positioned little-endian f64 IO
// ---------------------------------------------------------------------------

#[cfg(unix)]
fn pread(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, off)
}

#[cfg(unix)]
fn pwrite(file: &File, buf: &[u8], off: u64) -> io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.write_all_at(buf, off)
}

// Non-unix fallback: seek + read through the shared handle. Positioned
// reads from multiple threads then serialize on the file offset, which
// only costs throughput — every caller passes an explicit offset.
#[cfg(not(unix))]
fn pread(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = file;
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(buf)
}

#[cfg(not(unix))]
fn pwrite(file: &File, buf: &[u8], off: u64) -> io::Result<()> {
    use std::io::{Seek, SeekFrom};
    let mut f = file;
    f.seek(SeekFrom::Start(off))?;
    f.write_all(buf)
}

/// Positioned read of `out.len()` little-endian f64 values at byte `off`.
fn read_f64s_at(file: &File, out: &mut [f64], off: u64) -> io::Result<()> {
    // SAFETY: f64 is plain-old-data with no invalid bit patterns; the
    // byte view aliases `out` only for the duration of the read.
    let bytes =
        unsafe { std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), out.len() * 8) };
    pread(file, bytes, off)?;
    if cfg!(target_endian = "big") {
        for v in out.iter_mut() {
            *v = f64::from_bits(v.to_bits().swap_bytes());
        }
    }
    Ok(())
}

/// Positioned write of `vals` as little-endian f64 at byte `off`.
fn write_f64s_at(file: &File, vals: &[f64], off: u64) -> io::Result<()> {
    let mut staged = Vec::with_capacity(vals.len().min(8192) * 8);
    let mut at = off;
    for chunk in vals.chunks(8192) {
        staged.clear();
        for v in chunk {
            staged.extend_from_slice(&v.to_le_bytes());
        }
        pwrite(file, &staged, at)?;
        at += staged.len() as u64;
    }
    Ok(())
}

/// Incremental FNV-1a over f64 bits — streaming twin of
/// `linalg::content_hash`, so a packed file's header hash equals
/// `content_hash` of the same data in column-major order.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    fn update(&mut self, v: f64) {
        self.0 ^= v.to_bits();
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }
}

fn le_u64(bytes: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(bytes);
    u64::from_le_bytes(a)
}

// ---------------------------------------------------------------------------
// OocDesign
// ---------------------------------------------------------------------------

/// Shared immutable state behind an [`OocDesign`]: the open pack file and
/// its decoded header. `Arc`-wrapped so `DesignOps::Ooc` stays cheap to
/// clone (dataset caches, the serving pool) without duplicating stats.
#[derive(Debug)]
struct OocInner {
    file: File,
    path: PathBuf,
    n: usize,
    p: usize,
    /// Per-column standardization offsets (raw column means).
    offsets: Vec<f64>,
    /// Per-column standardization scales (centered ℓ₂ norms, clamped).
    scales: Vec<f64>,
    /// Full-content FNV hash from the header (computed at pack time).
    content_hash: u64,
    /// Byte offset of the column-major data section.
    data_off: u64,
}

/// A chunk-file-backed design streamed in fixed column blocks — the
/// third `DesignRef`/`DesignOps` kernel variant. See the module docs for
/// the format and the streaming/centering contract.
#[derive(Clone, Debug)]
pub struct OocDesign {
    inner: Arc<OocInner>,
}

impl OocDesign {
    /// Open and validate a pack file: magic, non-empty shape, exact file
    /// length, finite stats. O(p) — the data section is never read here.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<OocDesign> {
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)
            .map_err(|e| anyhow::anyhow!("cannot open pack file {}: {e}", path.display()))?;
        let mut head = [0u8; HEADER_BASE as usize];
        pread(&file, &mut head, 0)
            .map_err(|e| anyhow::anyhow!("{}: cannot read header: {e}", path.display()))?;
        if &head[0..8] != MAGIC {
            anyhow::bail!(
                "{}: not a dfr pack file (bad magic; create one with `dfr pack`)",
                path.display()
            );
        }
        let n = le_u64(&head[8..16]) as usize;
        let p = le_u64(&head[16..24]) as usize;
        let content_hash = le_u64(&head[24..32]);
        if n == 0 || p == 0 {
            return Err(DfrError::EmptyDesign { n, p }.into());
        }
        let stats_bytes = (p as u64)
            .checked_mul(16)
            .ok_or_else(|| anyhow::anyhow!("{}: implausible column count {p}", path.display()))?;
        let data_off = HEADER_BASE + stats_bytes;
        let data_bytes = (n as u64)
            .checked_mul(p as u64)
            .and_then(|c| c.checked_mul(8))
            .ok_or_else(|| anyhow::anyhow!("{}: implausible shape {n}×{p}", path.display()))?;
        let expect = data_off + data_bytes;
        let actual = file
            .metadata()
            .map_err(|e| anyhow::anyhow!("{}: cannot stat: {e}", path.display()))?
            .len();
        anyhow::ensure!(
            actual == expect,
            "{}: truncated or corrupt pack file ({actual} bytes, header implies {expect})",
            path.display()
        );
        let mut stats = vec![0.0f64; 2 * p];
        read_f64s_at(&file, &mut stats, HEADER_BASE)
            .map_err(|e| anyhow::anyhow!("{}: cannot read stats block: {e}", path.display()))?;
        let mut offsets = Vec::with_capacity(p);
        let mut scales = Vec::with_capacity(p);
        for j in 0..p {
            let (m, s) = (stats[2 * j], stats[2 * j + 1]);
            anyhow::ensure!(
                m.is_finite() && s.is_finite() && s > 0.0,
                "{}: corrupt standardization stats for column {j} (offset {m}, scale {s})",
                path.display()
            );
            offsets.push(m);
            scales.push(s);
        }
        Ok(OocDesign {
            inner: Arc::new(OocInner { file, path, n, p, offsets, scales, content_hash, data_off }),
        })
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.inner.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.inner.p
    }

    /// Per-column standardization offsets (raw means) from the header.
    pub fn offsets(&self) -> &[f64] {
        &self.inner.offsets
    }

    /// Per-column standardization scales (centered ℓ₂ norms) from the header.
    pub fn scales(&self) -> &[f64] {
        &self.inner.scales
    }

    /// Full-content FNV hash recorded at pack time — the O(1) identity
    /// key for the model API's prepared-design cache.
    pub fn content_hash(&self) -> u64 {
        self.inner.content_hash
    }

    /// The backing pack file.
    pub fn path(&self) -> &Path {
        &self.inner.path
    }

    /// Streaming block width in columns: programmatic override, then
    /// `DFR_OOC_BLOCK`, then [`DEFAULT_OOC_BLOCK_BYTES`] worth of rows —
    /// always clamped to `[1, p]`.
    pub fn block_cols(&self) -> usize {
        let chosen = match OOC_BLOCK_OVERRIDE.load(Ordering::Relaxed) {
            0 => match env_block_cols() {
                Some(c) => c,
                None => DEFAULT_OOC_BLOCK_BYTES / (8 * self.inner.n.max(1)),
            },
            o => o,
        };
        chosen.clamp(1, self.inner.p)
    }

    /// Bytes of one streaming block (`block_cols · n · 8`) — the unit the
    /// peak-residency witness is measured in.
    pub fn block_bytes(&self) -> usize {
        self.block_cols() * self.inner.n * 8
    }

    /// Positioned read of raw columns `first..first+cols` into `out`
    /// (column-major, `cols·n` values). Panics on IO failure — see the
    /// module docs for why kernels have no error channel.
    fn read_cols(&self, first: usize, cols: usize, out: &mut [f64]) {
        debug_assert!(first + cols <= self.inner.p);
        let off = self.inner.data_off + 8 * (first as u64) * (self.inner.n as u64);
        if let Err(e) = read_f64s_at(&self.inner.file, &mut out[..cols * self.inner.n], off) {
            panic!(
                "dfr ooc: reading columns {first}..{} of {} failed mid-pass: {e}",
                first + cols,
                self.inner.path.display()
            );
        }
    }

    /// Stream the raw columns of `cols` through `f(first_col, ncols,
    /// data)` block by block. With more than one block, a dedicated
    /// reader thread prefetches block k+1 while the caller consumes
    /// block k (two witness-tracked buffers rotating through a rendezvous
    /// channel — peak residency exactly 2 blocks); a single block is read
    /// inline with one buffer.
    fn stream_blocks<F: FnMut(usize, usize, &[f64])>(&self, cols: Range<usize>, mut f: F) {
        let n = self.inner.n;
        let total = cols.len();
        if total == 0 {
            return;
        }
        let bc = self.block_cols().min(total);
        if bc == total {
            let mut buf = BlockBuf::new(total * n);
            self.read_cols(cols.start, total, &mut buf.data);
            f(cols.start, total, &buf.data);
            return;
        }
        std::thread::scope(|s| {
            // `full` capacity 1: the reader keeps at most one finished
            // block queued while the caller consumes the other, so the
            // two buffers bound residency and the reader never races
            // ahead of the consumer.
            let (full_tx, full_rx) = mpsc::sync_channel::<(usize, usize, BlockBuf)>(1);
            let (free_tx, free_rx) = mpsc::channel::<BlockBuf>();
            for _ in 0..2 {
                let _ = free_tx.send(BlockBuf::new(bc * n));
            }
            let range = cols.clone();
            s.spawn(move || {
                let mut first = range.start;
                while first < range.end {
                    let take = bc.min(range.end - first);
                    let Ok(mut buf) = free_rx.recv() else { return };
                    self.read_cols(first, take, &mut buf.data[..take * n]);
                    if full_tx.send((first, take, buf)).is_err() {
                        return;
                    }
                    first += take;
                }
            });
            while let Ok((first, take, buf)) = full_rx.recv() {
                f(first, take, &buf.data[..take * n]);
                let _ = free_tx.send(buf);
            }
        });
    }

    /// Single-buffer block walk over `cols` for the block-parallel legs:
    /// each worker already overlaps another worker's IO, so no per-worker
    /// prefetch thread (residency: 1 block per worker).
    fn walk_blocks_noprefetch<F: FnMut(usize, usize, &[f64])>(&self, cols: Range<usize>, mut f: F) {
        let n = self.inner.n;
        if cols.is_empty() {
            return;
        }
        let bc = self.block_cols().min(cols.len());
        let mut buf = BlockBuf::new(bc * n);
        let mut first = cols.start;
        while first < cols.end {
            let take = bc.min(cols.end - first);
            self.read_cols(first, take, &mut buf.data[..take * n]);
            f(first, take, &buf.data[..take * n]);
            first += take;
        }
    }

    /// Per-column body shared by every transpose-matvec leg:
    /// `out[j] = (X[:,j]ᵀ r − μ_j · Σr) / s_j` for each column of a block.
    #[inline]
    fn t_matvec_block(&self, first: usize, take: usize, data: &[f64], r: &[f64], sr: f64, out0: usize, out: &mut [f64]) {
        let n = self.inner.n;
        for k in 0..take {
            let j = first + k;
            let s = kernels::dot(&data[k * n..(k + 1) * n], r);
            out[j - out0] = (s - self.inner.offsets[j] * sr) / self.inner.scales[j];
        }
    }

    /// `out = X̃ᵀ r` in one streaming pass with prefetch.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        let sr: f64 = r.iter().sum();
        self.block_t_matvec_with_rsum_into(0..self.inner.p, r, sr, out);
    }

    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.inner.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// Block-parallel `X̃ᵀ r`: above the `DFR_PAR_GRAIN` break-even the
    /// column range fans out over workers, each streaming its own blocks
    /// (per-column results are identical to the serial pass — same dot,
    /// same centering formula — so parallel ≡ serial bitwise on the
    /// scalar backend).
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        let (n, p) = (self.inner.n, self.inner.p);
        if threads <= 1 || n.saturating_mul(p) < par_grain() {
            self.t_matvec_into(r, out);
            return;
        }
        let sr: f64 = r.iter().sum();
        // Each worker owns a disjoint `out` chunk and reads the shared
        // file through positioned reads, so no synchronization beyond the
        // chunk split itself.
        for_each_chunk(out, threads, |start, chunk| {
            let end = start + chunk.len();
            self.walk_blocks_noprefetch(start..end, |first, take, data| {
                self.t_matvec_block(first, take, data, r, sr, start, chunk);
            });
        });
    }

    /// Group-block transpose matvec `out[k] = X̃[:, cols.start+k]ᵀ r`.
    pub fn block_t_matvec_into(&self, cols: Range<usize>, r: &[f64], out: &mut [f64]) {
        let sr: f64 = r.iter().sum();
        self.block_t_matvec_with_rsum_into(cols, r, sr, out);
    }

    /// Carried-sum variant: the caller already holds `rsum = Σᵢ rᵢ`.
    pub fn block_t_matvec_with_rsum_into(
        &self,
        cols: Range<usize>,
        r: &[f64],
        rsum: f64,
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), cols.len());
        let start = cols.start;
        self.stream_blocks(cols, |first, take, data| {
            self.t_matvec_block(first, take, data, r, rsum, start, out);
        });
    }

    /// `out = X̃β`, touching only blocks with nonzero support — after
    /// screening, β is sparse, so most blocks are never even read.
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        debug_assert_eq!(beta.len(), self.inner.p);
        debug_assert_eq!(out.len(), self.inner.n);
        out.iter_mut().for_each(|v| *v = 0.0);
        let n = self.inner.n;
        let bc = self.block_cols();
        let mut shift = 0.0;
        let mut buf: Option<BlockBuf> = None;
        let mut first = 0;
        while first < self.inner.p {
            let take = bc.min(self.inner.p - first);
            let blk = &beta[first..first + take];
            if blk.iter().any(|&b| b != 0.0) {
                let buf = buf.get_or_insert_with(|| BlockBuf::new(bc * n));
                self.read_cols(first, take, &mut buf.data[..take * n]);
                for (k, &b) in blk.iter().enumerate() {
                    if b == 0.0 {
                        continue;
                    }
                    let j = first + k;
                    let bs = b / self.inner.scales[j];
                    kernels::axpy(bs, &buf.data[k * n..(k + 1) * n], out);
                    shift += bs * self.inner.offsets[j];
                }
            }
            first += take;
        }
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.inner.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `X̃β` is IO-bound and support-skipping; row-parallel fan-out would
    /// multiply the reads, so the parallel entry point delegates to the
    /// serial streaming pass.
    pub fn matvec_par_into(&self, beta: &[f64], _threads: usize, out: &mut [f64]) {
        self.matvec_into(beta, out);
    }

    /// Group-block matvec `out += Σ_k coeffs[k] · X̃[:, cols.start+k]`.
    pub fn block_axpy_into(&self, cols: Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(coeffs.len(), cols.len());
        if coeffs.iter().all(|&c| c == 0.0) {
            return;
        }
        let n = self.inner.n;
        let start = cols.start;
        let mut shift = 0.0;
        self.walk_blocks_noprefetch(cols, |first, take, data| {
            for k in 0..take {
                let c = coeffs[first + k - start];
                if c == 0.0 {
                    continue;
                }
                let j = first + k;
                let cs = c / self.inner.scales[j];
                kernels::axpy(cs, &data[k * n..(k + 1) * n], out);
                shift += cs * self.inner.offsets[j];
            }
        });
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    /// Squared ℓ₂ norm of each implied standardized column, streaming:
    /// `‖X̃_j‖² = Σᵢ(xᵢⱼ − μ_j)² / s_j²` via the shifted one-pass form.
    pub fn col_sq_norms_cols(&self, cols: Range<usize>, out: &mut [f64]) {
        debug_assert_eq!(out.len(), cols.len());
        let n = self.inner.n;
        let start = cols.start;
        self.stream_blocks(cols, |first, take, data| {
            for k in 0..take {
                let j = first + k;
                let col = &data[k * n..(k + 1) * n];
                let mu = self.inner.offsets[j];
                let sum: f64 = col.iter().sum();
                let sq = kernels::dot(col, col);
                let centered = (sq - 2.0 * mu * sum + n as f64 * mu * mu).max(0.0);
                out[j - start] = centered / (self.inner.scales[j] * self.inner.scales[j]);
            }
        });
    }

    /// Parallel per-column squared norms (same break-even gating as the
    /// transpose matvec).
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        let (n, p) = (self.inner.n, self.inner.p);
        let threads = crate::parallel::default_threads();
        if threads <= 1 || n.saturating_mul(p) < par_grain() {
            self.col_sq_norms_cols(0..p, out);
            return;
        }
        for_each_chunk(out, threads, |start, chunk| {
            let end = start + chunk.len();
            self.walk_blocks_noprefetch(start..end, |first, take, data| {
                for k in 0..take {
                    let j = first + k;
                    let col = &data[k * n..(k + 1) * n];
                    let mu = self.inner.offsets[j];
                    let sum: f64 = col.iter().sum();
                    let sq = kernels::dot(col, col);
                    let centered = (sq - 2.0 * mu * sum + n as f64 * mu * mu).max(0.0);
                    chunk[j - start] = centered / (self.inner.scales[j] * self.inner.scales[j]);
                }
            });
        });
    }

    /// ℓ₂ norm of each implied standardized column.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.inner.p];
        self.col_sq_norms_into(&mut out);
        out.iter_mut().for_each(|v| *v = v.sqrt());
        out
    }

    /// Column means of the implied standardized design:
    /// `(mean_raw − μ_j) / s_j` (≈ 0 when stats came from this data).
    pub fn col_means(&self) -> Vec<f64> {
        let n = self.inner.n;
        let mut out = vec![0.0; self.inner.p];
        self.stream_blocks(0..self.inner.p, |first, take, data| {
            for k in 0..take {
                let j = first + k;
                let sum: f64 = data[k * n..(k + 1) * n].iter().sum();
                out[j] = (sum / n as f64 - self.inner.offsets[j]) / self.inner.scales[j];
            }
        });
        out
    }

    /// Power-iteration `‖X̃‖₂²` estimate through the shared
    /// [`super::DesignRef::op_norm_sq_est`] implementation.
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        super::DesignRef::Ooc(self).op_norm_sq_est(iters, seed)
    }

    /// Read one *standardized* column into `out` — the
    /// `ReducedDesign::gather` primitive that pulls active columns out of
    /// the store into the dense in-RAM reduced problem.
    pub fn read_standardized_col_into(&self, j: usize, out: &mut [f64]) {
        assert!(j < self.inner.p, "column {j} out of range (p = {})", self.inner.p);
        assert_eq!(out.len(), self.inner.n);
        self.read_cols(j, 1, out);
        let mu = self.inner.offsets[j];
        let s = self.inner.scales[j];
        // Divide (not multiply by a reciprocal) so a gathered column is
        // bitwise what `Matrix::standardize_l2` would have produced.
        out.iter_mut().for_each(|v| *v = (*v - mu) / s);
    }

    /// `out += X β` on the *raw* (unstandardized) columns — prediction on
    /// original-scale coefficients, support-skipping like
    /// [`OocDesign::matvec_into`].
    pub fn raw_matvec_acc_into(&self, beta: &[f64], out: &mut [f64]) {
        debug_assert_eq!(beta.len(), self.inner.p);
        debug_assert_eq!(out.len(), self.inner.n);
        let n = self.inner.n;
        let bc = self.block_cols();
        let mut buf: Option<BlockBuf> = None;
        let mut first = 0;
        while first < self.inner.p {
            let take = bc.min(self.inner.p - first);
            let blk = &beta[first..first + take];
            if blk.iter().any(|&b| b != 0.0) {
                let buf = buf.get_or_insert_with(|| BlockBuf::new(bc * n));
                self.read_cols(first, take, &mut buf.data[..take * n]);
                for (k, &b) in blk.iter().enumerate() {
                    if b != 0.0 {
                        kernels::axpy(b, &buf.data[k * n..(k + 1) * n], out);
                    }
                }
            }
            first += take;
        }
    }

    /// Scan every entry for non-finite values in one streaming pass
    /// (the `Design::validate_contents` hook; pack-time ingest already
    /// rejects them, so this only fires on hand-built files).
    pub fn validate_finite(&self) -> Result<(), DfrError> {
        let n = self.inner.n;
        let mut bad: Option<DfrError> = None;
        self.stream_blocks(0..self.inner.p, |first, take, data| {
            if bad.is_some() {
                return;
            }
            for k in 0..take {
                for (i, &v) in data[k * n..(k + 1) * n].iter().enumerate() {
                    if !v.is_finite() {
                        bad = Some(DfrError::NonFiniteDesign { row: i, col: first + k, value: v });
                        return;
                    }
                }
            }
        });
        match bad {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Mean / clamped centered-ℓ₂-norm of one column — the exact
/// [`Matrix::standardize_l2`] formulas (sequential sum, centered scratch,
/// dispatched `norm2`, `> 1e-12` clamp) so OOC stats match an in-memory
/// standardization of the same data. Returns `(mean, scale, is_constant)`.
fn column_stats(col: &[f64], scratch: &mut [f64]) -> (f64, f64, bool) {
    let n = col.len();
    let mean = col.iter().sum::<f64>() / n as f64;
    for (d, &v) in scratch[..n].iter_mut().zip(col) {
        *d = v - mean;
    }
    let nrm = norm2(&scratch[..n]);
    if nrm > 1e-12 {
        (mean, nrm, false)
    } else {
        (mean, 1.0, true)
    }
}

fn write_header(file: &File, n: usize, p: usize, hash: u64) -> io::Result<()> {
    let mut head = Vec::with_capacity(HEADER_BASE as usize);
    head.extend_from_slice(MAGIC);
    head.extend_from_slice(&(n as u64).to_le_bytes());
    head.extend_from_slice(&(p as u64).to_le_bytes());
    head.extend_from_slice(&hash.to_le_bytes());
    pwrite(file, &head, 0)
}

fn write_stats(file: &File, stats: &[(f64, f64)]) -> io::Result<()> {
    let flat: Vec<f64> = stats.iter().flat_map(|&(m, s)| [m, s]).collect();
    write_f64s_at(file, &flat, HEADER_BASE)
}

/// Pack an in-memory dense matrix into a `.dfrpack` file (tests, benches,
/// and programmatic ingest). Validates like the model API (non-finite and
/// all-constant rejection) and returns the opened design.
pub fn pack_matrix(x: &Matrix, path: impl AsRef<Path>) -> anyhow::Result<OocDesign> {
    let path = path.as_ref();
    let (n, p) = (x.nrows(), x.ncols());
    if n == 0 || p == 0 {
        return Err(DfrError::EmptyDesign { n, p }.into());
    }
    let mut stats = Vec::with_capacity(p);
    let mut scratch = vec![0.0; n];
    let mut constant_cols = 0;
    for j in 0..p {
        let col = x.col(j);
        for (i, &v) in col.iter().enumerate() {
            if !v.is_finite() {
                return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v }.into());
            }
        }
        let (mean, scale, is_const) = column_stats(col, &mut scratch);
        if is_const {
            constant_cols += 1;
        }
        stats.push((mean, scale));
    }
    if constant_cols == p {
        return Err(DfrError::AllColumnsConstant { p }.into());
    }
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(path)
        .map_err(|e| anyhow::anyhow!("cannot create pack file {}: {e}", path.display()))?;
    write_header(&file, n, p, super::content_hash(x.as_slice()))
        .and_then(|()| write_stats(&file, &stats))
        .and_then(|()| write_f64s_at(&file, x.as_slice(), HEADER_BASE + 16 * p as u64))
        .map_err(|e| anyhow::anyhow!("cannot write pack file {}: {e}", path.display()))?;
    drop(file);
    OocDesign::open(path)
}

/// Transposition staging budget for [`pack_csv`]'s row→column pass.
const TRANSPOSE_BUF_BYTES: usize = 32 << 20;

/// Parse one CSV record into `out`; returns false if any field fails to
/// parse (used for header detection on the first line).
fn parse_csv_row(line: &str, out: &mut Vec<f64>) -> bool {
    out.clear();
    for field in line.split(',') {
        match field.trim().parse::<f64>() {
            Ok(v) => out.push(v),
            Err(_) => return false,
        }
    }
    !out.is_empty()
}

/// Convert a CSV design (rows = observations, comma-separated columns,
/// optional header line) to the chunked pack format without ever holding
/// the design in memory — the `dfr pack` core.
///
/// Three bounded-memory passes:
/// 1. parse + validate, accumulate per-column sums (→ means);
/// 2. re-read, transposing row chunks (≤ 32 MiB) into positioned
///    column-strided writes of the data section;
/// 3. stream the written data section sequentially (= column order),
///    computing each column's centered norm and the full content hash,
///    then finalize the header.
pub fn pack_csv(csv: impl AsRef<Path>, out_path: impl AsRef<Path>) -> anyhow::Result<OocDesign> {
    let (csv, out_path) = (csv.as_ref(), out_path.as_ref());
    let open_csv = || -> anyhow::Result<BufReader<File>> {
        File::open(csv)
            .map(BufReader::new)
            .map_err(|e| anyhow::anyhow!("cannot open {}: {e}", csv.display()))
    };

    // Pass 1: shape + finiteness + column sums.
    let mut reader = open_csv()?;
    let mut line = String::new();
    let mut row = Vec::new();
    let mut sums: Vec<f64> = Vec::new();
    let mut n = 0usize;
    let mut header_lines = 0usize;
    let mut first_data_seen = false;
    loop {
        line.clear();
        if reader
            .read_line(&mut line)
            .map_err(|e| anyhow::anyhow!("{}: read error: {e}", csv.display()))?
            == 0
        {
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if !parse_csv_row(trimmed, &mut row) {
            // Only the leading line may be non-numeric (a header).
            anyhow::ensure!(
                !first_data_seen && header_lines == 0,
                "{}: row {} contains a non-numeric field",
                csv.display(),
                n + 1
            );
            header_lines = 1;
            continue;
        }
        if !first_data_seen {
            first_data_seen = true;
            sums = vec![0.0; row.len()];
        }
        if row.len() != sums.len() {
            return Err(DfrError::DimensionMismatch {
                what: "csv row width",
                expected: sums.len(),
                got: row.len(),
            }
            .into());
        }
        for (j, &v) in row.iter().enumerate() {
            if !v.is_finite() {
                return Err(DfrError::NonFiniteDesign { row: n, col: j, value: v }.into());
            }
            sums[j] += v;
        }
        n += 1;
    }
    let p = sums.len();
    if n == 0 || p == 0 {
        return Err(DfrError::EmptyDesign { n, p }.into());
    }
    let means: Vec<f64> = sums.iter().map(|s| s / n as f64).collect();

    // Pass 2: transpose row chunks into the data section.
    let data_off = HEADER_BASE + 16 * p as u64;
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(out_path)
        .map_err(|e| anyhow::anyhow!("cannot create pack file {}: {e}", out_path.display()))?;
    file.set_len(data_off + 8 * (n as u64) * (p as u64))
        .map_err(|e| anyhow::anyhow!("cannot size pack file {}: {e}", out_path.display()))?;
    let chunk_rows = (TRANSPOSE_BUF_BYTES / (8 * p)).clamp(1, n);
    let mut rowbuf: Vec<f64> = Vec::with_capacity(chunk_rows * p);
    let mut colstage: Vec<f64> = vec![0.0; chunk_rows];
    let mut reader = open_csv()?;
    for _ in 0..header_lines {
        line.clear();
        let _ = reader.read_line(&mut line);
    }
    let mut row0 = 0usize;
    let mut flush_chunk = |rowbuf: &mut Vec<f64>, row0: usize| -> anyhow::Result<()> {
        let rc = rowbuf.len() / p;
        for j in 0..p {
            for i in 0..rc {
                colstage[i] = rowbuf[i * p + j];
            }
            write_f64s_at(&file, &colstage[..rc], data_off + 8 * ((j * n + row0) as u64))
                .map_err(|e| anyhow::anyhow!("{}: write error: {e}", out_path.display()))?;
        }
        rowbuf.clear();
        Ok(())
    };
    loop {
        line.clear();
        if reader
            .read_line(&mut line)
            .map_err(|e| anyhow::anyhow!("{}: read error: {e}", csv.display()))?
            == 0
        {
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        anyhow::ensure!(
            parse_csv_row(trimmed, &mut row) && row.len() == p,
            "{}: file changed between packing passes",
            csv.display()
        );
        rowbuf.extend_from_slice(&row);
        if rowbuf.len() == chunk_rows * p {
            flush_chunk(&mut rowbuf, row0)?;
            row0 += chunk_rows;
        }
    }
    if !rowbuf.is_empty() {
        let rc = rowbuf.len() / p;
        flush_chunk(&mut rowbuf, row0)?;
        row0 += rc;
    }
    anyhow::ensure!(row0 == n, "{}: file changed between packing passes", csv.display());

    // Pass 3: sequential sweep of the data section (column order) —
    // centered norms + content hash — then finalize header and stats.
    let mut stats: Vec<(f64, f64)> = Vec::with_capacity(p);
    let mut hash = Fnv::new();
    let mut constant_cols = 0usize;
    let block = (DEFAULT_OOC_BLOCK_BYTES / (8 * n)).clamp(1, p);
    let mut buf = vec![0.0f64; block * n];
    let mut scratch = vec![0.0f64; n];
    let mut j0 = 0usize;
    while j0 < p {
        let take = block.min(p - j0);
        read_f64s_at(&file, &mut buf[..take * n], data_off + 8 * ((j0 * n) as u64))
            .map_err(|e| anyhow::anyhow!("{}: readback error: {e}", out_path.display()))?;
        for k in 0..take {
            let col = &buf[k * n..(k + 1) * n];
            for &v in col {
                hash.update(v);
            }
            let (mean, scale, is_const) = column_stats(col, &mut scratch);
            if is_const {
                constant_cols += 1;
            }
            stats.push((mean, scale));
        }
        j0 += take;
    }
    if constant_cols == p {
        return Err(DfrError::AllColumnsConstant { p }.into());
    }
    write_header(&file, n, p, hash.0)
        .and_then(|()| write_stats(&file, &stats))
        .map_err(|e| anyhow::anyhow!("cannot finalize pack file {}: {e}", out_path.display()))?;
    drop(file);
    OocDesign::open(out_path)
}
