//! Smooth loss functions `f(β)` for the SGL objective (Eq. 1).
//!
//! Two families, as in the paper's experiments: squared error
//! `(1/2n)‖y − Xβ‖₂²` for continuous responses, and mean logistic deviance
//! for binary responses (§D.6). Each exposes value, residual-style
//! intermediate, full gradient `∇f`, and a Lipschitz bound on `∇f` used to
//! seed the solvers' backtracking line search.

use crate::linalg::DesignRef;

/// Which loss to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    Squared,
    Logistic,
}

impl LossKind {
    pub fn for_response(r: crate::data::Response) -> LossKind {
        match r {
            crate::data::Response::Linear => LossKind::Squared,
            crate::data::Response::Logistic => LossKind::Logistic,
        }
    }
}

/// A smooth loss bound to a dataset. The design is held through the
/// [`DesignRef`] kernel contract, so the same loss (and everything above
/// it — solvers, screening, the pathwise coordinator) runs on a dense
/// standardized matrix or a centered-implicit sparse design unchanged.
#[derive(Clone)]
pub struct Loss<'a> {
    pub kind: LossKind,
    pub x: DesignRef<'a>,
    pub y: &'a [f64],
}

impl<'a> Loss<'a> {
    pub fn new(kind: LossKind, x: impl Into<DesignRef<'a>>, y: &'a [f64]) -> Self {
        let x = x.into();
        assert_eq!(x.nrows(), y.len());
        Loss { kind, x, y }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Loss value at `β` given precomputed `Xβ`.
    pub fn value_from_xb(&self, xb: &[f64]) -> f64 {
        let n = self.n() as f64;
        match self.kind {
            LossKind::Squared => {
                let mut s = 0.0;
                for (xi, yi) in xb.iter().zip(self.y) {
                    let r = yi - xi;
                    s += r * r;
                }
                s / (2.0 * n)
            }
            LossKind::Logistic => {
                // mean[ log(1 + e^η) − y·η ], computed stably.
                let mut s = 0.0;
                for (&eta, &yi) in xb.iter().zip(self.y) {
                    let log1p = if eta > 0.0 {
                        eta + (-eta).exp().ln_1p()
                    } else {
                        eta.exp().ln_1p()
                    };
                    s += log1p - yi * eta;
                }
                s / n
            }
        }
    }

    /// Loss value at `β`.
    pub fn value(&self, beta: &[f64]) -> f64 {
        self.value_from_xb(&self.x.matvec(beta))
    }

    /// The "residual" `r` such that `∇f(β) = Xᵀ r / n`:
    /// squared → `Xβ − y`; logistic → `σ(Xβ) − y`.
    pub fn residual_from_xb(&self, xb: &[f64], out: &mut [f64]) {
        self.residual_with_sum_from_xb(xb, out);
    }

    /// [`Loss::residual_from_xb`] fused with the residual sum `Σᵢ rᵢ` —
    /// one pass instead of two. The sum accumulates in element order, so
    /// it equals `out.iter().sum()` bit for bit; the centered-sparse
    /// block kernels reuse it across a whole epoch of BCD block updates
    /// ([`DesignRef::block_t_matvec_with_rsum_into`]) instead of
    /// recomputing the O(n) reduction per block.
    pub fn residual_with_sum_from_xb(&self, xb: &[f64], out: &mut [f64]) -> f64 {
        let mut sr = 0.0;
        match self.kind {
            LossKind::Squared => {
                for i in 0..xb.len() {
                    out[i] = xb[i] - self.y[i];
                    sr += out[i];
                }
            }
            LossKind::Logistic => {
                for i in 0..xb.len() {
                    out[i] = sigmoid(xb[i]) - self.y[i];
                    sr += out[i];
                }
            }
        }
        // Inert unless a test armed a fault plan (one relaxed atomic
        // load). A fired fault mutates the residual after the fused
        // accumulation, so recompute the sum to keep it consistent with
        // the poisoned buffer (the guardrails must see the NaN either
        // way).
        if crate::faults::poison_residual(out) {
            sr = out.iter().sum();
        }
        sr
    }

    /// Full gradient `∇f(β) = Xᵀ r(β) / n`.
    pub fn gradient(&self, beta: &[f64]) -> Vec<f64> {
        let xb = self.x.matvec(beta);
        self.gradient_from_xb(&xb)
    }

    /// Gradient given precomputed `Xβ` (threaded over columns).
    pub fn gradient_from_xb(&self, xb: &[f64]) -> Vec<f64> {
        let mut r = vec![0.0; self.n()];
        let mut g = vec![0.0; self.x.ncols()];
        self.gradient_from_xb_into(xb, &mut r, &mut g);
        g
    }

    /// `out = Xᵀ·residual(xb)/n` with caller-provided buffers — the
    /// allocation-free form the pathwise coordinator and the solvers use.
    /// `r_scratch` (length n) receives the residual as a side effect.
    pub fn gradient_from_xb_into(&self, xb: &[f64], r_scratch: &mut [f64], out: &mut [f64]) {
        self.residual_from_xb(xb, r_scratch);
        self.x.t_matvec_par_into(r_scratch, crate::parallel::default_threads(), out);
        let inv_n = 1.0 / self.n() as f64;
        out.iter_mut().for_each(|v| *v *= inv_n);
    }

    /// Upper bound on the Lipschitz constant of `∇f`:
    /// squared → `‖X‖₂²/n`; logistic → `‖X‖₂²/(4n)`.
    pub fn lipschitz_bound(&self) -> f64 {
        let opsq = self.x.op_norm_sq_est(30, 0xC0FFEE);
        let n = self.n() as f64;
        match self.kind {
            LossKind::Squared => opsq / n,
            LossKind::Logistic => opsq / (4.0 * n),
        }
    }
}

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Finite-difference gradient for testing.
#[cfg(test)]
pub fn fd_gradient(loss: &Loss, beta: &[f64], h: f64) -> Vec<f64> {
    let mut g = vec![0.0; beta.len()];
    let mut b = beta.to_vec();
    for j in 0..beta.len() {
        b[j] = beta[j] + h;
        let up = loss.value(&b);
        b[j] = beta[j] - h;
        let dn = loss.value(&b);
        b[j] = beta[j];
        g[j] = (up - dn) / (2.0 * h);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    fn problem(kind: LossKind, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(25, 8, |_, _| rng.gauss());
        let y: Vec<f64> = match kind {
            LossKind::Squared => rng.gauss_vec(25),
            LossKind::Logistic => {
                (0..25).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect()
            }
        };
        (x, y)
    }

    #[test]
    fn squared_gradient_matches_finite_difference() {
        let (x, y) = problem(LossKind::Squared, 1);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let mut rng = Rng::new(2);
        let beta = rng.gauss_vec(8);
        let g = loss.gradient(&beta);
        let fd = fd_gradient(&loss, &beta, 1e-6);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn logistic_gradient_matches_finite_difference() {
        let (x, y) = problem(LossKind::Logistic, 3);
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let mut rng = Rng::new(4);
        let beta: Vec<f64> = rng.gauss_vec(8).iter().map(|v| 0.3 * v).collect();
        let g = loss.gradient(&beta);
        let fd = fd_gradient(&loss, &beta, 1e-6);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sigmoid_stable_at_extremes() {
        assert!(sigmoid(800.0) <= 1.0 && sigmoid(800.0) > 0.999);
        assert!(sigmoid(-800.0) >= 0.0 && sigmoid(-800.0) < 1e-100);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn logistic_value_stable_at_large_eta() {
        let (x, y) = problem(LossKind::Logistic, 5);
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let beta = vec![100.0; 8];
        let v = loss.value(&beta);
        assert!(v.is_finite());
    }

    #[test]
    fn lipschitz_bound_dominates_gradient_variation() {
        let (x, y) = problem(LossKind::Squared, 7);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let l = loss.lipschitz_bound();
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let a = rng.gauss_vec(8);
            let b = rng.gauss_vec(8);
            let ga = loss.gradient(&a);
            let gb = loss.gradient(&b);
            let num = crate::linalg::l2_distance(&ga, &gb);
            let den = crate::linalg::l2_distance(&a, &b);
            assert!(num <= l * den * (1.0 + 1e-6), "{num} > {l}·{den}");
        }
    }

    #[test]
    fn gradient_of_zero_beta_is_minus_xty_over_n_for_squared() {
        let (x, y) = problem(LossKind::Squared, 9);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g = loss.gradient(&vec![0.0; 8]);
        let direct = x.t_matvec(&y);
        for (a, b) in g.iter().zip(&direct) {
            assert!((a + b / 25.0).abs() < 1e-12);
        }
    }

    #[test]
    fn value_from_xb_consistent_with_value() {
        let (x, y) = problem(LossKind::Logistic, 10);
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let beta = vec![0.1; 8];
        let xb = x.matvec(&beta);
        assert!((loss.value(&beta) - loss.value_from_xb(&xb)).abs() < 1e-14);
    }

    #[test]
    fn dot_sanity() {
        assert_eq!(crate::linalg::dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
