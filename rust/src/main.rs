//! `dfr` — launcher for the DFR sparse-group-lasso framework.
//!
//! Subcommands:
//!
//! * `fit`      — pathwise (a)SGL fit on synthetic or surrogate-real data
//!                with a chosen screening rule; prints paper-style metrics.
//! * `compare`  — screened vs no-screen paired run (improvement factor).
//! * `cv`       — workspace-pooled k-fold cross-validation, optionally
//!                over a joint `(α, γ)` grid (`--alphas` / `--gammas`),
//!                with per-cell screening stats and the 1-SE rule.
//! * `pack`     — convert a CSV design into a column-major `.dfrpack`
//!                file for out-of-core fitting (`fit --ooc`).
//! * `info`     — environment report (threads, kernel backends).

// Same no-panic discipline as the library (see lib.rs).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use dfr::cli::{parse_f64_list, parse_gamma_list, parse_rule, usage, Args, OptSpec};
use dfr::data::real::{RealDatasetKind, SurrogateConfig};
use dfr::error::{check_non_negative, check_range, DfrError};
use dfr::data::{Dataset, Response, SyntheticConfig};
use dfr::linalg::CscMatrix;
use dfr::model_api::{sparse_density_threshold, Design, SglFitter, SglModel, SparseMode};
use dfr::path::{compare_with_no_screen, PathConfig};
use dfr::report;
use dfr::solver::{SolverConfig, SolverKind};

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "rule", help: "screening rule: none|dfr|dfr-asgl|sparsegl|gap|gap-dyn|tlfre", default: Some("dfr"), takes_value: true },
        OptSpec { name: "dataset", help: "synthetic | brca1 | scheetz | trust-experts | adenoma | celiac | tumour", default: Some("synthetic"), takes_value: true },
        OptSpec { name: "scale", help: "surrogate real-data scale factor (0..1]", default: Some("0.1"), takes_value: true },
        OptSpec { name: "p", help: "synthetic: number of variables", default: Some("1000"), takes_value: true },
        OptSpec { name: "n", help: "synthetic: number of observations", default: Some("200"), takes_value: true },
        OptSpec { name: "alpha", help: "SGL mixing parameter", default: Some("0.95"), takes_value: true },
        OptSpec { name: "path-len", help: "number of λ path points", default: Some("50"), takes_value: true },
        OptSpec { name: "path-end", help: "λ_l/λ₁ ratio", default: Some("0.1"), takes_value: true },
        OptSpec { name: "gamma", help: "aSGL adaptive weight exponent γ₁=γ₂", default: None, takes_value: true },
        OptSpec { name: "solver", help: "inner solver: fista | atos | bcd (group-major block-coordinate descent)", default: Some("fista"), takes_value: true },
        OptSpec { name: "threads", help: "worker threads (overrides DFR_THREADS)", default: None, takes_value: true },
        OptSpec { name: "sparse", help: "CSC solve kernel: auto (density ≤ DFR_SPARSE_DENSITY, default 0.25) | on | off", default: Some("auto"), takes_value: true },
        OptSpec { name: "csc", help: "fit/cv: ingest the design as CSC sparse (exact zeros become implicit), letting --sparse route the solve kernel", default: None, takes_value: false },
        OptSpec { name: "folds", help: "cv: number of folds", default: Some("10"), takes_value: true },
        OptSpec { name: "alphas", help: "cv: comma-separated α grid (overrides --alpha)", default: None, takes_value: true },
        OptSpec { name: "gammas", help: "cv: comma-separated γ grid; entries are `none`, `g`, or `g1:g2`", default: None, takes_value: true },
        OptSpec { name: "one-se", help: "cv: select λ by the one-standard-error rule", default: None, takes_value: false },
        OptSpec { name: "seed", help: "RNG seed", default: Some("42"), takes_value: true },
        OptSpec { name: "logistic", help: "synthetic: logistic response", default: None, takes_value: false },
        OptSpec { name: "ooc", help: "fit: stream the design from a .dfrpack file (see `dfr pack`) instead of building one in RAM", default: None, takes_value: true },
        OptSpec { name: "y", help: "fit --ooc: response vector CSV (one value per line)", default: None, takes_value: true },
        OptSpec { name: "group-size", help: "fit --ooc: uniform group size (last group takes the remainder)", default: Some("10"), takes_value: true },
        OptSpec { name: "csv", help: "write per-path-point metrics CSV to this path", default: None, takes_value: true },
        OptSpec { name: "max-entries", help: "serve: LRU entry bound of each shared cache", default: Some("8"), takes_value: true },
        OptSpec { name: "max-bytes-mb", help: "serve: LRU byte bound of each shared cache (MiB)", default: Some("512"), takes_value: true },
        OptSpec { name: "batch-max", help: "serve: max requests admitted as one batch", default: Some("64"), takes_value: true },
        OptSpec { name: "help", help: "print help", default: None, takes_value: false },
    ]
}

fn main() {
    let specs = specs();
    let args = match Args::from_env(&specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage("dfr", ABOUT, &specs));
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional.is_empty() {
        println!("{}", usage("dfr <fit|compare|cv|serve|pack|info>", ABOUT, &specs));
        return;
    }
    let cmd = args.positional[0].clone();
    if let Err(e) = run(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const ABOUT: &str = "Dual Feature Reduction for the sparse-group lasso (ICML 2025) — \
pathwise fitting with bi-level strong screening";

fn build_dataset(args: &Args) -> anyhow::Result<Dataset> {
    let name = args.str_or("dataset", "synthetic");
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    if name == "synthetic" {
        let cfg = SyntheticConfig {
            p: args.usize_or("p", 1000).map_err(anyhow::Error::msg)?,
            n: args.usize_or("n", 200).map_err(anyhow::Error::msg)?,
            response: if args.flag("logistic") { Response::Logistic } else { Response::Linear },
            ..SyntheticConfig::default()
        };
        return Ok(cfg.generate(seed).dataset);
    }
    let kind = RealDatasetKind::ALL
        .into_iter()
        .find(|k| k.name() == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset `{name}`"))?;
    let scale = args.f64_or("scale", 0.1).map_err(anyhow::Error::msg)?;
    if !scale.is_finite() || scale <= 0.0 || scale > 1.0 {
        return Err(DfrError::InvalidParameter {
            name: "scale",
            value: scale,
            constraint: "in (0, 1]",
        }
        .into());
    }
    Ok(SurrogateConfig { kind, scale, seed }.generate())
}

fn build_path_config(args: &Args) -> anyhow::Result<PathConfig> {
    let solver_kind =
        SolverKind::parse(&args.str_or("solver", "fista")).map_err(anyhow::Error::msg)?;
    // `--gamma` parse failures are hard errors: the old behavior silently
    // substituted 0.1 for any typo, fitting a different model than asked.
    let adaptive = match args.options.get("gamma") {
        Some(raw) => {
            let g: f64 = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("--gamma: expected number, got `{raw}`"))?;
            check_non_negative("gamma", g)?;
            Some((g, g))
        }
        None => None,
    };
    let cfg = PathConfig {
        alpha: args.f64_or("alpha", 0.95).map_err(anyhow::Error::msg)?,
        path_len: args.usize_or("path-len", 50).map_err(anyhow::Error::msg)?,
        path_end_ratio: args.f64_or("path-end", 0.1).map_err(anyhow::Error::msg)?,
        solver: SolverConfig { kind: solver_kind, ..SolverConfig::default() },
        adaptive,
        ..PathConfig::default()
    };
    // Fail fast at the CLI boundary with a structured `DfrError` (α range,
    // path shape, tolerances) instead of deep inside the first solve.
    cfg.validate()?;
    Ok(cfg)
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    // `--threads` pins the worker count before any engine/pool is built;
    // the programmatic override beats the `DFR_THREADS` environment
    // variable by construction.
    if let Some(t) = args.options.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads: expected integer, got `{t}`"))?;
        anyhow::ensure!(n >= 1, "--threads: need at least one worker");
        dfr::parallel::set_thread_override(Some(n));
    }
    match cmd {
        "fit" => {
            if args.options.contains_key("ooc") {
                return fit_ooc(args);
            }
            let ds = build_dataset(args)?;
            let cfg = build_path_config(args)?;
            let rule = parse_rule(&args.str_or("rule", "dfr")).map_err(anyhow::Error::msg)?;
            let threads = dfr::parallel::default_threads();
            println!(
                "fitting {} (p={}, n={}, m={}) with {} [solver {}, {} thread{}{}, kernels {}] ...",
                ds.name,
                ds.p(),
                ds.n(),
                ds.m(),
                rule.name(),
                cfg.solver.kind.name(),
                threads,
                if threads == 1 { "" } else { "s" },
                if args.options.contains_key("threads") { ", --threads" } else { "" },
                dfr::linalg::kernels::describe(),
            );
            // Native fits go through the serving API: borrowed
            // zero-copy design straight into the fitter.
            let sparse =
                SparseMode::parse(&args.str_or("sparse", "auto")).map_err(anyhow::Error::msg)?;
            let model = SglModel {
                path: cfg,
                rule,
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                sparse,
                ..SglModel::default()
            };
            let mut fitter = model.fitter();
            let sizes = ds.groups.sizes();
            // `--csc` routes the design through the sparse ingest so
            // `--sparse` / DFR_SPARSE_DENSITY actually pick the solve
            // kernel; without it dense inputs always solve dense.
            let csc = args
                .flag("csc")
                .then(|| CscMatrix::from_dense(ds.x.dense(), 0.0));
            let fit = match &csc {
                Some(c) => fitter.fit_path(&Design::Csc(c), &ds.y, &sizes, ds.response)?,
                None => {
                    fitter.fit_path(&Design::Matrix(ds.x.dense()), &ds.y, &sizes, ds.response)?
                }
            };
            report_fit(&ds.name, rule.name(), fit, args)?;
            let density = csc
                .as_ref()
                .map(|c| format!(", csc density {:.4}", c.density()))
                .unwrap_or_default();
            println!(
                "[kernel] {} (sparse mode {:?}, density threshold {}{density})",
                fitter.kernel_variant().unwrap_or("dense"),
                sparse,
                sparse_density_threshold(),
            );
            Ok(())
        }
        "compare" => {
            let ds = build_dataset(args)?;
            let cfg = build_path_config(args)?;
            let rule = parse_rule(&args.str_or("rule", "dfr")).map_err(anyhow::Error::msg)?;
            let c = compare_with_no_screen(&ds, &cfg, rule)?;
            println!(
                "{}: improvement factor {:.2} (screen {:.3}s vs no-screen {:.3}s), \
                 input proportion {:.4}, ℓ₂ distance {:.2e}",
                rule.name(),
                c.improvement_factor,
                c.screened.metrics.total_seconds,
                c.no_screen.metrics.total_seconds,
                c.screened.metrics.input_proportion(),
                c.l2_distance,
            );
            let rec = report::run_record(
                &ds.name,
                rule.name(),
                &c.screened.metrics,
                Some(c.improvement_factor),
                Some(c.l2_distance),
            );
            println!("{}", rec.render());
            Ok(())
        }
        "cv" => {
            let ds = build_dataset(args)?;
            let model = SglModel {
                path: build_path_config(args)?,
                rule: parse_rule(&args.str_or("rule", "dfr")).map_err(anyhow::Error::msg)?,
                cv_folds: args.usize_or("folds", 10).map_err(anyhow::Error::msg)?,
                one_se_rule: args.flag("one-se"),
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                sparse: SparseMode::parse(&args.str_or("sparse", "auto"))
                    .map_err(anyhow::Error::msg)?,
            };
            let alphas = match args.options.get("alphas") {
                Some(s) => parse_f64_list(s).map_err(anyhow::Error::msg)?,
                None => vec![model.path.alpha],
            };
            for &a in &alphas {
                check_range("alphas", a, 0.0, 1.0, "in [0, 1]")?;
            }
            let gammas = match args.options.get("gammas") {
                Some(s) => parse_gamma_list(s).map_err(anyhow::Error::msg)?,
                None => vec![model.path.adaptive],
            };
            for (g1, g2) in gammas.iter().flatten() {
                check_non_negative("gammas", *g1)?;
                check_non_negative("gammas", *g2)?;
            }
            // The serving surface: a persistent fitter holding the pooled
            // CV engine, fed the dataset as a borrowed zero-copy design.
            let mut fitter = SglFitter::new(model.clone());
            let sizes = ds.groups.sizes();
            // As in `fit`: --csc routes the design through the sparse
            // ingest so --sparse can pick the solve kernel for CV too.
            let csc = args
                .flag("csc")
                .then(|| CscMatrix::from_dense(ds.x.dense(), 0.0));
            let design = match &csc {
                Some(c) => Design::Csc(c),
                None => Design::Matrix(ds.x.dense()),
            };
            let (cells, best) =
                fitter.cv_grid(&design, &ds.y, &sizes, ds.response, &alphas, &gammas)?;
            println!(
                "[kernel] {} (sparse mode {:?})",
                fitter.kernel_variant().unwrap_or("dense"),
                model.sparse,
            );
            let engine = fitter.cv_engine();
            println!(
                "cv({} folds, {} grid cell{}, {} thread{}{}, solver {}, kernels {}):",
                model.cv_folds,
                cells.len(),
                if cells.len() == 1 { "" } else { "s" },
                engine.threads(),
                if engine.threads() == 1 { "" } else { "s" },
                if args.options.contains_key("threads") { " via --threads" } else { "" },
                model.path.solver.kind.name(),
                dfr::linalg::kernels::describe(),
            );
            // Report the γ each cell actually fit with (an aSGL rule
            // forces γ=(0.1, 0.1) even when the spec says none).
            let fmt_gamma = |spec: Option<(f64, f64)>| match dfr::path::PathConfig::resolve_adaptive(spec, model.rule) {
                Some((g1, g2)) => format!("γ=({g1},{g2})"),
                None => "γ=none".to_string(),
            };
            for (i, cell) in cells.iter().enumerate() {
                let marker = if i == best { "  <-- best" } else { "" };
                let gamma = fmt_gamma(cell.gamma);
                println!(
                    "  α={:.3} {gamma}: loss {:.5} ± {:.5} at λ={:.5} (idx {}), \
                     1-SE λ={:.5} (idx {}), C_v/p {:.4}, O_v/p {:.4}, {:.2}s{marker}",
                    cell.alpha,
                    cell.cv_loss[cell.best_idx],
                    cell.cv_se[cell.best_idx],
                    cell.lambdas[cell.best_idx],
                    cell.best_idx,
                    cell.lambdas[cell.best_1se_idx],
                    cell.best_1se_idx,
                    cell.mean_candidate_proportion,
                    cell.mean_input_proportion,
                    cell.seconds,
                );
            }
            let w = &cells[best];
            let idx = if args.flag("one-se") { w.best_1se_idx } else { w.best_idx };
            println!(
                "selected: α={:.3}, {}, λ={:.5} (index {}{}), held-out loss {:.5}, status {}",
                w.alpha,
                fmt_gamma(w.gamma),
                w.lambdas[idx],
                idx,
                if args.flag("one-se") { ", 1-SE rule" } else { "" },
                w.cv_loss[idx],
                w.status,
            );
            println!(
                "workspace pool: {} workspace(s) served {} path fits",
                engine.pool_slots(),
                engine.pool_checkouts(),
            );
            Ok(())
        }
        "serve" => {
            let cfg = build_path_config(args)?;
            let rule = parse_rule(&args.str_or("rule", "dfr")).map_err(anyhow::Error::msg)?;
            let sparse = dfr::model_api::SparseMode::parse(&args.str_or("sparse", "auto"))
                .map_err(anyhow::Error::msg)?;
            let model = dfr::model_api::SglModel {
                path: cfg,
                rule,
                cv_folds: args.usize_or("folds", 10).map_err(anyhow::Error::msg)?,
                one_se_rule: args.flag("one-se"),
                seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
                sparse,
            };
            let max_entries = args.usize_or("max-entries", 8).map_err(anyhow::Error::msg)?;
            let max_mb = args.usize_or("max-bytes-mb", 512).map_err(anyhow::Error::msg)?;
            let batch_max = args.usize_or("batch-max", 64).map_err(anyhow::Error::msg)?;
            let threads = dfr::parallel::default_threads();
            let pool = dfr::serve::FitterPool::new(dfr::serve::PoolConfig {
                model,
                threads,
                max_entries,
                max_bytes: max_mb << 20,
            });
            eprintln!(
                "dfr serve: NDJSON on stdin/stdout (verbs fit|predict|cv|stats|evict|shutdown), \
                 {threads} thread{}, caches ≤{max_entries} entries / {max_mb} MiB each, \
                 batches ≤{batch_max}, kernels {}",
                if threads == 1 { "" } else { "s" },
                dfr::linalg::kernels::describe(),
            );
            let opts = dfr::serve::ServeOptions { batch_max };
            let mut stdout = std::io::stdout();
            let summary = dfr::serve::serve(&pool, std::io::stdin(), &mut stdout, &opts)?;
            eprintln!(
                "dfr serve: {} request(s) in {} batch(es), {}",
                summary.requests,
                summary.batches,
                if summary.shutdown { "shutdown verb" } else { "input EOF" },
            );
            Ok(())
        }
        "pack" => {
            let (src, dst) = match &args.positional[1..] {
                [src, dst] => (src, dst),
                _ => anyhow::bail!("usage: dfr pack <design.csv> <out.dfrpack>"),
            };
            let o = dfr::linalg::ooc::pack_csv(src, dst)?;
            println!(
                "packed {} -> {} (n={}, p={}, {} data bytes, content hash {:016x})",
                src,
                dst,
                o.nrows(),
                o.ncols(),
                o.nrows() * o.ncols() * 8,
                o.content_hash(),
            );
            Ok(())
        }
        "info" => {
            println!("dfr {}", env!("CARGO_PKG_VERSION"));
            println!("threads: {}", dfr::parallel::default_threads());
            println!(
                "kernels: {} (available: {})",
                dfr::linalg::kernels::describe(),
                dfr::linalg::kernels::available()
                    .iter()
                    .map(|b| b.name())
                    .collect::<Vec<_>>()
                    .join(", "),
            );
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}` (try --help)"),
    }
}

/// `fit --ooc <pack>`: stream the design from a `.dfrpack` file built by
/// `dfr pack`. The response comes from `--y` (one value per line); groups
/// are uniform `--group-size` blocks with the last taking the remainder.
/// Nothing `n × p`-sized is ever resident — the `[ooc]` line reports the
/// streaming block geometry and the peak block-buffer residency actually
/// observed during the fit.
fn fit_ooc(args: &Args) -> anyhow::Result<()> {
    let pack = match args.options.get("ooc") {
        Some(p) => p,
        None => anyhow::bail!("fit --ooc requires a pack file path"),
    };
    let y_path = match args.options.get("y") {
        Some(p) => p,
        None => anyhow::bail!("fit --ooc requires --y <csv> (one response value per line)"),
    };
    let cfg = build_path_config(args)?;
    let rule = parse_rule(&args.str_or("rule", "dfr")).map_err(anyhow::Error::msg)?;
    let design = dfr::linalg::OocDesign::open(pack)?;
    let y = read_response_csv(y_path)?;
    anyhow::ensure!(
        y.len() == design.nrows(),
        "--y has {} value(s) but the pack holds n={} observations",
        y.len(),
        design.nrows(),
    );
    let g = args.usize_or("group-size", 10).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(g >= 1, "--group-size: need at least 1");
    let p = design.ncols();
    let mut sizes = vec![g; p / g];
    if p % g != 0 {
        sizes.push(p % g);
    }
    let response = if args.flag("logistic") { Response::Logistic } else { Response::Linear };
    let threads = dfr::parallel::default_threads();
    println!(
        "fitting {pack} out-of-core (p={}, n={}, m={}) with {} [solver {}, {} thread{}, kernels {}] ...",
        p,
        design.nrows(),
        sizes.len(),
        rule.name(),
        cfg.solver.kind.name(),
        threads,
        if threads == 1 { "" } else { "s" },
        dfr::linalg::kernels::describe(),
    );
    let model = SglModel {
        path: cfg,
        rule,
        seed: args.u64_or("seed", 42).map_err(anyhow::Error::msg)?,
        ..SglModel::default()
    };
    let mut fitter = model.fitter();
    dfr::linalg::ooc_reset_peak();
    let fit = fitter.fit_path(&Design::Ooc(&design), &y, &sizes, response)?;
    report_fit(pack, rule.name(), fit, args)?;
    println!(
        "[ooc] kernel {}, block {} cols ({} MiB), peak resident {} MiB vs dense design {} MiB",
        fitter.kernel_variant().unwrap_or("ooc-stream"),
        design.block_cols(),
        design.block_bytes() >> 20,
        dfr::linalg::ooc_peak_resident_bytes() >> 20,
        (design.nrows() * p * 8) >> 20,
    );
    Ok(())
}

/// Read a response vector CSV: one numeric value per line, blank lines
/// ignored, a single non-numeric first line tolerated as a header.
fn read_response_csv(path: &str) -> anyhow::Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("--y {path}: {e}"))?;
    let mut y = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        match t.parse::<f64>() {
            Ok(v) => y.push(v),
            Err(_) if lineno == 0 => continue, // header line
            Err(_) => anyhow::bail!("--y {path}: line {} is not a number: `{t}`", lineno + 1),
        }
    }
    anyhow::ensure!(!y.is_empty(), "--y {path}: no numeric values found");
    Ok(y)
}

fn report_fit(
    name: &str,
    rule: &str,
    fit: &dfr::path::PathFit,
    args: &Args,
) -> anyhow::Result<()> {
    let m = &fit.metrics;
    println!(
        "done in {:.3}s: status {}, input proportion {:.4} (groups {:.4}), \
         KKT violations {}, failed convergences {}, active at end {}",
        m.total_seconds,
        m.worst_status(),
        m.input_proportion(),
        m.group_input_proportion(),
        m.total_kkt_violations(),
        m.failed_convergences(),
        fit.active_vars_last(),
    );
    if m.screening_fallback {
        println!(
            "[screening] {rule} has squared-loss certificates only: logistic \
             response fell back to full candidate sets (safe, but unscreened)"
        );
    }
    println!("{}", report::run_record(name, rule, m, None, None).render());
    if let Some(csv) = args.options.get("csv") {
        report::write_file(csv, &report::path_metrics_csv(m))?;
        println!("[csv] {csv}");
    }
    Ok(())
}
