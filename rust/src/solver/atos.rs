//! Adaptive Three Operator Splitting (Pedregosa & Gidel, ICML 2018) — the
//! solver used in the paper's experiments (§3).
//!
//! Davis–Yin splitting for `min f + g + h` with `f` smooth and `g`, `h`
//! proxable. For SGL we split the penalty into its ℓ1 part (`g`) and its
//! group-ℓ2 part (`h`), both with closed-form proxes. The step size adapts
//! by backtracking on the sufficient-decrease condition
//! `f(u_h) ≤ f(u_g) + ⟨∇f(u_g), u_h−u_g⟩ + ‖u_h−u_g‖²/(2γ)`.

use super::{ProxPenalty, SolveResult, SolverConfig};

use crate::loss::Loss;

pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let p = beta0.len();
    let n = loss.n();
    let lip = loss.lipschitz_bound().max(1e-12);
    let mut gamma = 1.0 / lip;

    let mut z = beta0.to_vec();
    let mut u_g = vec![0.0; p];
    let mut u_h = vec![0.0; p];
    let mut grad = vec![0.0; p];
    let mut arg = vec![0.0; p];
    let mut xb = vec![0.0; n];
    let mut r = vec![0.0; n];

    let mut iterations = 0;
    let mut converged = false;

    for it in 0..cfg.max_iters {
        iterations = it + 1;
        // u_g = prox_{γ·λ·h_group}(z)  (group part first; order is a free
        // choice in Davis–Yin — matching the exact-prox composition order).
        penalty.pen_prox_group_into(&z, gamma * lambda, &mut u_g);

        // ∇f(u_g)
        loss.x.matvec_into(&u_g, &mut xb);
        let f_ug = loss.value_from_xb(&xb);
        loss.residual_from_xb(&xb, &mut r);
        let g_full = loss.x.t_matvec_par(&r, crate::parallel::default_threads());
        let inv_n = 1.0 / n as f64;
        for j in 0..p {
            grad[j] = g_full[j] * inv_n;
        }

        // Backtracking on γ.
        let mut bt = 0;
        loop {
            for j in 0..p {
                arg[j] = 2.0 * u_g[j] - z[j] - gamma * grad[j];
            }
            penalty.pen_prox_l1_into(&arg, gamma * lambda, &mut u_h);
            let f_uh = loss.value(&u_h);
            let mut ip = 0.0;
            let mut dsq = 0.0;
            for j in 0..p {
                let d = u_h[j] - u_g[j];
                ip += grad[j] * d;
                dsq += d * d;
            }
            if f_uh <= f_ug + ip + dsq / (2.0 * gamma) + 1e-12 * f_ug.abs().max(1.0) {
                break;
            }
            bt += 1;
            if bt >= cfg.max_backtrack {
                break;
            }
            gamma *= cfg.backtrack;
        }

        // z update and fixed-point residual.
        let mut res = 0.0;
        for j in 0..p {
            let d = u_h[j] - u_g[j];
            z[j] += d;
            res += d * d;
        }
        let scale = u_g.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
        if res.sqrt() / scale <= cfg.tol {
            converged = true;
            break;
        }
    }

    // The primal iterate is u_h (it has passed through both proxes).
    let beta = u_h;
    let objective = super::objective(loss, penalty, lambda, &beta);
    SolveResult { beta, iterations, converged, objective }
}

#[cfg(test)]
mod tests {
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::Penalty;
    use crate::rng::Rng;
    use crate::solver::{SolverConfig, SolverKind};

    #[test]
    fn atos_matches_fista_on_random_problems() {
        let mut rng = Rng::new(10);
        for trial in 0..5 {
            let p = 12;
            let mut x = Matrix::from_fn(40, p, |_, _| rng.gauss());
            x.standardize_l2();
            let y: Vec<f64> = rng.gauss_vec(40);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let g = Groups::even(p, 4);
            let pen = Penalty::sgl(g.clone(), 0.9);
            let lam_max =
                crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
            let lambda = 0.25 * lam_max;
            let cfg_a = SolverConfig {
                kind: SolverKind::Atos,
                tol: 1e-10,
                max_iters: 30000,
                ..Default::default()
            };
            let cfg_f = SolverConfig { tol: 1e-10, max_iters: 30000, ..Default::default() };
            let ra = super::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_a);
            let rf = crate::solver::fista::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_f);
            assert!(
                (ra.objective - rf.objective).abs() < 1e-5 * (1.0 + rf.objective),
                "trial {trial}: atos {} fista {}",
                ra.objective,
                rf.objective
            );
        }
    }

    #[test]
    fn atos_null_model_above_lambda_max() {
        let mut rng = Rng::new(11);
        let p = 8;
        let mut x = Matrix::from_fn(30, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(30);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g = Groups::even(p, 4);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.95);
        let cfg = SolverConfig { kind: SolverKind::Atos, tol: 1e-10, max_iters: 30000, ..Default::default() };
        let r = super::solve(&loss, &pen, 1.05 * lam_max, &vec![0.0; p], &cfg);
        let nrm: f64 = r.beta.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(nrm < 1e-6, "norm {nrm}");
    }
}
