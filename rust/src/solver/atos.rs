//! Adaptive Three Operator Splitting (Pedregosa & Gidel, ICML 2018) — the
//! solver used in the paper's experiments (§3).
//!
//! Davis–Yin splitting for `min f + g + h` with `f` smooth and `g`, `h`
//! proxable, packaged as the [`Atos`] state machine behind the [`Solver`]
//! trait. For SGL we split the penalty into its ℓ1 part (`g`) and its
//! group-ℓ2 part (`h`), both with closed-form proxes. The step size adapts
//! by backtracking on the sufficient-decrease condition
//! `f(u_h) ≤ f(u_g) + ⟨∇f(u_g), u_h−u_g⟩ + ‖u_h−u_g‖²/(2γ)`.
//!
//! Like FISTA, all per-iteration state lives in the caller's
//! [`SolverWorkspace`] (`u_g` ↦ `beta_prev`, `u_h` ↦ `beta`, the reflected
//! argument ↦ `cand`), so the iteration and backtracking loops perform no
//! heap allocation.

use super::{ProxPenalty, SolveResult, SolveStatus, Solver, SolverConfig, SolverKind, SolverWorkspace};
use crate::linalg::norm2;
use crate::loss::Loss;

/// One-shot entry point (allocates a private workspace).
pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let mut ws = SolverWorkspace::new();
    solve_ws(loss, penalty, lambda, beta0, cfg, &mut ws)
}

/// Workspace entry point — the pathwise hot loop.
pub fn solve_ws<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    super::drive::<P, Atos<P>>(loss, penalty, lambda, beta0, cfg, ws)
}

/// ATOS iteration state (the adaptive step `γ` persists across steps; all
/// vector state lives in the workspace).
pub struct Atos<'a, P: ProxPenalty> {
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    cfg: &'a SolverConfig,
    gamma: f64,
    threads: usize,
    inv_n: f64,
    iterations: usize,
    converged: bool,
    /// Backtracking exhausted at least once: the step certificate is gone.
    failed: bool,
}

impl<'a, P: ProxPenalty> Solver<'a, P> for Atos<'a, P> {
    fn init(
        loss: &'a Loss<'a>,
        penalty: &'a P,
        lambda: f64,
        beta0: &[f64],
        cfg: &'a SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> Self {
        let p = beta0.len();
        let n = loss.n();
        debug_assert_eq!(p, loss.x.ncols());
        ws.resize(n, p);
        let lip = loss.lipschitz_bound().max(1e-12);

        ws.z.copy_from_slice(beta0);
        ws.beta.copy_from_slice(beta0); // u_h; returned as-is if max_iters == 0
        loss.x.matvec_par_into(&ws.beta, crate::parallel::default_threads(), &mut ws.xb_beta);

        Atos {
            loss,
            penalty,
            lambda,
            cfg,
            // `step_shrink` defaults to 1.0 (bit-identical); the
            // degradation ladder halves it on a fallback restart.
            gamma: cfg.step_shrink / lip,
            threads: crate::parallel::default_threads(),
            inv_n: 1.0 / n as f64,
            iterations: 0,
            converged: false,
            failed: false,
        }
    }

    fn step(&mut self, ws: &mut SolverWorkspace) {
        self.iterations += 1;
        // u_g = prox_{γ·λ·h_group}(z)  (group part first; order is a free
        // choice in Davis–Yin — matching the exact-prox composition order).
        self.penalty.pen_prox_group_into(&ws.z, self.gamma * self.lambda, &mut ws.beta_prev);

        // ∇f(u_g)
        self.loss.x.matvec_par_into(&ws.beta_prev, self.threads, &mut ws.xb);
        let f_ug = self.loss.value_from_xb(&ws.xb);
        self.loss.residual_from_xb(&ws.xb, &mut ws.r);
        self.loss.x.t_matvec_par_into(&ws.r, self.threads, &mut ws.grad);
        for g in ws.grad.iter_mut() {
            *g *= self.inv_n;
        }

        // Backtracking on γ.
        let mut bt = 0;
        loop {
            for (((c, &ug), &zj), &gj) in
                ws.cand.iter_mut().zip(&ws.beta_prev).zip(&ws.z).zip(&ws.grad)
            {
                *c = 2.0 * ug - zj - self.gamma * gj;
            }
            self.penalty.pen_prox_l1_into(&ws.cand, self.gamma * self.lambda, &mut ws.beta); // u_h
            self.loss.x.matvec_par_into(&ws.beta, self.threads, &mut ws.xb_cand);
            let f_uh = self.loss.value_from_xb(&ws.xb_cand);
            let mut ip = 0.0;
            let mut dsq = 0.0;
            for ((&uh, &ug), &gj) in ws.beta.iter().zip(&ws.beta_prev).zip(&ws.grad) {
                let d = uh - ug;
                ip += gj * d;
                dsq += d * d;
            }
            let forced = crate::faults::backtrack_must_fail(SolverKind::Atos);
            if !forced
                && f_uh <= f_ug + ip + dsq / (2.0 * self.gamma) + 1e-12 * f_ug.abs().max(1.0)
            {
                break;
            }
            bt += 1;
            if bt >= self.cfg.max_backtrack {
                // Exhausted: accept the candidate, but flag the lost step
                // certificate for the driver's ladder.
                self.failed = true;
                break;
            }
            self.gamma *= self.cfg.backtrack;
        }
        // The last evaluated candidate is the accepted u_h.
        std::mem::swap(&mut ws.xb_beta, &mut ws.xb_cand);

        // z update and fixed-point residual.
        let mut res = 0.0;
        for ((zj, &uh), &ug) in ws.z.iter_mut().zip(&ws.beta).zip(&ws.beta_prev) {
            let d = uh - ug;
            *zj += d;
            res += d * d;
        }
        let scale = norm2(&ws.beta_prev).max(1.0);
        if res.sqrt() / scale <= self.cfg.tol {
            self.converged = true;
        }
    }

    fn converged(&self) -> bool {
        self.converged
    }

    fn objective(&self, ws: &SolverWorkspace) -> f64 {
        // The primal iterate is u_h (it has passed through both proxes);
        // `xb_beta` tracks it, so the objective costs no matvec.
        self.loss.value_from_xb(&ws.xb_beta) + self.lambda * self.penalty.pen_value(&ws.beta)
    }

    fn failed(&self) -> bool {
        self.failed
    }

    fn extract(&self, ws: &SolverWorkspace) -> SolveResult {
        SolveResult {
            beta: ws.beta.clone(),
            iterations: self.iterations,
            status: if self.converged { SolveStatus::Converged } else { SolveStatus::MaxIters },
            objective: self.objective(ws),
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::Penalty;
    use crate::rng::Rng;
    use crate::solver::{SolverConfig, SolverKind, SolverWorkspace};

    #[test]
    fn atos_matches_fista_on_random_problems() {
        let mut rng = Rng::new(10);
        for trial in 0..5 {
            let p = 12;
            let mut x = Matrix::from_fn(40, p, |_, _| rng.gauss());
            x.standardize_l2();
            let y: Vec<f64> = rng.gauss_vec(40);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let g = Groups::even(p, 4);
            let pen = Penalty::sgl(g.clone(), 0.9);
            let lam_max =
                crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
            let lambda = 0.25 * lam_max;
            let cfg_a = SolverConfig {
                kind: SolverKind::Atos,
                tol: 1e-10,
                max_iters: 30000,
                ..Default::default()
            };
            let cfg_f = SolverConfig { tol: 1e-10, max_iters: 30000, ..Default::default() };
            let ra = super::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_a);
            let rf = crate::solver::fista::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_f);
            assert!(
                (ra.objective - rf.objective).abs() < 1e-5 * (1.0 + rf.objective),
                "trial {trial}: atos {} fista {}",
                ra.objective,
                rf.objective
            );
        }
    }

    #[test]
    fn atos_null_model_above_lambda_max() {
        let mut rng = Rng::new(11);
        let p = 8;
        let mut x = Matrix::from_fn(30, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(30);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g = Groups::even(p, 4);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.95);
        let cfg = SolverConfig { kind: SolverKind::Atos, tol: 1e-10, max_iters: 30000, ..Default::default() };
        let r = super::solve(&loss, &pen, 1.05 * lam_max, &vec![0.0; p], &cfg);
        let nrm: f64 = r.beta.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(nrm < 1e-6, "norm {nrm}");
    }

    #[test]
    fn atos_workspace_reuse_is_exact() {
        let mut rng = Rng::new(12);
        let p = 10;
        let mut x = Matrix::from_fn(35, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(35);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::even(p, 5), 0.9);
        let cfg = SolverConfig { kind: SolverKind::Atos, ..Default::default() };
        let mut ws = SolverWorkspace::new();
        let first = super::solve_ws(&loss, &pen, 0.05, &vec![0.0; p], &cfg, &mut ws);
        let reused = super::solve_ws(&loss, &pen, 0.05, &vec![0.0; p], &cfg, &mut ws);
        assert_eq!(first.beta, reused.beta, "dirty workspace changed ATOS result");
        assert_eq!(first.iterations, reused.iterations);
    }
}
