//! Inner solvers for the SGL / aSGL optimization (Eq. 1) — a solver
//! *subsystem* behind the step-driven [`Solver`] trait.
//!
//! Three algorithms, all warm-startable, all holding their per-iteration
//! state in a caller-provided [`SolverWorkspace`]:
//!
//! * [`fista`] — accelerated proximal gradient with the *exact* sparse-group
//!   prox (soft-threshold → group-shrink) and backtracking line search.
//!   Default engine: the exact prox makes it both faster and more accurate
//!   than splitting for this penalty.
//! * [`atos`] — Adaptive Three Operator Splitting (Pedregosa & Gidel,
//!   2018), the algorithm the paper's experiments use; splits the penalty
//!   into its ℓ1 and group-ℓ2 parts, each with a closed-form prox.
//! * [`bcd`] — proximal block-coordinate descent in the style of the
//!   `sparsegl` solver (Liang et al. '22) and the Friedman–Hastie–
//!   Tibshirani note: cycles over groups with per-group Lipschitz
//!   constants, residual-carried block updates through the
//!   [`crate::linalg::DesignRef`] block kernels, and an active-group epoch
//!   schedule (full sweep → active epochs → certifying full sweep).
//!
//! Each algorithm is a state machine implementing [`Solver`]
//! (`init` from workspace → `step` → `converged` → `extract`); [`drive`]
//! is the shared iteration driver and [`solve_ws`] dispatches a
//! [`SolverKind`] through it. Screening is solver-agnostic (the paper
//! stresses DFR works with any fitting algorithm); the pathwise
//! coordinator takes [`SolverKind`] as a parameter and the benches pin one
//! solver across all rules so improvement factors are solver-independent.

pub mod atos;
pub mod bcd;
pub mod fista;

use crate::groups::Groups;
use crate::loss::Loss;
use crate::penalty::{Penalty, RestrictedPenalty};

/// Penalty interface the solvers need. Implemented by the full [`Penalty`]
/// and by [`RestrictedPenalty`] (screening-reduced problems).
///
/// The block accessors expose the grouping that tiles the coordinate
/// vector and the exact prox of one group's block — the contract the BCD
/// solver cycles over (whole-vector solvers ignore them).
pub trait ProxPenalty {
    fn pen_value(&self, beta: &[f64]) -> f64;
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    /// Grouping structure the penalty is defined over; its blocks tile the
    /// coordinate vector exactly.
    fn pen_groups(&self) -> &Groups;
    /// Exact prox restricted to group `g`'s block (`z`/`out` are the block
    /// slices of length `p_g`).
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]);
}

impl ProxPenalty for Penalty {
    fn pen_value(&self, beta: &[f64]) -> f64 {
        self.value(beta)
    }
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_into(z, t_lambda, out)
    }
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_l1_into(z, t_lambda, out)
    }
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_group_into(z, t_lambda, out)
    }
    fn pen_groups(&self) -> &Groups {
        &self.groups
    }
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_block_into(g, z, t_lambda, out)
    }
}

impl ProxPenalty for RestrictedPenalty {
    fn pen_value(&self, beta: &[f64]) -> f64 {
        self.value(beta)
    }
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_into(z, t_lambda, out)
    }
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_l1_into(z, t_lambda, out)
    }
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_group_into(z, t_lambda, out)
    }
    fn pen_groups(&self) -> &Groups {
        &self.groups
    }
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_block_into(g, z, t_lambda, out)
    }
}

/// Choice of inner solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Fista,
    Atos,
    /// Group-major proximal block-coordinate descent ([`bcd`]).
    Bcd,
}

impl SolverKind {
    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Fista => "fista",
            SolverKind::Atos => "atos",
            SolverKind::Bcd => "bcd",
        }
    }

    /// Parse a CLI-style solver name (`fista` | `atos` | `bcd`).
    pub fn parse(s: &str) -> Result<SolverKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "fista" => Ok(SolverKind::Fista),
            "atos" => Ok(SolverKind::Atos),
            "bcd" | "blockcd" | "block-cd" => Ok(SolverKind::Bcd),
            other => Err(format!("unknown solver `{other}` (fista|atos|bcd)")),
        }
    }
}

/// How a solve ended — the taxonomy every layer above the solvers
/// (path coordinator, CV engine, fitter, CLI) carries instead of a bare
/// `converged` flag.
///
/// Ordered by *severity* ([`SolveStatus::severity`]): aggregations over
/// several solves (KKT re-entry rounds, CV fold batches) keep the worst
/// status seen. The first three variants are **successes** — the returned
/// β satisfies the stopping criterion, possibly via a degraded route; the
/// rest are failures where the returned β is the best iterate available
/// but carries no optimality certificate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    /// Stopping criterion met by the configured solver.
    Converged,
    /// The configured solver failed (backtracking exhaustion or
    /// divergence) and the degradation ladder's restart — `to` with a
    /// halved step, warm-started from the last finite iterate — met the
    /// stopping criterion instead.
    FellBack { from: SolverKind, to: SolverKind },
    /// The KKT re-entry cap was exhausted and the coordinator escalated
    /// to a full no-screening solve at that λ. The solution is certified
    /// (it was solved over *all* variables); the screening rule's
    /// efficiency claim is what degraded.
    KktCapHit,
    /// Iteration budget exhausted before the stopping criterion.
    MaxIters,
    /// No objective progress for `stall_window` consecutive iterations.
    Stalled,
    /// The wall-clock budget (`max_seconds`) — or an externally truncated
    /// iteration budget — ran out; β is the best iterate seen so far.
    BudgetExhausted,
    /// The objective became non-finite or rose persistently; β is the
    /// best finite iterate seen before divergence.
    Diverged,
}

impl Default for SolveStatus {
    fn default() -> Self {
        SolveStatus::Converged
    }
}

impl SolveStatus {
    /// Severity rank (0 = clean convergence, 6 = divergence). Used by
    /// [`SolveStatus::worst`] to aggregate across solves.
    pub fn severity(&self) -> u8 {
        match self {
            SolveStatus::Converged => 0,
            SolveStatus::FellBack { .. } => 1,
            SolveStatus::KktCapHit => 2,
            SolveStatus::MaxIters => 3,
            SolveStatus::Stalled => 4,
            SolveStatus::BudgetExhausted => 5,
            SolveStatus::Diverged => 6,
        }
    }

    /// The more severe of the two statuses (ties keep `self`).
    pub fn worst(self, other: SolveStatus) -> SolveStatus {
        if other.severity() > self.severity() {
            other
        } else {
            self
        }
    }

    /// Did the solve produce a certified solution (possibly degraded)?
    /// `true` for [`SolveStatus::Converged`], [`SolveStatus::FellBack`]
    /// and [`SolveStatus::KktCapHit`].
    pub fn is_success(&self) -> bool {
        self.severity() <= SolveStatus::KktCapHit.severity()
    }

    /// Stable machine-readable label (CSV/JSON columns).
    pub fn label(&self) -> &'static str {
        match self {
            SolveStatus::Converged => "converged",
            SolveStatus::FellBack { .. } => "fell_back",
            SolveStatus::KktCapHit => "kkt_cap_hit",
            SolveStatus::MaxIters => "max_iters",
            SolveStatus::Stalled => "stalled",
            SolveStatus::BudgetExhausted => "budget_exhausted",
            SolveStatus::Diverged => "diverged",
        }
    }
}

impl std::fmt::Display for SolveStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveStatus::FellBack { from, to } => {
                write!(f, "fell back ({}→{})", from.name(), to.name())
            }
            other => f.write_str(other.label()),
        }
    }
}

/// Solver settings; defaults follow Table A1's algorithm block
/// (max 5000 iterations, backtracking 0.7 with 100 inner steps,
/// convergence tolerance 1e-5). The guardrail fields default to "off"
/// (`step_shrink` 1, no wall-clock budget, no stall window), so default
/// configurations are bit-identical to the pre-guardrail solver.
#[derive(Clone, Debug, PartialEq)]
pub struct SolverConfig {
    pub kind: SolverKind,
    pub max_iters: usize,
    pub tol: f64,
    /// Backtracking shrink factor on the step size (paper: 0.7).
    pub backtrack: f64,
    pub max_backtrack: usize,
    /// Multiplier on the initial step size (`1/L̂ · step_shrink`). The
    /// degradation ladder halves it on each fallback; 1.0 = untouched.
    pub step_shrink: f64,
    /// Wall-clock budget per solve in seconds (checked every 32
    /// iterations); ∞ = unlimited. On exhaustion the solve returns
    /// [`SolveStatus::BudgetExhausted`] with the best iterate seen.
    pub max_seconds: f64,
    /// Declare [`SolveStatus::Stalled`] after this many consecutive
    /// iterations with no new best objective; 0 disables the check (the
    /// default — enable it for long-running serving workloads).
    pub stall_window: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            kind: SolverKind::Fista,
            max_iters: 5000,
            tol: 1e-5,
            backtrack: 0.7,
            max_backtrack: 100,
            step_shrink: 1.0,
            max_seconds: f64::INFINITY,
            stall_window: 0,
        }
    }
}

/// Result of an inner solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub beta: Vec<f64>,
    pub iterations: usize,
    /// How the solve ended (see [`SolveStatus`]).
    pub status: SolveStatus,
    /// Final primal objective value `f(β) + λΩ(β)`.
    pub objective: f64,
}

impl SolveResult {
    /// Did the solve meet its stopping criterion (directly or through the
    /// degradation ladder's fallback)?
    pub fn converged(&self) -> bool {
        matches!(self.status, SolveStatus::Converged | SolveStatus::FellBack { .. })
    }
}

/// Reusable buffers for the inner solvers.
///
/// Every vector FISTA/ATOS needs per iteration lives here, pre-sized by
/// [`SolverWorkspace::resize`] at solve entry. Capacity is grow-only, so a
/// workspace carried across λ steps (and KKT re-entry rounds) stops
/// allocating once it has seen the largest problem on the path — the
/// iteration and backtracking loops themselves are allocation-free.
#[derive(Clone, Debug, Default)]
pub struct SolverWorkspace {
    /// `Xz` at the extrapolated/prox point driving the gradient.
    pub(crate) xb: Vec<f64>,
    /// `Xβ` of the candidate evaluated during backtracking.
    pub(crate) xb_cand: Vec<f64>,
    /// `Xβ` at the accepted iterate (exposed via [`SolverWorkspace::fitted`]).
    pub(crate) xb_beta: Vec<f64>,
    /// Residual scratch (length n).
    pub(crate) r: Vec<f64>,
    /// Gradient at the current point (length p).
    pub(crate) grad: Vec<f64>,
    /// Gradient-step argument (FISTA) / reflected argument (ATOS).
    pub(crate) cand: Vec<f64>,
    /// Prox output: FISTA's candidate iterate / ATOS's `u_h`.
    pub(crate) next: Vec<f64>,
    /// Current iterate.
    pub(crate) beta: Vec<f64>,
    /// Previous iterate (FISTA) / ATOS's `u_g`.
    pub(crate) beta_prev: Vec<f64>,
    /// Extrapolated / splitting state.
    pub(crate) z: Vec<f64>,
    /// BCD: squared ℓ₂ norm of every design column (length p), cached once
    /// per solve from [`crate::linalg::DesignRef::col_sq_norms_into`].
    pub(crate) col_sq: Vec<f64>,
    /// BCD: per-group block Lipschitz estimates (length m), seeded from
    /// the column-norm cache and grown in place by per-block backtracking.
    pub(crate) group_lip: Vec<f64>,
    /// BCD: the active-group list of the current epoch.
    pub(crate) groups_active: Vec<usize>,
    /// Guardrails: β at the best finite objective seen this solve.
    pub(crate) best_beta: Vec<f64>,
    /// Guardrails: `Xβ` matching `best_beta` (carried-fitted contract).
    pub(crate) best_xb: Vec<f64>,
    /// Whether `best_beta`/`best_xb` hold a snapshot from the current solve.
    pub(crate) best_valid: bool,
}

impl SolverWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for an (n × p) problem. Shrinking keeps capacity.
    pub fn resize(&mut self, n: usize, p: usize) {
        fn fit(v: &mut Vec<f64>, len: usize) {
            v.clear();
            v.resize(len, 0.0);
        }
        fit(&mut self.xb, n);
        fit(&mut self.xb_cand, n);
        fit(&mut self.xb_beta, n);
        fit(&mut self.r, n);
        fit(&mut self.grad, p);
        fit(&mut self.cand, p);
        fit(&mut self.next, p);
        fit(&mut self.beta, p);
        fit(&mut self.beta_prev, p);
        fit(&mut self.z, p);
    }

    /// Snapshot the current iterate (and its carried fitted values) as the
    /// best seen this solve. `clear` + `extend` keeps capacity, so the
    /// snapshot is allocation-free once the buffers have grown.
    pub(crate) fn snapshot_best(&mut self) {
        self.best_beta.clear();
        self.best_beta.extend_from_slice(&self.beta);
        self.best_xb.clear();
        self.best_xb.extend_from_slice(&self.xb_beta);
        self.best_valid = true;
    }

    /// Final iterate of the last solve.
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Fitted values `Xβ` at the final iterate of the last solve.
    pub fn fitted(&self) -> &[f64] {
        &self.xb_beta
    }
}

/// One inner algorithm as a step-driven state machine.
///
/// The lifecycle is fixed by [`drive`]: `init` sizes the workspace and
/// builds iteration state from the warm start, `step` advances one
/// iteration (one group sweep for BCD), `converged` reports the stopping
/// test, and `extract` packages the final iterate — whose fitted values
/// `Xβ` every implementation must leave in `ws.xb_beta` (the pathwise
/// coordinator's residual-carry contract, see
/// [`SolverWorkspace::fitted`]).
pub trait Solver<'a, P: ProxPenalty>: Sized {
    /// Build iteration state in `ws` from the warm start `beta0`.
    fn init(
        loss: &'a Loss<'a>,
        penalty: &'a P,
        lambda: f64,
        beta0: &[f64],
        cfg: &'a SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> Self;

    /// Advance one iteration.
    fn step(&mut self, ws: &mut SolverWorkspace);

    /// Has the stopping criterion been met?
    fn converged(&self) -> bool;

    /// Primal objective `f(β) + λΩ(β)` at the current iterate, computed
    /// from the carried fitted values (no matvec). The driver's numerical
    /// guardrails observe this every iteration.
    fn objective(&self, ws: &SolverWorkspace) -> f64;

    /// Did the solver lose its step-size certificate (backtracking
    /// exhausted)? A `true` here makes the driver distrust `converged`
    /// and engage the degradation ladder.
    fn failed(&self) -> bool {
        false
    }

    /// Package the final iterate held in `ws`.
    fn extract(&self, ws: &SolverWorkspace) -> SolveResult;
}

/// Consecutive objective observations ≥ `DIVERGE_FACTOR` above the best
/// before the driver declares divergence.
const DIVERGE_PATIENCE: usize = 8;
/// How far (relative) above the best objective counts as "rising".
const DIVERGE_FACTOR: f64 = 1e4;
/// Iterations between wall-clock budget checks.
const CLOCK_CHECK_EVERY: usize = 32;

/// The raw iteration loop with numerical guardrails: `init`, then `step`
/// until `converged`, divergence, stall, or a budget runs out, then
/// `extract`. Returns the result plus the solver's failure flag. No
/// fallback here — [`drive`] owns the degradation ladder.
///
/// Guardrails only *observe* on the healthy path (the per-iteration
/// objective and best-iterate snapshots never touch the iterate), so a
/// converging run is bit-identical to the pre-guardrail driver. Only the
/// degraded exits (`Diverged` / `Stalled` / `BudgetExhausted`) replace
/// the current iterate with the best finite one seen.
fn drive_core<'a, P: ProxPenalty, S: Solver<'a, P>>(
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    beta0: &[f64],
    cfg: &'a SolverConfig,
    ws: &mut SolverWorkspace,
) -> (SolveResult, bool) {
    let start = std::time::Instant::now();
    let budget = match crate::faults::iteration_cap() {
        Some(cap) => cap.min(cfg.max_iters),
        None => cfg.max_iters,
    };
    let mut state = S::init(loss, penalty, lambda, beta0, cfg, ws);
    ws.best_valid = false;
    let mut status = SolveStatus::MaxIters;
    let mut best_obj = f64::INFINITY;
    let mut rising = 0usize;
    let mut since_best = 0usize;
    let mut done = 0usize;
    while done < budget {
        state.step(ws);
        done += 1;
        if state.converged() {
            status = SolveStatus::Converged;
            break;
        }
        let obj = state.objective(ws);
        if !obj.is_finite() {
            status = SolveStatus::Diverged;
            break;
        }
        if obj < best_obj {
            best_obj = obj;
            rising = 0;
            since_best = 0;
            ws.snapshot_best();
        } else {
            since_best += 1;
            if obj > best_obj + DIVERGE_FACTOR * best_obj.abs().max(1.0) {
                rising += 1;
                if rising >= DIVERGE_PATIENCE {
                    status = SolveStatus::Diverged;
                    break;
                }
            } else {
                rising = 0;
            }
        }
        if cfg.stall_window > 0 && since_best >= cfg.stall_window {
            status = SolveStatus::Stalled;
            break;
        }
        if cfg.max_seconds.is_finite()
            && done % CLOCK_CHECK_EVERY == 0
            && start.elapsed().as_secs_f64() >= cfg.max_seconds
        {
            status = SolveStatus::BudgetExhausted;
            break;
        }
    }
    if status == SolveStatus::MaxIters && budget < cfg.max_iters {
        // An externally truncated (fault-injected) budget ran out.
        status = SolveStatus::BudgetExhausted;
    }
    if matches!(
        status,
        SolveStatus::Diverged | SolveStatus::Stalled | SolveStatus::BudgetExhausted
    ) && ws.best_valid
    {
        // Degraded exit: hand back the best finite iterate, keeping the
        // carried-fitted-values contract (`ws.xb_beta` tracks `ws.beta`).
        ws.beta.copy_from_slice(&ws.best_beta);
        ws.xb_beta.copy_from_slice(&ws.best_xb);
    }
    let mut res = state.extract(ws);
    res.status = status;
    (res, state.failed())
}

/// Concrete FISTA instantiation of [`drive_core`] for the ladder (a free
/// function so the fallback config's fresh lifetime unifies locally).
fn fista_fallback<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    warm: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> (SolveResult, bool) {
    drive_core::<P, fista::Fista<P>>(loss, penalty, lambda, warm, cfg, ws)
}

/// The shared iteration driver: [`drive_core`] plus the degradation
/// ladder. If the solve diverges or loses its backtracking certificate,
/// restart once under FISTA with a halved step from the last finite
/// iterate; a successful restart reports
/// [`SolveStatus::FellBack`]`{ from, to }`.
pub fn drive<'a, P: ProxPenalty, S: Solver<'a, P>>(
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    beta0: &[f64],
    cfg: &'a SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    let (res, failed) = drive_core::<P, S>(loss, penalty, lambda, beta0, cfg, ws);
    if !failed && res.status != SolveStatus::Diverged {
        return res;
    }
    // Degradation ladder: one FISTA restart, half the step, warm-started
    // from the best finite iterate (or the sanitized warm start when the
    // failure predates any finite objective).
    let warm: Vec<f64> = if ws.best_valid {
        ws.best_beta.clone()
    } else {
        beta0.iter().map(|&b| if b.is_finite() { b } else { 0.0 }).collect()
    };
    let fcfg = SolverConfig {
        kind: SolverKind::Fista,
        step_shrink: 0.5 * cfg.step_shrink,
        ..cfg.clone()
    };
    let (fres, ffailed) = fista_fallback(loss, penalty, lambda, &warm, &fcfg, ws);
    let iterations = res.iterations + fres.iterations;
    let status = if !ffailed && fres.status == SolveStatus::Converged {
        SolveStatus::FellBack { from: cfg.kind, to: SolverKind::Fista }
    } else if ffailed && fres.status == SolveStatus::Converged {
        // Convergence declared under a broken step certificate is not
        // trustworthy — report the stall instead.
        SolveStatus::Stalled
    } else {
        fres.status
    };
    SolveResult { iterations, status, ..fres }
}

/// Solve `min f(β) + λ·Ω(β)` from the warm start `beta0` (allocates a
/// one-shot workspace; hot paths should hold a [`SolverWorkspace`] and call
/// [`solve_ws`]).
pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let mut ws = SolverWorkspace::new();
    solve_ws(loss, penalty, lambda, beta0, cfg, &mut ws)
}

/// Solve with caller-provided buffers — the zero-allocation pathwise form.
/// Dispatches `cfg.kind` through the [`Solver`] trait via [`drive`].
pub fn solve_ws<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    match cfg.kind {
        SolverKind::Fista => drive::<P, fista::Fista<P>>(loss, penalty, lambda, beta0, cfg, ws),
        SolverKind::Atos => drive::<P, atos::Atos<P>>(loss, penalty, lambda, beta0, cfg, ws),
        SolverKind::Bcd => drive::<P, bcd::Bcd<P>>(loss, penalty, lambda, beta0, cfg, ws),
    }
}

/// Primal objective — shared by every solver and the tests.
pub fn objective<P: ProxPenalty>(loss: &Loss, penalty: &P, lambda: f64, beta: &[f64]) -> f64 {
    loss.value(beta) + lambda * penalty.pen_value(beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::LossKind;
    use crate::penalty::Penalty;
    use crate::rng::Rng;

    fn problem(seed: u64, n: usize, p: usize) -> (Matrix, Vec<f64>, Groups) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::from_fn(n, p, |_, _| rng.gauss());
        x.standardize_l2();
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 3 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
        let mut y = x.matvec(&beta_true);
        y.iter_mut().for_each(|v| *v += rng.normal(0.0, 0.1));
        let g = Groups::even(p, 4);
        (x, y, g)
    }

    #[test]
    fn fista_and_atos_agree() {
        let (x, y, g) = problem(1, 40, 16);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g, 0.95);
        let lambda = 0.05 * crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 16]), &pen.groups, 0.95);
        let cfg_f = SolverConfig { tol: 1e-9, max_iters: 20000, ..Default::default() };
        let cfg_a = SolverConfig { kind: SolverKind::Atos, tol: 1e-9, max_iters: 20000, ..Default::default() };
        let rf = solve(&loss, &pen, lambda, &vec![0.0; 16], &cfg_f);
        let ra = solve(&loss, &pen, lambda, &vec![0.0; 16], &cfg_a);
        assert!(rf.converged() && ra.converged());
        assert!(
            (rf.objective - ra.objective).abs() < 1e-6 * (1.0 + rf.objective),
            "fista {} vs atos {}",
            rf.objective,
            ra.objective
        );
    }

    #[test]
    fn solution_satisfies_kkt_conditions() {
        let (x, y, g) = problem(2, 50, 20);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let alpha = 0.9;
        let pen = Penalty::sgl(g.clone(), alpha);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 20]), &g, alpha);
        let lambda = 0.3 * lam_max;
        let cfg = SolverConfig { tol: 1e-12, max_iters: 50000, ..Default::default() };
        let r = solve(&loss, &pen, lambda, &vec![0.0; 20], &cfg);
        let grad = loss.gradient(&r.beta);
        // Inactive variables in inactive groups: |S(∇ᵢ, λ(1−α)√p_g)| ≤ λα.
        for (gi, rr) in g.iter() {
            let bg = &r.beta[rr.clone()];
            let active_group = bg.iter().any(|&b| b != 0.0);
            let sq = (g.size(gi) as f64).sqrt();
            for i in rr {
                if r.beta[i] == 0.0 && !active_group {
                    let s = crate::norms::soft_threshold(grad[i], lambda * (1.0 - alpha) * sq);
                    assert!(
                        s.abs() <= lambda * alpha + 1e-5,
                        "KKT violated at {i}: {} > {}",
                        s.abs(),
                        lambda * alpha
                    );
                }
                if r.beta[i] != 0.0 {
                    // Active variable stationarity: ∇ᵢ + λα sign + λ(1−α)√p_g βᵢ/‖β_g‖ = 0.
                    let bnorm = bg.iter().map(|v| v * v).sum::<f64>().sqrt();
                    let sub = grad[i]
                        + lambda * alpha * r.beta[i].signum()
                        + lambda * (1.0 - alpha) * sq * r.beta[i] / bnorm;
                    assert!(sub.abs() < 1e-4, "stationarity at {i}: {sub}");
                }
            }
        }
    }

    #[test]
    fn lambda_above_max_gives_null_model() {
        let (x, y, g) = problem(3, 30, 12);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 12]), &g, 0.95);
        let r = solve(&loss, &pen, lam_max * 1.01, &vec![0.0; 12], &SolverConfig::default());
        assert!(r.beta.iter().all(|&b| b == 0.0), "expected null model");
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let (x, y, g) = problem(4, 60, 24);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 24]), &g, 0.95);
        let cfg = SolverConfig::default();
        let cold = solve(&loss, &pen, 0.2 * lam_max, &vec![0.0; 24], &cfg);
        let near = solve(&loss, &pen, 0.22 * lam_max, &vec![0.0; 24], &cfg);
        let warm = solve(&loss, &pen, 0.2 * lam_max, &near.beta, &cfg);
        assert!(warm.iterations <= cold.iterations, "warm {} cold {}", warm.iterations, cold.iterations);
        assert!((warm.objective - cold.objective).abs() < 1e-5 * (1.0 + cold.objective));
    }

    #[test]
    fn logistic_solve_converges() {
        let mut rng = Rng::new(5);
        let mut x = Matrix::from_fn(80, 12, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = (0..80).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let g = Groups::even(12, 3);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 12]), &g, 0.95);
        let r = solve(&loss, &pen, 0.1 * lam_max, &vec![0.0; 12], &SolverConfig::default());
        assert!(r.converged());
        // objective must beat the null model
        assert!(r.objective <= objective(&loss, &pen, 0.1 * lam_max, &vec![0.0; 12]) + 1e-12);
    }

    #[test]
    fn adaptive_penalty_solve_monotone_objective() {
        let (x, y, g) = problem(6, 40, 16);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let aw = crate::penalty::AdaptiveWeights::from_design(&x, &g, 0.1, 0.1);
        let pen = Penalty::asgl(g, 0.95, aw.v, aw.w);
        let r = solve(&loss, &pen, 0.01, &vec![0.0; 16], &SolverConfig::default());
        assert!(r.converged());
        assert!(r.objective.is_finite());
    }
}
