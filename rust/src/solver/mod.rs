//! Inner solvers for the SGL / aSGL optimization (Eq. 1) — a solver
//! *subsystem* behind the step-driven [`Solver`] trait.
//!
//! Three algorithms, all warm-startable, all holding their per-iteration
//! state in a caller-provided [`SolverWorkspace`]:
//!
//! * [`fista`] — accelerated proximal gradient with the *exact* sparse-group
//!   prox (soft-threshold → group-shrink) and backtracking line search.
//!   Default engine: the exact prox makes it both faster and more accurate
//!   than splitting for this penalty.
//! * [`atos`] — Adaptive Three Operator Splitting (Pedregosa & Gidel,
//!   2018), the algorithm the paper's experiments use; splits the penalty
//!   into its ℓ1 and group-ℓ2 parts, each with a closed-form prox.
//! * [`bcd`] — proximal block-coordinate descent in the style of the
//!   `sparsegl` solver (Liang et al. '22) and the Friedman–Hastie–
//!   Tibshirani note: cycles over groups with per-group Lipschitz
//!   constants, residual-carried block updates through the
//!   [`crate::linalg::DesignRef`] block kernels, and an active-group epoch
//!   schedule (full sweep → active epochs → certifying full sweep).
//!
//! Each algorithm is a state machine implementing [`Solver`]
//! (`init` from workspace → `step` → `converged` → `extract`); [`drive`]
//! is the shared iteration driver and [`solve_ws`] dispatches a
//! [`SolverKind`] through it. Screening is solver-agnostic (the paper
//! stresses DFR works with any fitting algorithm); the pathwise
//! coordinator takes [`SolverKind`] as a parameter and the benches pin one
//! solver across all rules so improvement factors are solver-independent.

pub mod atos;
pub mod bcd;
pub mod fista;

use crate::groups::Groups;
use crate::loss::Loss;
use crate::penalty::{Penalty, RestrictedPenalty};

/// Penalty interface the solvers need. Implemented by the full [`Penalty`]
/// and by [`RestrictedPenalty`] (screening-reduced problems).
///
/// The block accessors expose the grouping that tiles the coordinate
/// vector and the exact prox of one group's block — the contract the BCD
/// solver cycles over (whole-vector solvers ignore them).
pub trait ProxPenalty {
    fn pen_value(&self, beta: &[f64]) -> f64;
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]);
    /// Grouping structure the penalty is defined over; its blocks tile the
    /// coordinate vector exactly.
    fn pen_groups(&self) -> &Groups;
    /// Exact prox restricted to group `g`'s block (`z`/`out` are the block
    /// slices of length `p_g`).
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]);
}

impl ProxPenalty for Penalty {
    fn pen_value(&self, beta: &[f64]) -> f64 {
        self.value(beta)
    }
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_into(z, t_lambda, out)
    }
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_l1_into(z, t_lambda, out)
    }
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_group_into(z, t_lambda, out)
    }
    fn pen_groups(&self) -> &Groups {
        &self.groups
    }
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_block_into(g, z, t_lambda, out)
    }
}

impl ProxPenalty for RestrictedPenalty {
    fn pen_value(&self, beta: &[f64]) -> f64 {
        self.value(beta)
    }
    fn pen_prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_into(z, t_lambda, out)
    }
    fn pen_prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_l1_into(z, t_lambda, out)
    }
    fn pen_prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_group_into(z, t_lambda, out)
    }
    fn pen_groups(&self) -> &Groups {
        &self.groups
    }
    fn pen_prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        self.prox_block_into(g, z, t_lambda, out)
    }
}

/// Choice of inner solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Fista,
    Atos,
    /// Group-major proximal block-coordinate descent ([`bcd`]).
    Bcd,
}

impl SolverKind {
    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Fista => "fista",
            SolverKind::Atos => "atos",
            SolverKind::Bcd => "bcd",
        }
    }

    /// Parse a CLI-style solver name (`fista` | `atos` | `bcd`).
    pub fn parse(s: &str) -> Result<SolverKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "fista" => Ok(SolverKind::Fista),
            "atos" => Ok(SolverKind::Atos),
            "bcd" | "blockcd" | "block-cd" => Ok(SolverKind::Bcd),
            other => Err(format!("unknown solver `{other}` (fista|atos|bcd)")),
        }
    }
}

/// Solver settings; defaults follow Table A1's algorithm block
/// (max 5000 iterations, backtracking 0.7 with 100 inner steps,
/// convergence tolerance 1e-5).
#[derive(Clone, Debug, PartialEq)]
pub struct SolverConfig {
    pub kind: SolverKind,
    pub max_iters: usize,
    pub tol: f64,
    /// Backtracking shrink factor on the step size (paper: 0.7).
    pub backtrack: f64,
    pub max_backtrack: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            kind: SolverKind::Fista,
            max_iters: 5000,
            tol: 1e-5,
            backtrack: 0.7,
            max_backtrack: 100,
        }
    }
}

/// Result of an inner solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub beta: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// Final primal objective value `f(β) + λΩ(β)`.
    pub objective: f64,
}

/// Reusable buffers for the inner solvers.
///
/// Every vector FISTA/ATOS needs per iteration lives here, pre-sized by
/// [`SolverWorkspace::resize`] at solve entry. Capacity is grow-only, so a
/// workspace carried across λ steps (and KKT re-entry rounds) stops
/// allocating once it has seen the largest problem on the path — the
/// iteration and backtracking loops themselves are allocation-free.
#[derive(Clone, Debug, Default)]
pub struct SolverWorkspace {
    /// `Xz` at the extrapolated/prox point driving the gradient.
    pub(crate) xb: Vec<f64>,
    /// `Xβ` of the candidate evaluated during backtracking.
    pub(crate) xb_cand: Vec<f64>,
    /// `Xβ` at the accepted iterate (exposed via [`SolverWorkspace::fitted`]).
    pub(crate) xb_beta: Vec<f64>,
    /// Residual scratch (length n).
    pub(crate) r: Vec<f64>,
    /// Gradient at the current point (length p).
    pub(crate) grad: Vec<f64>,
    /// Gradient-step argument (FISTA) / reflected argument (ATOS).
    pub(crate) cand: Vec<f64>,
    /// Prox output: FISTA's candidate iterate / ATOS's `u_h`.
    pub(crate) next: Vec<f64>,
    /// Current iterate.
    pub(crate) beta: Vec<f64>,
    /// Previous iterate (FISTA) / ATOS's `u_g`.
    pub(crate) beta_prev: Vec<f64>,
    /// Extrapolated / splitting state.
    pub(crate) z: Vec<f64>,
    /// BCD: squared ℓ₂ norm of every design column (length p), cached once
    /// per solve from [`crate::linalg::DesignRef::col_sq_norms_into`].
    pub(crate) col_sq: Vec<f64>,
    /// BCD: per-group block Lipschitz estimates (length m), seeded from
    /// the column-norm cache and grown in place by per-block backtracking.
    pub(crate) group_lip: Vec<f64>,
    /// BCD: the active-group list of the current epoch.
    pub(crate) groups_active: Vec<usize>,
}

impl SolverWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for an (n × p) problem. Shrinking keeps capacity.
    pub fn resize(&mut self, n: usize, p: usize) {
        fn fit(v: &mut Vec<f64>, len: usize) {
            v.clear();
            v.resize(len, 0.0);
        }
        fit(&mut self.xb, n);
        fit(&mut self.xb_cand, n);
        fit(&mut self.xb_beta, n);
        fit(&mut self.r, n);
        fit(&mut self.grad, p);
        fit(&mut self.cand, p);
        fit(&mut self.next, p);
        fit(&mut self.beta, p);
        fit(&mut self.beta_prev, p);
        fit(&mut self.z, p);
    }

    /// Final iterate of the last solve.
    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Fitted values `Xβ` at the final iterate of the last solve.
    pub fn fitted(&self) -> &[f64] {
        &self.xb_beta
    }
}

/// One inner algorithm as a step-driven state machine.
///
/// The lifecycle is fixed by [`drive`]: `init` sizes the workspace and
/// builds iteration state from the warm start, `step` advances one
/// iteration (one group sweep for BCD), `converged` reports the stopping
/// test, and `extract` packages the final iterate — whose fitted values
/// `Xβ` every implementation must leave in `ws.xb_beta` (the pathwise
/// coordinator's residual-carry contract, see
/// [`SolverWorkspace::fitted`]).
pub trait Solver<'a, P: ProxPenalty>: Sized {
    /// Build iteration state in `ws` from the warm start `beta0`.
    fn init(
        loss: &'a Loss<'a>,
        penalty: &'a P,
        lambda: f64,
        beta0: &[f64],
        cfg: &'a SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> Self;

    /// Advance one iteration.
    fn step(&mut self, ws: &mut SolverWorkspace);

    /// Has the stopping criterion been met?
    fn converged(&self) -> bool;

    /// Package the final iterate held in `ws`.
    fn extract(&self, ws: &SolverWorkspace) -> SolveResult;
}

/// The shared iteration driver: `init`, then `step` until `converged` or
/// `cfg.max_iters`, then `extract`.
pub fn drive<'a, P: ProxPenalty, S: Solver<'a, P>>(
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    beta0: &[f64],
    cfg: &'a SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    let mut state = S::init(loss, penalty, lambda, beta0, cfg, ws);
    for _ in 0..cfg.max_iters {
        state.step(ws);
        if state.converged() {
            break;
        }
    }
    state.extract(ws)
}

/// Solve `min f(β) + λ·Ω(β)` from the warm start `beta0` (allocates a
/// one-shot workspace; hot paths should hold a [`SolverWorkspace`] and call
/// [`solve_ws`]).
pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let mut ws = SolverWorkspace::new();
    solve_ws(loss, penalty, lambda, beta0, cfg, &mut ws)
}

/// Solve with caller-provided buffers — the zero-allocation pathwise form.
/// Dispatches `cfg.kind` through the [`Solver`] trait via [`drive`].
pub fn solve_ws<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    match cfg.kind {
        SolverKind::Fista => drive::<P, fista::Fista<P>>(loss, penalty, lambda, beta0, cfg, ws),
        SolverKind::Atos => drive::<P, atos::Atos<P>>(loss, penalty, lambda, beta0, cfg, ws),
        SolverKind::Bcd => drive::<P, bcd::Bcd<P>>(loss, penalty, lambda, beta0, cfg, ws),
    }
}

/// Primal objective — shared by every solver and the tests.
pub fn objective<P: ProxPenalty>(loss: &Loss, penalty: &P, lambda: f64, beta: &[f64]) -> f64 {
    loss.value(beta) + lambda * penalty.pen_value(beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::LossKind;
    use crate::penalty::Penalty;
    use crate::rng::Rng;

    fn problem(seed: u64, n: usize, p: usize) -> (Matrix, Vec<f64>, Groups) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::from_fn(n, p, |_, _| rng.gauss());
        x.standardize_l2();
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 3 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
        let mut y = x.matvec(&beta_true);
        y.iter_mut().for_each(|v| *v += rng.normal(0.0, 0.1));
        let g = Groups::even(p, 4);
        (x, y, g)
    }

    #[test]
    fn fista_and_atos_agree() {
        let (x, y, g) = problem(1, 40, 16);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g, 0.95);
        let lambda = 0.05 * crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 16]), &pen.groups, 0.95);
        let cfg_f = SolverConfig { tol: 1e-9, max_iters: 20000, ..Default::default() };
        let cfg_a = SolverConfig { kind: SolverKind::Atos, tol: 1e-9, max_iters: 20000, ..Default::default() };
        let rf = solve(&loss, &pen, lambda, &vec![0.0; 16], &cfg_f);
        let ra = solve(&loss, &pen, lambda, &vec![0.0; 16], &cfg_a);
        assert!(rf.converged && ra.converged);
        assert!(
            (rf.objective - ra.objective).abs() < 1e-6 * (1.0 + rf.objective),
            "fista {} vs atos {}",
            rf.objective,
            ra.objective
        );
    }

    #[test]
    fn solution_satisfies_kkt_conditions() {
        let (x, y, g) = problem(2, 50, 20);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let alpha = 0.9;
        let pen = Penalty::sgl(g.clone(), alpha);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 20]), &g, alpha);
        let lambda = 0.3 * lam_max;
        let cfg = SolverConfig { tol: 1e-12, max_iters: 50000, ..Default::default() };
        let r = solve(&loss, &pen, lambda, &vec![0.0; 20], &cfg);
        let grad = loss.gradient(&r.beta);
        // Inactive variables in inactive groups: |S(∇ᵢ, λ(1−α)√p_g)| ≤ λα.
        for (gi, rr) in g.iter() {
            let bg = &r.beta[rr.clone()];
            let active_group = bg.iter().any(|&b| b != 0.0);
            let sq = (g.size(gi) as f64).sqrt();
            for i in rr {
                if r.beta[i] == 0.0 && !active_group {
                    let s = crate::norms::soft_threshold(grad[i], lambda * (1.0 - alpha) * sq);
                    assert!(
                        s.abs() <= lambda * alpha + 1e-5,
                        "KKT violated at {i}: {} > {}",
                        s.abs(),
                        lambda * alpha
                    );
                }
                if r.beta[i] != 0.0 {
                    // Active variable stationarity: ∇ᵢ + λα sign + λ(1−α)√p_g βᵢ/‖β_g‖ = 0.
                    let bnorm = bg.iter().map(|v| v * v).sum::<f64>().sqrt();
                    let sub = grad[i]
                        + lambda * alpha * r.beta[i].signum()
                        + lambda * (1.0 - alpha) * sq * r.beta[i] / bnorm;
                    assert!(sub.abs() < 1e-4, "stationarity at {i}: {sub}");
                }
            }
        }
    }

    #[test]
    fn lambda_above_max_gives_null_model() {
        let (x, y, g) = problem(3, 30, 12);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 12]), &g, 0.95);
        let r = solve(&loss, &pen, lam_max * 1.01, &vec![0.0; 12], &SolverConfig::default());
        assert!(r.beta.iter().all(|&b| b == 0.0), "expected null model");
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let (x, y, g) = problem(4, 60, 24);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 24]), &g, 0.95);
        let cfg = SolverConfig::default();
        let cold = solve(&loss, &pen, 0.2 * lam_max, &vec![0.0; 24], &cfg);
        let near = solve(&loss, &pen, 0.22 * lam_max, &vec![0.0; 24], &cfg);
        let warm = solve(&loss, &pen, 0.2 * lam_max, &near.beta, &cfg);
        assert!(warm.iterations <= cold.iterations, "warm {} cold {}", warm.iterations, cold.iterations);
        assert!((warm.objective - cold.objective).abs() < 1e-5 * (1.0 + cold.objective));
    }

    #[test]
    fn logistic_solve_converges() {
        let mut rng = Rng::new(5);
        let mut x = Matrix::from_fn(80, 12, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = (0..80).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let g = Groups::even(12, 3);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 12]), &g, 0.95);
        let r = solve(&loss, &pen, 0.1 * lam_max, &vec![0.0; 12], &SolverConfig::default());
        assert!(r.converged);
        // objective must beat the null model
        assert!(r.objective <= objective(&loss, &pen, 0.1 * lam_max, &vec![0.0; 12]) + 1e-12);
    }

    #[test]
    fn adaptive_penalty_solve_monotone_objective() {
        let (x, y, g) = problem(6, 40, 16);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let aw = crate::penalty::AdaptiveWeights::from_design(&x, &g, 0.1, 0.1);
        let pen = Penalty::asgl(g, 0.95, aw.v, aw.w);
        let r = solve(&loss, &pen, 0.01, &vec![0.0; 16], &SolverConfig::default());
        assert!(r.converged);
        assert!(r.objective.is_finite());
    }
}
