//! Group-major proximal block-coordinate descent — the `sparsegl`-style
//! inner solver (Liang et al. '22; Friedman, Hastie & Tibshirani's SGL
//! note), packaged as the [`Bcd`] state machine behind the [`Solver`]
//! trait.
//!
//! One iteration is one *sweep*: cycle the penalty's groups and give each
//! block `g` a proximal gradient update
//!
//! ```text
//!     β_g ← prox_{(1/L_g)·λ·Ω_g}( β_g − (1/L_g) ∇_g f(β) )
//! ```
//!
//! with a per-group Lipschitz estimate `L_g`, seeded from the cached
//! squared column norms (`max_j‖x_j‖²` is a spectral lower bound of
//! `‖X_g‖₂²`) and grown in place by per-block backtracking on the
//! quadratic majorization — so every accepted block update decreases the
//! objective. The fitted values `Xβ` are **residual-carried**: each block
//! update adjusts them through the group-block kernels
//! ([`crate::linalg::DesignRef::block_axpy_into`] /
//! [`crate::linalg::DesignRef::block_t_matvec_with_rsum_into`]), which cost
//! O(n·p_g) dense and O(nnz_g + n) on centered-implicit sparse designs —
//! never a full matvec per block. A periodic full refresh kills the
//! accumulated floating-point drift.
//!
//! Sweeps follow an **active-group epoch schedule**: a full sweep over all
//! groups, then epochs restricted to the currently-nonzero groups until
//! they are stable, then a full sweep to certify (a group outside the
//! active set that moves re-opens the epochs). Convergence is only ever
//! declared on a certifying full sweep, so the solver cannot silently
//! converge on a stale active set. On screening-reduced problems the
//! blocks are the [`crate::penalty::RestrictedPenalty`]'s groups, which
//! tile the reduced design exactly (see
//! [`crate::linalg::ReducedDesign::update_grouped`]).
//!
//! Like FISTA/ATOS, all vector state lives in the caller's
//! [`SolverWorkspace`] (plus its BCD extensions: the per-column squared-
//! norm cache, the per-group Lipschitz estimates, and the active-group
//! list); the sweep and backtracking loops perform no heap allocation.

use super::{ProxPenalty, SolveResult, SolveStatus, Solver, SolverConfig, SolverKind, SolverWorkspace};
use crate::linalg::{dot, norm2};
use crate::loss::{Loss, LossKind};

/// Sweeps between full `Xβ` refreshes (drift control for the carried
/// fitted values).
const REFRESH_EVERY: usize = 64;

/// One-shot entry point (allocates a private workspace).
pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let mut ws = SolverWorkspace::new();
    solve_ws(loss, penalty, lambda, beta0, cfg, &mut ws)
}

/// Workspace entry point — the pathwise hot loop.
pub fn solve_ws<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    super::drive::<P, Bcd<P>>(loss, penalty, lambda, beta0, cfg, ws)
}

/// Where the epoch schedule currently is.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Sweep every group (also the certification sweep).
    Full,
    /// Sweep only the currently-active groups.
    Active,
}

/// BCD iteration state (one `step` = one sweep).
pub struct Bcd<'a, P: ProxPenalty> {
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    cfg: &'a SolverConfig,
    inv_n: f64,
    phase: Phase,
    since_refresh: usize,
    iterations: usize,
    converged: bool,
    /// Backtracking exhausted at least once: the step certificate is gone.
    failed: bool,
}

impl<'a, P: ProxPenalty> Solver<'a, P> for Bcd<'a, P> {
    fn init(
        loss: &'a Loss<'a>,
        penalty: &'a P,
        lambda: f64,
        beta0: &[f64],
        cfg: &'a SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> Self {
        let p = beta0.len();
        let n = loss.n();
        debug_assert_eq!(p, loss.x.ncols());
        let groups = penalty.pen_groups();
        assert_eq!(
            groups.p(),
            p,
            "BCD needs the penalty's groups to tile the coordinate vector"
        );
        ws.resize(n, p);
        ws.beta.copy_from_slice(beta0);
        // Carried fitted values at the warm start (sparse warm starts skip
        // zero coordinates).
        loss.x.matvec_par_into(&ws.beta, crate::parallel::default_threads(), &mut ws.xb_beta);

        let inv_n = 1.0 / n as f64;
        // Factor turning a block operator-norm bound `‖X_g‖₂²` into a
        // block Lipschitz bound of `∇_g f`: `1/n` squared, `1/(4n)`
        // logistic.
        let lip_factor = match loss.kind {
            LossKind::Squared => inv_n,
            LossKind::Logistic => 0.25 * inv_n,
        };

        // Per-column squared-norm cache → per-group Lipschitz seeds.
        // `max_j‖x_j‖²` lower-bounds `‖X_g‖₂²`, so the seed errs fast;
        // the per-block backtracking doubles it to a certified value (at
        // most log₂ p_g times ever, since `‖X_g‖₂² ≤ Σ_j‖x_j‖²`).
        ws.col_sq.clear();
        ws.col_sq.resize(p, 0.0);
        loss.x.col_sq_norms_into(&mut ws.col_sq);
        ws.group_lip.clear();
        ws.group_lip.resize(groups.m(), 0.0);
        for (g, r) in groups.iter() {
            let mx = ws.col_sq[r].iter().fold(0.0f64, |a, &b| a.max(b));
            // Block step is 1/L_g, so a halved ladder step (`step_shrink`
            // 0.5) means doubled seeds; the default 1.0 divides out exactly.
            ws.group_lip[g] = (lip_factor * mx).max(1e-12) / cfg.step_shrink;
        }
        ws.groups_active.clear();

        Bcd {
            loss,
            penalty,
            lambda,
            cfg,
            inv_n,
            phase: Phase::Full,
            since_refresh: 0,
            iterations: 0,
            converged: false,
            failed: false,
        }
    }

    fn step(&mut self, ws: &mut SolverWorkspace) {
        self.iterations += 1;
        self.since_refresh += 1;
        if self.since_refresh >= REFRESH_EVERY {
            // Re-anchor the carried fitted values on the exact matvec.
            self.loss.x.matvec_par_into(
                &ws.beta,
                crate::parallel::default_threads(),
                &mut ws.xb_beta,
            );
            self.since_refresh = 0;
        }
        match self.phase {
            Phase::Full => {
                let m = self.penalty.pen_groups().m();
                let mut dsq = 0.0;
                for g in 0..m {
                    dsq += self.update_block(g, ws);
                }
                if self.rel_change(dsq, ws) <= self.cfg.tol {
                    // A clean full sweep certifies convergence.
                    self.converged = true;
                } else {
                    self.phase = Phase::Active;
                }
            }
            Phase::Active => {
                // Active set re-read from the iterate each epoch (blocks
                // the epoch zeroes out drop off; none can join until the
                // certifying full sweep).
                let groups = self.penalty.pen_groups();
                ws.groups_active.clear();
                for (g, r) in groups.iter() {
                    if ws.beta[r].iter().any(|&b| b != 0.0) {
                        ws.groups_active.push(g);
                    }
                }
                let active = std::mem::take(&mut ws.groups_active);
                let mut dsq = 0.0;
                for &g in &active {
                    dsq += self.update_block(g, ws);
                }
                ws.groups_active = active;
                if self.rel_change(dsq, ws) <= self.cfg.tol {
                    // Stable on the active set — certify with a full sweep.
                    self.phase = Phase::Full;
                }
            }
        }
    }

    fn converged(&self) -> bool {
        self.converged
    }

    fn objective(&self, ws: &SolverWorkspace) -> f64 {
        // `xb_beta` is carried in lock-step, so the objective needs no
        // fresh matvec.
        self.loss.value_from_xb(&ws.xb_beta) + self.lambda * self.penalty.pen_value(&ws.beta)
    }

    fn failed(&self) -> bool {
        self.failed
    }

    fn extract(&self, ws: &SolverWorkspace) -> SolveResult {
        SolveResult {
            beta: ws.beta.clone(),
            iterations: self.iterations,
            status: if self.converged { SolveStatus::Converged } else { SolveStatus::MaxIters },
            objective: self.objective(ws),
        }
    }
}

impl<'a, P: ProxPenalty> Bcd<'a, P> {
    /// Relative sweep movement `√(Σ_g‖Δβ_g‖²) / max(1, ‖β‖)` — the same
    /// iterate-change criterion FISTA uses, accumulated per sweep.
    fn rel_change(&self, sweep_dsq: f64, ws: &SolverWorkspace) -> f64 {
        sweep_dsq.sqrt() / norm2(&ws.beta).max(1.0)
    }

    /// One proximal gradient update of block `g`, with backtracking growth
    /// of `L_g` on the quadratic majorization. Returns `‖Δβ_g‖²`; leaves
    /// `ws.beta` and the carried `ws.xb_beta` consistent.
    fn update_block(&mut self, g: usize, ws: &mut SolverWorkspace) -> f64 {
        let r = self.penalty.pen_groups().range(g);

        // ∇_g f(β) through the carried fitted values: one residual pass
        // plus one group-block transpose matvec. The residual sum rides
        // along for free and spares the centered-sparse kernel its O(n)
        // `Σᵢ rᵢ` reduction per block.
        let sr = self.loss.residual_with_sum_from_xb(&ws.xb_beta, &mut ws.r);
        self.loss.x.block_t_matvec_with_rsum_into(r.clone(), &ws.r, sr, &mut ws.grad[r.clone()]);
        for gj in ws.grad[r.clone()].iter_mut() {
            *gj *= self.inv_n;
        }

        let mut bt = 0;
        // Computed on first need: invariant across backtracking retries,
        // and never needed for blocks that do not move (the common
        // inactive-block case pays no O(n) loss evaluation).
        let mut f_old = f64::NAN;
        loop {
            let step = 1.0 / ws.group_lip[g];
            for ((c, &b), &gj) in ws.cand[r.clone()]
                .iter_mut()
                .zip(&ws.beta[r.clone()])
                .zip(&ws.grad[r.clone()])
            {
                *c = b - step * gj;
            }
            self.penalty.pen_prox_block_into(
                g,
                &ws.cand[r.clone()],
                step * self.lambda,
                &mut ws.next[r.clone()],
            );
            // Δβ_g into the gradient-step buffer (its job is done).
            let mut dsq = 0.0;
            for ((c, &nb), &b) in ws.cand[r.clone()]
                .iter_mut()
                .zip(&ws.next[r.clone()])
                .zip(&ws.beta[r.clone()])
            {
                let d = nb - b;
                *c = d;
                dsq += d * d;
            }
            if dsq == 0.0 {
                // Fixed point (inactive block staying inactive is the
                // common case): nothing moves, nothing to check.
                return 0.0;
            }

            // Majorization check: f(β + Δ_g) ≤ f(β) + ⟨∇_g, Δ⟩ + L_g‖Δ‖²/2
            // guarantees the prox step decreased the composite objective.
            ws.xb_cand.copy_from_slice(&ws.xb_beta);
            self.loss.x.block_axpy_into(r.clone(), &ws.cand[r.clone()], &mut ws.xb_cand);
            if !f_old.is_finite() {
                // Recompute on NaN *or* ±∞ — an infinite cached objective
                // is as useless a reference point as a NaN one.
                f_old = self.loss.value_from_xb(&ws.xb_beta);
            }
            let f_new = self.loss.value_from_xb(&ws.xb_cand);
            let ip = dot(&ws.grad[r.clone()], &ws.cand[r.clone()]);
            let forced = crate::faults::backtrack_must_fail(SolverKind::Bcd);
            let bound_ok = !forced
                && f_new
                    <= f_old + ip + 0.5 * ws.group_lip[g] * dsq + 1e-12 * f_old.abs().max(1.0);
            if !bound_ok {
                bt += 1;
                if bt < self.cfg.max_backtrack {
                    ws.group_lip[g] *= 2.0;
                    continue;
                }
                // Backtracking exhausted: accept the latest candidate
                // (mirrors FISTA's exhaustion behaviour), but flag the
                // lost majorization certificate for the driver's ladder.
                self.failed = true;
            }
            ws.beta[r.clone()].copy_from_slice(&ws.next[r.clone()]);
            std::mem::swap(&mut ws.xb_beta, &mut ws.xb_cand);
            return dsq;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::Penalty;
    use crate::rng::Rng;
    use crate::solver::{SolverConfig, SolverKind, SolverWorkspace};

    fn standardized(seed: u64, n: usize, p: usize) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::from_fn(n, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(n);
        (x, y)
    }

    #[test]
    fn bcd_matches_fista_on_random_problems() {
        let mut seed = 20;
        for trial in 0..5 {
            seed += 1;
            let p = 16;
            let (x, y) = standardized(seed, 50, p);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let g = Groups::even(p, 4);
            let pen = Penalty::sgl(g.clone(), 0.9);
            let lam_max =
                crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
            let lambda = 0.2 * lam_max;
            let cfg_b = SolverConfig {
                kind: SolverKind::Bcd,
                tol: 1e-11,
                max_iters: 100_000,
                ..Default::default()
            };
            let cfg_f = SolverConfig { tol: 1e-11, max_iters: 100_000, ..Default::default() };
            let rb = super::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_b);
            let rf = crate::solver::fista::solve(&loss, &pen, lambda, &vec![0.0; p], &cfg_f);
            assert!(rb.converged(), "trial {trial}: BCD did not certify");
            let d = crate::linalg::l2_distance(&rb.beta, &rf.beta);
            assert!(d < 1e-8, "trial {trial}: BCD vs FISTA ℓ₂ = {d}");
        }
    }

    #[test]
    fn bcd_null_model_above_lambda_max() {
        let p = 12;
        let (x, y) = standardized(30, 40, p);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g = Groups::even(p, 3);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.95);
        let cfg = SolverConfig { kind: SolverKind::Bcd, ..Default::default() };
        let r = super::solve(&loss, &pen, 1.05 * lam_max, &vec![0.0; p], &cfg);
        assert!(r.beta.iter().all(|&b| b == 0.0), "expected null model");
        assert!(r.converged());
    }

    #[test]
    fn bcd_logistic_never_increases_objective() {
        let mut rng = Rng::new(31);
        let p = 12;
        let mut x = Matrix::from_fn(60, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> =
            (0..60).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let loss = Loss::new(LossKind::Logistic, &x, &y);
        let pen = Penalty::sgl(Groups::even(p, 4), 0.9);
        let cfg = SolverConfig { kind: SolverKind::Bcd, ..Default::default() };
        for _ in 0..5 {
            let b0: Vec<f64> = rng.gauss_vec(p).iter().map(|v| 0.3 * v).collect();
            let r = super::solve(&loss, &pen, 0.05, &b0, &cfg);
            let start = crate::solver::objective(&loss, &pen, 0.05, &b0);
            assert!(r.objective <= start + 1e-10, "{} > {start}", r.objective);
        }
    }

    #[test]
    fn bcd_workspace_reuse_is_exact_and_carries_fitted_values() {
        let p = 12;
        let (x, y) = standardized(32, 40, p);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::even(p, 4), 0.9);
        let cfg = SolverConfig { kind: SolverKind::Bcd, ..Default::default() };
        let mut ws = SolverWorkspace::new();
        // Dirty the workspace with a different-shaped solve first.
        let (x2, _) = standardized(33, 40, 7);
        let loss2 = Loss::new(LossKind::Squared, &x2, &y);
        let pen2 = Penalty::sgl(Groups::even(7, 2), 0.9);
        super::solve_ws(&loss2, &pen2, 0.05, &vec![0.0; 7], &cfg, &mut ws);

        let reused = super::solve_ws(&loss, &pen, 0.05, &vec![0.0; p], &cfg, &mut ws);
        let fresh = super::solve(&loss, &pen, 0.05, &vec![0.0; p], &cfg);
        assert_eq!(reused.beta, fresh.beta, "dirty workspace changed BCD result");
        assert_eq!(reused.iterations, fresh.iterations);
        let xb = x.matvec(&reused.beta);
        for (a, b) in ws.fitted().iter().zip(&xb) {
            assert!((a - b).abs() < 1e-10, "carried fitted values out of sync");
        }
    }

    #[test]
    fn bcd_warm_start_certifies_quickly() {
        let p = 20;
        let (x, y) = standardized(34, 60, p);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g = Groups::even(p, 5);
        let pen = Penalty::sgl(g.clone(), 0.9);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
        let cfg = SolverConfig { kind: SolverKind::Bcd, tol: 1e-9, ..Default::default() };
        let cold = super::solve(&loss, &pen, 0.3 * lam_max, &vec![0.0; p], &cfg);
        let warm = super::solve(&loss, &pen, 0.3 * lam_max, &cold.beta, &cfg);
        assert!(
            warm.iterations < cold.iterations.max(2),
            "warm {} vs cold {} sweeps",
            warm.iterations,
            cold.iterations
        );
        let d = crate::linalg::l2_distance(&warm.beta, &cold.beta);
        assert!(d <= 1e-8, "warm restart moved the solution: ℓ₂ = {d}");
    }
}
