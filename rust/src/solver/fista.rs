//! FISTA (accelerated proximal gradient) with backtracking line search and
//! the exact sparse-group prox.
//!
//! Classical Beck–Teboulle iteration with the standard restart-free
//! momentum sequence, packaged as the [`Fista`] state machine behind the
//! [`Solver`] trait. The step size starts at `1/L̂` from a power-iteration
//! Lipschitz estimate (or the warm-started previous step) and backtracks by
//! the paper's factor 0.7 whenever the quadratic upper bound is violated.
//!
//! All per-iteration state lives in the caller's [`SolverWorkspace`]; the
//! iteration and backtracking loops perform **no heap allocation** — every
//! matvec/prox writes into a pre-sized buffer, iterates advance by pointer
//! swaps, and the candidate's fitted values `Xβ` are carried so the loss is
//! never evaluated through a fresh `Xβ` allocation.

use super::{ProxPenalty, SolveResult, SolveStatus, Solver, SolverConfig, SolverKind, SolverWorkspace};
use crate::linalg::{dot, l2_distance};
use crate::loss::Loss;

/// One-shot entry point (allocates a private workspace).
pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let mut ws = SolverWorkspace::new();
    solve_ws(loss, penalty, lambda, beta0, cfg, &mut ws)
}

/// Workspace entry point — the pathwise hot loop.
pub fn solve_ws<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
    ws: &mut SolverWorkspace,
) -> SolveResult {
    super::drive::<P, Fista<P>>(loss, penalty, lambda, beta0, cfg, ws)
}

/// FISTA iteration state (everything vector-shaped lives in the
/// workspace; this holds only the scalars that persist across steps).
pub struct Fista<'a, P: ProxPenalty> {
    loss: &'a Loss<'a>,
    penalty: &'a P,
    lambda: f64,
    cfg: &'a SolverConfig,
    /// Momentum scalar `t_k`.
    t: f64,
    /// Current step size (monotone non-increasing under backtracking).
    step: f64,
    threads: usize,
    inv_n: f64,
    iterations: usize,
    converged: bool,
    /// Backtracking exhausted at least once: the step certificate is gone.
    failed: bool,
}

impl<'a, P: ProxPenalty> Solver<'a, P> for Fista<'a, P> {
    fn init(
        loss: &'a Loss<'a>,
        penalty: &'a P,
        lambda: f64,
        beta0: &[f64],
        cfg: &'a SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> Self {
        let p = beta0.len();
        let n = loss.n();
        debug_assert_eq!(p, loss.x.ncols());
        ws.resize(n, p);
        ws.beta.copy_from_slice(beta0);
        ws.beta_prev.copy_from_slice(beta0);
        ws.z.copy_from_slice(beta0);

        // Initial step: inverse Lipschitz estimate (backtracking corrects).
        let lip = loss.lipschitz_bound().max(1e-12);

        // Fitted values at the warm start (zero coordinates are skipped, so
        // a sparse warm start costs O(n·nnz)); kept in lock-step with
        // `beta` so the final objective needs no fresh `Xβ`.
        loss.x.matvec_par_into(&ws.beta, crate::parallel::default_threads(), &mut ws.xb_beta);

        Fista {
            loss,
            penalty,
            lambda,
            cfg,
            t: 1.0,
            // `step_shrink` defaults to 1.0 (bit-identical); the
            // degradation ladder halves it on a fallback restart.
            step: cfg.step_shrink / lip,
            threads: crate::parallel::default_threads(),
            inv_n: 1.0 / n as f64,
            iterations: 0,
            converged: false,
            failed: false,
        }
    }

    fn step(&mut self, ws: &mut SolverWorkspace) {
        self.iterations += 1;
        // Gradient at the extrapolated point z.
        self.loss.x.matvec_par_into(&ws.z, self.threads, &mut ws.xb);
        let fz = self.loss.value_from_xb(&ws.xb);
        self.loss.residual_from_xb(&ws.xb, &mut ws.r);
        self.loss.x.t_matvec_par_into(&ws.r, self.threads, &mut ws.grad);
        for g in ws.grad.iter_mut() {
            *g *= self.inv_n;
        }

        // Backtracking on the composite upper bound.
        let mut bt = 0;
        loop {
            for ((c, &zj), &gj) in ws.cand.iter_mut().zip(&ws.z).zip(&ws.grad) {
                *c = zj - self.step * gj;
            }
            self.penalty.pen_prox_into(&ws.cand, self.step * self.lambda, &mut ws.next);
            // Quadratic bound check: f(next) ≤ f(z) + ⟨∇f(z), d⟩ + ‖d‖²/(2·step).
            self.loss.x.matvec_par_into(&ws.next, self.threads, &mut ws.xb_cand);
            let fnext = self.loss.value_from_xb(&ws.xb_cand);
            let mut ip = 0.0;
            let mut dsq = 0.0;
            for ((&nj, &zj), &gj) in ws.next.iter().zip(&ws.z).zip(&ws.grad) {
                let d = nj - zj;
                ip += gj * d;
                dsq += d * d;
            }
            let forced = crate::faults::backtrack_must_fail(SolverKind::Fista);
            let bound_ok = !forced
                && fnext <= fz + ip + dsq / (2.0 * self.step) + 1e-12 * fz.abs().max(1.0);
            if !bound_ok {
                bt += 1;
                if bt < self.cfg.max_backtrack {
                    self.step *= self.cfg.backtrack;
                    continue;
                }
                // Backtracking exhausted: accept the latest candidate, but
                // flag the lost step certificate for the driver's ladder.
                self.failed = true;
            }
            // Accept: advance the iterate by buffer rotation (no copies of
            // the coefficient vectors, no allocation).
            std::mem::swap(&mut ws.beta_prev, &mut ws.beta);
            std::mem::swap(&mut ws.beta, &mut ws.next);
            std::mem::swap(&mut ws.xb_beta, &mut ws.xb_cand);
            break;
        }

        // Momentum update.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * self.t * self.t).sqrt());
        let mom = (self.t - 1.0) / t_next;
        for ((zj, &bj), &pj) in ws.z.iter_mut().zip(&ws.beta).zip(&ws.beta_prev) {
            *zj = bj + mom * (bj - pj);
        }
        self.t = t_next;

        // Convergence: relative change in iterates (paper's tol 1e-5).
        let num = l2_distance(&ws.beta, &ws.beta_prev);
        let den = dot(&ws.beta, &ws.beta).sqrt().max(1.0);
        if num / den <= self.cfg.tol {
            self.converged = true;
        }
    }

    fn converged(&self) -> bool {
        self.converged
    }

    fn objective(&self, ws: &SolverWorkspace) -> f64 {
        // `xb_beta` tracks `beta` exactly, so the objective costs no matvec.
        self.loss.value_from_xb(&ws.xb_beta) + self.lambda * self.penalty.pen_value(&ws.beta)
    }

    fn failed(&self) -> bool {
        self.failed
    }

    fn extract(&self, ws: &SolverWorkspace) -> SolveResult {
        SolveResult {
            beta: ws.beta.clone(),
            iterations: self.iterations,
            status: if self.converged { SolveStatus::Converged } else { SolveStatus::MaxIters },
            objective: self.objective(ws),
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::Penalty;
    use crate::rng::Rng;
    use crate::solver::{objective, SolverConfig, SolverWorkspace};

    /// Unpenalized (λ=0) quadratic: FISTA must approach the least-squares
    /// solution found by normal equations (small, well-conditioned case).
    #[test]
    fn converges_to_least_squares_when_lambda_zero() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(30, 3, |_, _| rng.gauss());
        let beta_true = [1.5, -2.0, 0.5];
        let y = x.matvec(&beta_true);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::singletons(3), 0.5);
        let cfg = SolverConfig { tol: 1e-12, max_iters: 50000, ..Default::default() };
        let r = super::solve(&loss, &pen, 0.0, &[0.0; 3], &cfg);
        for (a, b) in r.beta.iter().zip(&beta_true) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    /// Objective is monotone-ish: the final objective is no worse than the
    /// starting one, for many random starts.
    #[test]
    fn never_increases_objective_from_start() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(25, 10, |_, _| rng.gauss());
        let y: Vec<f64> = rng.gauss_vec(25);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::even(10, 5), 0.8);
        for _ in 0..10 {
            let b0: Vec<f64> = rng.gauss_vec(10);
            let r = super::solve(&loss, &pen, 0.1, &b0, &SolverConfig::default());
            assert!(r.objective <= objective(&loss, &pen, 0.1, &b0) + 1e-10);
        }
    }

    /// A reused workspace must produce the exact same result as a fresh
    /// one, and its fitted-values buffer must track the returned iterate.
    #[test]
    fn workspace_reuse_is_exact_and_carries_fitted_values() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(30, 12, |_, _| rng.gauss());
        let y: Vec<f64> = rng.gauss_vec(30);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::even(12, 4), 0.9);
        let cfg = SolverConfig::default();
        let mut ws = SolverWorkspace::new();
        // Dirty the workspace with a different-sized solve first.
        let x2 = Matrix::from_fn(30, 7, |_, _| rng.gauss());
        let loss2 = Loss::new(LossKind::Squared, &x2, &y);
        let pen2 = Penalty::sgl(Groups::even(7, 7), 0.9);
        super::solve_ws(&loss2, &pen2, 0.05, &vec![0.0; 7], &cfg, &mut ws);

        let reused = super::solve_ws(&loss, &pen, 0.05, &vec![0.0; 12], &cfg, &mut ws);
        let fresh = super::solve(&loss, &pen, 0.05, &vec![0.0; 12], &cfg);
        assert_eq!(reused.beta, fresh.beta, "workspace reuse changed the solution");
        assert_eq!(reused.iterations, fresh.iterations);
        let xb = x.matvec(&reused.beta);
        for (a, b) in ws.fitted().iter().zip(&xb) {
            assert!((a - b).abs() < 1e-12, "fitted values out of sync");
        }
    }
}
