//! FISTA (accelerated proximal gradient) with backtracking line search and
//! the exact sparse-group prox.
//!
//! Classical Beck–Teboulle iteration with the standard restart-free
//! momentum sequence. The step size starts at `1/L̂` from a power-iteration
//! Lipschitz estimate (or the warm-started previous step) and backtracks by
//! the paper's factor 0.7 whenever the quadratic upper bound is violated.

use super::{ProxPenalty, SolveResult, SolverConfig};
use crate::linalg::{dot, l2_distance};
use crate::loss::Loss;

pub fn solve<P: ProxPenalty>(
    loss: &Loss,
    penalty: &P,
    lambda: f64,
    beta0: &[f64],
    cfg: &SolverConfig,
) -> SolveResult {
    let p = beta0.len();
    let n = loss.n();
    let mut beta = beta0.to_vec();
    let mut z = beta.clone(); // extrapolated point
    let mut beta_prev = beta.clone();
    let mut t = 1.0f64;

    // Initial step: inverse Lipschitz estimate (backtracking will correct).
    let lip = loss.lipschitz_bound().max(1e-12);
    let mut step = 1.0 / lip;

    let mut xb = vec![0.0; n];
    let mut r = vec![0.0; n];
    let mut cand = vec![0.0; p];
    let mut grad_point = vec![0.0; p];

    let mut iterations = 0;
    let mut converged = false;

    for it in 0..cfg.max_iters {
        iterations = it + 1;
        // Gradient at the extrapolated point z.
        loss.x.matvec_into(&z, &mut xb);
        let fz = loss.value_from_xb(&xb);
        loss.residual_from_xb(&xb, &mut r);
        let threads = crate::parallel::default_threads();
        let g = loss.x.t_matvec_par(&r, threads);
        let inv_n = 1.0 / n as f64;
        for j in 0..p {
            grad_point[j] = g[j] * inv_n;
        }

        // Backtracking on the composite upper bound.
        let mut bt = 0;
        loop {
            for j in 0..p {
                cand[j] = z[j] - step * grad_point[j];
            }
            let mut next = vec![0.0; p];
            penalty.pen_prox_into(&cand, step * lambda, &mut next);
            // Quadratic bound check: f(next) ≤ f(z) + ⟨∇f(z), d⟩ + ‖d‖²/(2·step).
            let fnext = loss.value(&next);
            let mut ip = 0.0;
            let mut dsq = 0.0;
            for j in 0..p {
                let d = next[j] - z[j];
                ip += grad_point[j] * d;
                dsq += d * d;
            }
            if fnext <= fz + ip + dsq / (2.0 * step) + 1e-12 * fz.abs().max(1.0) {
                beta_prev.copy_from_slice(&beta);
                beta = next;
                break;
            }
            bt += 1;
            if bt >= cfg.max_backtrack {
                beta_prev.copy_from_slice(&beta);
                beta = next;
                break;
            }
            step *= cfg.backtrack;
        }

        // Momentum update.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let mom = (t - 1.0) / t_next;
        for j in 0..p {
            z[j] = beta[j] + mom * (beta[j] - beta_prev[j]);
        }
        t = t_next;

        // Convergence: relative change in iterates (paper's tol 1e-5).
        let num = l2_distance(&beta, &beta_prev);
        let den = dot(&beta, &beta).sqrt().max(1.0);
        if num / den <= cfg.tol {
            converged = true;
            break;
        }
    }

    let objective = super::objective(loss, penalty, lambda, &beta);
    SolveResult { beta, iterations, converged, objective }
}

#[cfg(test)]
mod tests {
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::Penalty;
    use crate::rng::Rng;
    use crate::solver::{objective, SolverConfig};

    /// Unpenalized (λ=0) quadratic: FISTA must approach the least-squares
    /// solution found by normal equations (small, well-conditioned case).
    #[test]
    fn converges_to_least_squares_when_lambda_zero() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(30, 3, |_, _| rng.gauss());
        let beta_true = [1.5, -2.0, 0.5];
        let y = x.matvec(&beta_true);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::singletons(3), 0.5);
        let cfg = SolverConfig { tol: 1e-12, max_iters: 50000, ..Default::default() };
        let r = super::solve(&loss, &pen, 0.0, &[0.0; 3], &cfg);
        for (a, b) in r.beta.iter().zip(&beta_true) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    /// Objective is monotone-ish: the final objective is no worse than the
    /// starting one, for many random starts.
    #[test]
    fn never_increases_objective_from_start() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(25, 10, |_, _| rng.gauss());
        let y: Vec<f64> = rng.gauss_vec(25);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let pen = Penalty::sgl(Groups::even(10, 5), 0.8);
        for _ in 0..10 {
            let b0: Vec<f64> = rng.gauss_vec(10);
            let r = super::solve(&loss, &pen, 0.1, &b0, &SolverConfig::default());
            assert!(r.objective <= objective(&loss, &pen, 0.1, &b0) + 1e-10);
        }
    }
}
