//! PJRT runtime: executes AOT-compiled JAX/Pallas artifacts from Rust.
//!
//! The Python compile path (`python/compile/aot.py`, run once by
//! `make artifacts`) lowers the L2 gradient graphs — whose inner matvecs
//! are the L1 Pallas kernels — to **HLO text** under `artifacts/`:
//!
//! ```text
//!     grad_sq_{n}x{p}.hlo.txt    (X[n,p], β[p], y[n]) → (Xᵀ(Xβ−y)/n,)
//!     grad_log_{n}x{p}.hlo.txt   (X[n,p], β[p], y[n]) → (Xᵀ(σ(Xβ)−y)/n,)
//! ```
//!
//! HLO *text* is the interchange format: the image's xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos (64-bit instruction ids), while the
//! text parser reassigns ids (see /opt/xla-example/README.md). Artifacts
//! are f64 (`jax_enable_x64`) so screening/KKT decisions keep native
//! precision.
//!
//! [`XlaEngine`] implements [`crate::path::Engine`]: the pathwise
//! coordinator's full-gradient hot path (screening + KKT checks — the
//! dominant O(np) cost per path point) runs on PJRT; shapes without a
//! matching artifact fall back to the native engine transparently, and
//! `stats()` reports the hit/miss split so benches can verify what
//! actually ran where. Design matrices are uploaded to the device once and
//! cached (keyed by buffer identity), so the per-call traffic is O(n + p).
//!
//! ## Feature gate
//!
//! The real PJRT client needs the (git-only) `xla` bindings, which cannot
//! be resolved in an offline build, so it compiles only with the `xla`
//! cargo feature (after adding the dependency to `Cargo.toml`). Without
//! the feature this module provides a **stub `XlaEngine`** with the same
//! public API whose every artifact call errors, so all call sites fall
//! through to the native engine and keep a single code path.

use crate::linalg::{DesignRef, Matrix};
use crate::loss::{Loss, LossKind};
use crate::path::Engine;
use crate::penalty::RestrictedPenalty;
use crate::solver::{SolveResult, SolverConfig, SolverWorkspace};
use std::cell::RefCell;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::PathBuf;
#[cfg(feature = "xla")]
use std::rc::Rc;

/// Runtime statistics (artifact hits vs native fallbacks).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub xla_gradient_calls: usize,
    pub xla_solver_chunks: usize,
    pub native_fallbacks: usize,
    pub compiled_artifacts: usize,
}

/// PJRT-backed compute engine.
#[cfg(feature = "xla")]
pub struct XlaEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Compiled executables keyed by artifact stem.
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Device-resident copies of host arrays, keyed by pointer + length +
    /// content fingerprint (see [`cache_key`]).
    buffers: RefCell<HashMap<(usize, usize, u64), Rc<xla::PjRtBuffer>>>,
    /// Row-major (XLA-layout) copies of column-major design matrices.
    rowmajor: RefCell<HashMap<(usize, usize, u64), Rc<Vec<f64>>>>,
    stats: RefCell<EngineStats>,
}

/// Stub engine compiled when the `xla` feature is off: constructs
/// successfully, reports artifact presence, and serves every computation
/// from the native fallback so callers keep one code path.
#[cfg(not(feature = "xla"))]
pub struct XlaEngine {
    dir: PathBuf,
    stats: RefCell<EngineStats>,
}

// --- API shared by the real engine and the stub ---
impl XlaEngine {
    /// Was the crate compiled with the real PJRT runtime?
    pub const fn compiled_with_xla() -> bool {
        cfg!(feature = "xla")
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    /// Does an artifact exist for this stem (without compiling it)?
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.dir.join(format!("{stem}.hlo.txt")).exists()
    }

    /// Gradient artifact stem for a loss/shape pair.
    pub fn gradient_stem(kind: LossKind, n: usize, p: usize) -> String {
        match kind {
            LossKind::Squared => format!("grad_sq_{n}x{p}"),
            LossKind::Logistic => format!("grad_log_{n}x{p}"),
        }
    }

    /// Bucket a reduced width to the next power of two ≥ 32.
    pub fn bucket_for(k: usize) -> usize {
        std::cmp::max(32, k.next_power_of_two())
    }

    /// Stem of the FISTA-chunk artifact for an (n, bucket) pair.
    pub fn fista_stem(n: usize, bucket: usize) -> String {
        format!("fista_sq_{n}x{bucket}_t{FISTA_ITERS}")
    }
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Create an engine over an artifact directory (usually `artifacts/`).
    pub fn new(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(XlaEngine {
            client,
            dir: dir.into(),
            execs: RefCell::new(HashMap::new()),
            buffers: RefCell::new(HashMap::new()),
            rowmajor: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// Load + compile an artifact (cached).
    fn executable(&self, stem: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(stem) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{stem}.hlo.txt"));
        if !path.exists() {
            anyhow::bail!("artifact {} not found", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow_xla)?;
        let rc = Rc::new(exe);
        self.execs.borrow_mut().insert(stem.to_string(), rc.clone());
        self.stats.borrow_mut().compiled_artifacts += 1;
        Ok(rc)
    }

    /// Device buffer for a host slice with the given logical dims, cached
    /// by identity of the host allocation PLUS a content fingerprint —
    /// pointer identity alone is unsound because a dropped dataset's
    /// allocation can be reused at the same address by the next one.
    fn cached_buffer(
        &self,
        data: &[f64],
        dims: &[usize],
    ) -> anyhow::Result<Rc<xla::PjRtBuffer>> {
        let key = cache_key(data);
        if let Some(b) = self.buffers.borrow().get(&key) {
            return Ok(b.clone());
        }
        let buf = self
            .client
            .buffer_from_host_buffer::<f64>(data, dims, None)
            .map_err(anyhow_xla)?;
        let rc = Rc::new(buf);
        self.buffers.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Row-major copy of a (col-major) design matrix, cached per matrix so
    /// the O(np) transpose happens once per dataset.
    fn design_rowmajor(&self, x: &Matrix) -> Rc<Vec<f64>> {
        let key = cache_key(x.as_slice());
        if let Some(v) = self.rowmajor.borrow().get(&key) {
            return v.clone();
        }
        let (n, p) = (x.nrows(), x.ncols());
        let mut row = vec![0.0f64; n * p];
        for j in 0..p {
            let col = x.col(j);
            for i in 0..n {
                row[i * p + j] = col[i];
            }
        }
        let rc = Rc::new(row);
        self.rowmajor.borrow_mut().insert(key, rc.clone());
        rc
    }

    /// Full gradient through the `grad_{sq,log}_{n}x{p}` artifact. Errors
    /// if the artifact does not exist or the design is not dense (the
    /// [`Engine`] impl guards both and falls back to native).
    pub fn gradient_via_xla<'a>(
        &self,
        kind: LossKind,
        x: impl Into<DesignRef<'a>>,
        y: &[f64],
        beta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        let x = x
            .into()
            .as_dense()
            .ok_or_else(|| anyhow::anyhow!("sparse designs are served by the native kernels"))?;
        let (n, p) = (x.nrows(), x.ncols());
        let stem = Self::gradient_stem(kind, n, p);
        let exe = self.executable(&stem)?;
        let xrow = self.design_rowmajor(x);
        let xbuf = self.cached_buffer(&xrow, &[n, p])?;
        let ybuf = self.cached_buffer(y, &[n])?;
        // β changes every call — fresh upload (O(p)).
        let bbuf = self
            .client
            .buffer_from_host_buffer::<f64>(beta, &[p], None)
            .map_err(anyhow_xla)?;
        // `&PjRtBuffer: Borrow<PjRtBuffer>` — no ownership juggling needed.
        let out = exe
            .execute_b(&[&*xbuf, &bbuf, &*ybuf])
            .map_err(anyhow_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let tuple = lit.to_tuple1().map_err(anyhow_xla)?;
        let grad = tuple.to_vec::<f64>().map_err(anyhow_xla)?;
        anyhow::ensure!(grad.len() == p, "gradient artifact returned wrong length");
        self.stats.borrow_mut().xla_gradient_calls += 1;
        Ok(grad)
    }

    /// Solve the reduced SGL problem via bucketed AOT FISTA chunks.
    ///
    /// Gathers the reduced design into the next power-of-two bucket (pad
    /// columns zero, pad groups with empty one-hot rows — fixed points of
    /// the prox), uploads the static operands once, then executes
    /// 50-iteration chunks with Rust-side convergence checks between them
    /// (the state round-trips through host literals, O(p_b) per chunk).
    ///
    /// Errors when no artifact matches (the [`Engine`] impl falls back to
    /// the native solver) and for logistic losses (squared only — matching
    /// the artifact set).
    pub fn solve_reduced_via_xla(
        &self,
        x_red: &Matrix,
        y: &[f64],
        pen: &RestrictedPenalty,
        lam: f64,
        beta0: &[f64],
        cfg: &SolverConfig,
    ) -> anyhow::Result<SolveResult> {
        let n = x_red.nrows();
        let k = x_red.ncols();
        let pb = Self::bucket_for(k);
        let stem = Self::fista_stem(n, pb);
        let exe = self.executable(&stem)?;

        // --- static operands (uploaded once per solve) ---
        let mut xrow = vec![0.0f64; n * pb];
        for j in 0..k {
            let col = x_red.col(j);
            for i in 0..n {
                xrow[i * pb + j] = col[i];
            }
        }
        let xbuf = self
            .client
            .buffer_from_host_buffer::<f64>(&xrow, &[n, pb], None)
            .map_err(anyhow_xla)?;
        let ybuf = self
            .client
            .buffer_from_host_buffer::<f64>(y, &[n], None)
            .map_err(anyhow_xla)?;
        let mut l1 = vec![0.0f64; pb];
        for j in 0..k {
            l1[j] = lam * pen.alpha * pen.v[j];
        }
        let l1buf = self
            .client
            .buffer_from_host_buffer::<f64>(&l1, &[pb], None)
            .map_err(anyhow_xla)?;
        let mut onehot = vec![0.0f64; pb * pb];
        let mut gthr = vec![0.0f64; pb];
        for (g, r) in pen.groups.iter() {
            gthr[g] = lam * (1.0 - pen.alpha) * pen.w[g] * pen.sqrt_pg[g];
            for j in r {
                onehot[g * pb + j] = 1.0;
            }
        }
        let ohbuf = self
            .client
            .buffer_from_host_buffer::<f64>(&onehot, &[pb, pb], None)
            .map_err(anyhow_xla)?;
        let gtbuf = self
            .client
            .buffer_from_host_buffer::<f64>(&gthr, &[pb], None)
            .map_err(anyhow_xla)?;

        // Fixed step from the power-iteration Lipschitz estimate. Power
        // iteration approaches ||X||2^2 FROM BELOW, so a too-large step
        // (and FISTA divergence) is possible; the chunk loop guards it by
        // checking the primal objective between chunks and halving the
        // step (reverting the chunk) whenever the objective rose --
        // backtracking at chunk granularity.
        let lip = x_red.op_norm_sq_est(60, 0xF157A) / n as f64;
        let mut step = 1.0 / (1.1 * lip.max(1e-12));

        let loss = Loss::new(LossKind::Squared, x_red, y);
        let objective_of =
            |b: &[f64]| crate::solver::objective(&loss, pen, lam, &b[..k]);

        // --- chunk loop ---
        let mut beta = vec![0.0f64; pb];
        beta[..k].copy_from_slice(beta0);
        let mut z = beta.clone();
        let mut t = 1.0f64;
        let mut obj_prev = objective_of(&beta);
        let max_iters_total = (cfg.max_iters / FISTA_ITERS).max(1) * FISTA_ITERS;
        let mut iterations = 0;
        let mut converged = false;
        let mut halvings = 0;
        while iterations < max_iters_total {
            let stepbuf = self
                .client
                .buffer_from_host_buffer::<f64>(&[step], &[], None)
                .map_err(anyhow_xla)?;
            let bbuf = self
                .client
                .buffer_from_host_buffer::<f64>(&beta, &[pb], None)
                .map_err(anyhow_xla)?;
            let zbuf = self
                .client
                .buffer_from_host_buffer::<f64>(&z, &[pb], None)
                .map_err(anyhow_xla)?;
            let tbuf = self
                .client
                .buffer_from_host_buffer::<f64>(&[t], &[], None)
                .map_err(anyhow_xla)?;
            let out = exe
                .execute_b(&[&xbuf, &ybuf, &bbuf, &zbuf, &tbuf, &stepbuf, &l1buf, &ohbuf, &gtbuf])
                .map_err(anyhow_xla)?;
            let lit = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
            let parts = lit.to_tuple().map_err(anyhow_xla)?;
            anyhow::ensure!(parts.len() == 4, "fista artifact returned {} parts", parts.len());
            let beta_new = parts[0].to_vec::<f64>().map_err(anyhow_xla)?;
            let obj_new = objective_of(&beta_new);
            if !obj_new.is_finite() || obj_new > obj_prev + 1e-10 * obj_prev.abs().max(1.0) {
                // Divergence (step > 1/L) or momentum overshoot: halve the
                // step, reset momentum, retry from the previous iterate.
                step *= 0.5;
                z.copy_from_slice(&beta);
                t = 1.0;
                halvings += 1;
                anyhow::ensure!(halvings <= 40, "step collapse: Lipschitz estimate broken");
                continue;
            }
            beta = beta_new;
            z = parts[1].to_vec::<f64>().map_err(anyhow_xla)?;
            t = parts[2].to_vec::<f64>().map_err(anyhow_xla)?[0];
            let delta = parts[3].to_vec::<f64>().map_err(anyhow_xla)?[0];
            obj_prev = obj_new;
            iterations += FISTA_ITERS;
            let scale = beta.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0);
            if delta / scale <= cfg.tol {
                converged = true;
                break;
            }
        }
        self.stats.borrow_mut().xla_solver_chunks += iterations / FISTA_ITERS;

        let beta_red = beta[..k].to_vec();
        let objective = crate::solver::objective(&loss, pen, lam, &beta_red);
        let status = if converged {
            crate::solver::SolveStatus::Converged
        } else {
            crate::solver::SolveStatus::MaxIters
        };
        Ok(SolveResult { beta: beta_red, iterations, status, objective })
    }
}

#[cfg(not(feature = "xla"))]
impl XlaEngine {
    /// Create a stub engine over an artifact directory. Always succeeds;
    /// every artifact call errors so callers fall back to native compute.
    pub fn new(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        Ok(XlaEngine { dir: dir.into(), stats: RefCell::new(EngineStats::default()) })
    }

    /// Stub: always errors (compiled without the `xla` feature).
    pub fn gradient_via_xla<'a>(
        &self,
        _kind: LossKind,
        _x: impl Into<DesignRef<'a>>,
        _y: &[f64],
        _beta: &[f64],
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::bail!("compiled without the `xla` feature")
    }

    /// Stub: always errors (compiled without the `xla` feature).
    pub fn solve_reduced_via_xla(
        &self,
        _x_red: &Matrix,
        _y: &[f64],
        _pen: &RestrictedPenalty,
        _lam: f64,
        _beta0: &[f64],
        _cfg: &SolverConfig,
    ) -> anyhow::Result<SolveResult> {
        anyhow::bail!("compiled without the `xla` feature")
    }
}

/// Iterations per AOT FISTA chunk (must match `aot.py::FISTA_ITERS`).
pub const FISTA_ITERS: usize = 50;

/// Cache key for device-resident copies of host arrays: allocation
/// identity (pointer + length) extended with the shared strided-sample
/// fingerprint ([`crate::linalg`]), so allocator reuse of a freed dataset's
/// memory cannot alias a stale device buffer.
#[cfg(feature = "xla")]
fn cache_key(data: &[f64]) -> (usize, usize, u64) {
    (data.as_ptr() as usize, data.len(), crate::linalg::fingerprint(data))
}

#[cfg(feature = "xla")]
fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

impl Engine for XlaEngine {
    fn full_gradient(&self, loss: &Loss, beta: &[f64]) -> Vec<f64> {
        match self.gradient_via_xla(loss.kind, loss.x, loss.y, beta) {
            Ok(g) => g,
            Err(_) => {
                self.stats.borrow_mut().native_fallbacks += 1;
                loss.gradient(beta)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn solve_reduced(
        &self,
        kind: LossKind,
        x_red: DesignRef<'_>,
        y: &[f64],
        pen: &RestrictedPenalty,
        lam: f64,
        beta0: &[f64],
        cfg: &SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> SolveResult {
        // AOT FISTA chunks only exist for dense squared-loss designs —
        // and they ARE FISTA, so they only stand in when the configured
        // solver is FISTA (an explicit `--solver atos|bcd` must run the
        // algorithm it names; solver_equivalence.rs treats the choice as
        // part of the contract). Centered-sparse reduced problems go
        // straight to the native kernels (which is also where their
        // O(nnz) advantage lives).
        if kind == LossKind::Squared && cfg.kind == crate::solver::SolverKind::Fista {
            if let Some(x_dense) = x_red.as_dense() {
                let stem =
                    Self::fista_stem(x_dense.nrows(), Self::bucket_for(x_dense.ncols()));
                if self.has_artifact(&stem) {
                    match self.solve_reduced_via_xla(x_dense, y, pen, lam, beta0, cfg) {
                        Ok(r) => return r,
                        Err(_) => {
                            self.stats.borrow_mut().native_fallbacks += 1;
                        }
                    }
                }
            }
        }
        let loss = Loss::new(kind, x_red, y);
        crate::solver::solve_ws(&loss, pen, lam, beta0, cfg, ws)
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Artifact-dependent integration tests live in
    // rust/tests/runtime_integration.rs (they need `make artifacts`).
    // Here: construction and fallback behaviour only (valid with or
    // without the `xla` feature).

    #[test]
    fn engine_constructs_and_reports_missing_artifacts() {
        let eng = XlaEngine::new("artifacts-nonexistent").unwrap();
        assert!(!eng.has_artifact("grad_sq_10x10"));
    }

    #[test]
    fn fallback_to_native_gradient() {
        let mut rng = crate::rng::Rng::new(1);
        let x = Matrix::from_fn(10, 6, |_, _| rng.gauss());
        let y: Vec<f64> = rng.gauss_vec(10);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let eng = XlaEngine::new("artifacts-nonexistent").unwrap();
        let beta = vec![0.1; 6];
        let g_eng = eng.full_gradient(&loss, &beta);
        let g_nat = loss.gradient(&beta);
        crate::testkit::assert_close(&g_eng, &g_nat, 1e-12, "fallback gradient");
        assert_eq!(eng.stats().native_fallbacks, 1);
    }

    #[test]
    fn gradient_stems() {
        assert_eq!(XlaEngine::gradient_stem(LossKind::Squared, 3, 4), "grad_sq_3x4");
        assert_eq!(XlaEngine::gradient_stem(LossKind::Logistic, 3, 4), "grad_log_3x4");
    }

    #[test]
    fn stub_solve_reduced_falls_back_to_native() {
        let mut rng = crate::rng::Rng::new(2);
        let mut x = Matrix::from_fn(30, 8, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(30);
        let groups = crate::groups::Groups::even(8, 4);
        let pen = crate::penalty::Penalty::sgl(groups, 0.9);
        let all: Vec<usize> = (0..8).collect();
        let rpen = pen.restrict(&all);
        let eng = XlaEngine::new("artifacts-nonexistent").unwrap();
        let cfg = SolverConfig::default();
        let mut ws = SolverWorkspace::new();
        let via_engine = eng.solve_reduced(
            LossKind::Squared,
            (&x).into(),
            &y,
            &rpen,
            0.05,
            &vec![0.0; 8],
            &cfg,
            &mut ws,
        );
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let native = crate::solver::solve(&loss, &rpen, 0.05, &vec![0.0; 8], &cfg);
        crate::testkit::assert_close(&via_engine.beta, &native.beta, 1e-12, "engine fallback solve");
    }
}
