//! Minimal data-parallel substrate built on `std::thread::scope`.
//!
//! No `rayon` is available offline; the pathwise experiments only need
//! three shapes of parallelism — chunked mutation of a slice (parallel
//! `Xᵀr`), a parallel map over independent work items (CV tasks,
//! simulation repeats), and a pool of reusable per-worker scratch states
//! ([`WorkspacePool`], the substrate of the workspace-pooled CV engine in
//! [`crate::cv`]) — so that is all we build.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, TryLockError};

/// Process-wide programmatic thread override (0 = unset). Set by the
/// CLI's `--threads` flag; wins over the `DFR_THREADS` environment
/// variable so a flag on the command line always beats ambient config.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Default parallel break-even grain (see [`par_grain`]): a kernel whose
/// work measure — `n·p` touched entries for dense matvecs, `nnz + n` for
/// the centered-sparse kernels — falls below this stays serial. Scoped-
/// thread spawn costs ~50–100 µs per worker and the kernels are memory-
/// bandwidth bound, so threading only pays once the operands are far
/// larger than L2 (measured in benches/perf_hotpath.rs).
pub const DEFAULT_PAR_GRAIN: usize = 4_000_000;

/// Process-wide programmatic grain override (0 = unset), for bench sweeps
/// and tests that need to force the parallel legs on small fixtures.
static PAR_GRAIN_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override [`par_grain`] programmatically. `Some(n)` pins the break-even
/// work measure (min 1 — every kernel goes parallel); `None` restores the
/// `DFR_PAR_GRAIN` / default resolution. Thresholds only pick a code
/// path; every parallel kernel is exact at any grain, so flipping this
/// never changes solver results on the scalar backend and stays within
/// the equivalence tolerances on SIMD backends.
pub fn set_par_grain_override(n: Option<usize>) {
    PAR_GRAIN_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// Parallel break-even grain consulted by the dense and sparse
/// `t_matvec_par_into` / `matvec_par_into` / `col_sq_norms_into` kernels:
/// the programmatic override ([`set_par_grain_override`]) wins, then
/// `DFR_PAR_GRAIN` (read once per process), otherwise
/// [`DEFAULT_PAR_GRAIN`].
pub fn par_grain() -> usize {
    let o = PAR_GRAIN_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DFR_PAR_GRAIN")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map_or(DEFAULT_PAR_GRAIN, |n| n.max(1))
    })
}

/// Override [`default_threads`] programmatically (the CLI `--threads`
/// hook). `Some(n)` pins the count (min 1); `None` clears the override.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// Number of worker threads to use by default: the programmatic override
/// ([`set_thread_override`]) wins, then `DFR_THREADS` if set, otherwise
/// `available_parallelism`, capped at 16.
pub fn default_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("DFR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Split `out` into `threads` nearly-equal chunks and run `f(start, chunk)`
/// on each from its own thread. `start` is the offset of the chunk within
/// the original slice.
pub fn for_each_chunk<T: Send>(
    out: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let threads = threads.max(1).min(out.len().max(1));
    if threads == 1 {
        f(0, out);
        return;
    }
    let len = out.len();
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fr = &f;
            s.spawn(move || fr(start, head));
            start += take;
            rest = tail;
        }
    });
}

/// Parallel map over indices `0..n` with a bounded worker pool; results are
/// returned in index order. Work is pulled from a shared atomic counter so
/// uneven item costs (e.g. no-screen vs screened path fits) balance out;
/// each worker accumulates `(index, result)` pairs in its own output buffer
/// — no shared lock on the result store — and the buffers are merged into
/// index order after the workers join.
pub fn par_map<R: Send>(n: usize, threads: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            // Re-raise a worker panic on the caller's thread with its
            // original payload (an `expect` here would erase it).
            match h.join() {
                Ok(pairs) => {
                    for (i, r) in pairs {
                        slots[i] = Some(r);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots
        .into_iter()
        .map(|r| match r {
            Some(v) => v,
            // The atomic counter hands out every index exactly once and
            // all workers joined above.
            None => unreachable!("par_map missed an index"),
        })
        .collect()
}

/// A fixed-size pool of reusable worker states (e.g.
/// [`crate::path::PathWorkspace`]), shared across [`par_map`] tasks.
///
/// The pool is created with as many slots as there are worker threads;
/// every slot is built once via `Default` and then *reused* — checked out,
/// mutated, and returned — for the lifetime of the pool. Because the
/// pooled states carry grow-only buffers, the allocator drops off the hot
/// path after each slot has seen the largest problem it will be asked to
/// hold: pooling `n_tasks ≫ n_threads` work items costs `n_threads`
/// workspace initializations, not `n_tasks`.
///
/// Checkout discipline: a worker thread must hold at most **one** guard at
/// a time. Under that discipline a pool with at least as many slots as
/// concurrently-running workers always finds a free slot without blocking;
/// oversubscription (more workers than slots) degrades to a brief spin
/// while it waits for a slot to free up — never a deadlock.
pub struct WorkspacePool<T> {
    slots: Vec<Mutex<T>>,
    checkouts: AtomicUsize,
    /// Round-robin rotor: each checkout starts probing at a different
    /// slot, so concurrent callers spread over the pool instead of
    /// contending on slot 0 (fair admission for multi-tenant serving).
    rotor: AtomicUsize,
}

impl<T: Default> WorkspacePool<T> {
    /// Build a pool with `slots` default-initialized states (min 1).
    pub fn new(slots: usize) -> Self {
        WorkspacePool {
            slots: (0..slots.max(1)).map(|_| Mutex::new(T::default())).collect(),
            checkouts: AtomicUsize::new(0),
            rotor: AtomicUsize::new(0),
        }
    }
}

impl<T> WorkspacePool<T> {
    /// Number of pooled states — the total number of workspace
    /// initializations this pool will ever perform.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Number of checkouts served so far (across all threads). The ratio
    /// `checkouts / slots` is the pool's reuse factor.
    pub fn checkouts(&self) -> usize {
        self.checkouts.load(Ordering::Relaxed)
    }

    /// Borrow a free slot, spinning until one is available. The slot's
    /// previous contents are preserved (that is the point: grow-only
    /// buffers keep their capacity), so callers must fully re-initialize
    /// any state they read — `PathWorkspace::ensure` does exactly that.
    pub fn checkout(&self) -> PoolGuard<'_, T> {
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        let start = self.rotor.fetch_add(1, Ordering::Relaxed);
        loop {
            for i in 0..self.slots.len() {
                let slot = &self.slots[(start + i) % self.slots.len()];
                match slot.try_lock() {
                    Ok(guard) => return PoolGuard { guard },
                    // A worker that panicked mid-task poisons its slot;
                    // the state itself is still structurally sound (every
                    // consumer resizes/clears before use), so recover it.
                    Err(TryLockError::Poisoned(p)) => return PoolGuard { guard: p.into_inner() },
                    Err(TryLockError::WouldBlock) => {}
                }
            }
            std::thread::yield_now();
        }
    }
}

/// Exclusive borrow of one pooled state; returns the slot on drop.
pub struct PoolGuard<'a, T> {
    guard: MutexGuard<'a, T>,
}

impl<T> Deref for PoolGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for PoolGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_override_wins_and_clears() {
        // Concurrent tests observing the override are unaffected: every
        // parallel kernel returns identical results at any thread count.
        set_thread_override(Some(3));
        assert_eq!(default_threads(), 3);
        set_thread_override(Some(0)); // clamped to at least one worker
        assert_eq!(default_threads(), 1);
        set_thread_override(None);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn par_grain_override_wins_and_clears() {
        set_par_grain_override(Some(123));
        assert_eq!(par_grain(), 123);
        set_par_grain_override(Some(0)); // clamped: grain of at least 1
        assert_eq!(par_grain(), 1);
        set_par_grain_override(None);
        assert!(par_grain() >= 1);
    }

    #[test]
    fn chunked_fill_covers_everything() {
        let mut v = vec![0usize; 1003];
        for_each_chunk(&mut v, 5, |start, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let r = par_map(100, 7, |i| i * i);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_path_works() {
        let r = par_map(5, 1, |i| i + 1);
        assert_eq!(r, vec![1, 2, 3, 4, 5]);
        let mut v = vec![0; 3];
        for_each_chunk(&mut v, 1, |s, c| c.iter_mut().for_each(|x| *x = s + 9));
        assert_eq!(v, vec![9, 9, 9]);
    }

    #[test]
    fn empty_input_is_fine() {
        let r: Vec<usize> = par_map(0, 4, |i| i);
        assert!(r.is_empty());
        let mut v: Vec<u8> = vec![];
        for_each_chunk(&mut v, 4, |_, _| {});
    }

    #[test]
    fn pool_reuses_slots_across_many_tasks() {
        let threads = 3;
        let pool: WorkspacePool<Vec<f64>> = WorkspacePool::new(threads);
        let sums = par_map(50, threads, |i| {
            let mut ws = pool.checkout();
            // Grow-only scratch: capacity persists, contents re-initialized.
            ws.clear();
            ws.resize(8, i as f64);
            ws.iter().sum::<f64>()
        });
        for (i, s) in sums.iter().enumerate() {
            assert_eq!(*s, 8.0 * i as f64);
        }
        assert_eq!(pool.slots(), threads, "pool must never grow");
        assert_eq!(pool.checkouts(), 50);
    }

    #[test]
    fn pool_serves_single_threaded_callers() {
        let pool: WorkspacePool<usize> = WorkspacePool::new(1);
        {
            let mut a = pool.checkout();
            *a += 1;
        }
        let b = pool.checkout();
        assert_eq!(*b, 1, "state persists across checkouts");
    }

    #[test]
    fn pool_checkout_rotates_over_slots() {
        // Sequential checkouts must land on *different* slots (rotor
        // fairness), not hammer slot 0: tag each slot on first touch,
        // then verify all three tags exist by holding three guards at
        // once — possible only if the three earlier checkouts spread.
        let pool: WorkspacePool<u32> = WorkspacePool::new(3);
        for _ in 0..3 {
            let mut g = pool.checkout();
            *g += 1;
        }
        let (a, b, c) = (pool.checkout(), pool.checkout(), pool.checkout());
        let mut tags = [*a, *b, *c];
        tags.sort_unstable();
        assert_eq!(tags, [1, 1, 1], "each sequential checkout must visit a fresh slot");
        assert_eq!(pool.checkouts(), 6);
    }
}
