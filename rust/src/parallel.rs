//! Minimal data-parallel substrate built on `std::thread::scope`.
//!
//! No `rayon` is available offline; the pathwise experiments only need two
//! shapes of parallelism — chunked mutation of a slice (parallel `Xᵀr`) and
//! a parallel map over independent work items (CV folds, simulation
//! repeats) — so that is all we build.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: respects
/// `DFR_THREADS` if set, otherwise `available_parallelism`, capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DFR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Split `out` into `threads` nearly-equal chunks and run `f(start, chunk)`
/// on each from its own thread. `start` is the offset of the chunk within
/// the original slice.
pub fn for_each_chunk<T: Send>(
    out: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let threads = threads.max(1).min(out.len().max(1));
    if threads == 1 {
        f(0, out);
        return;
    }
    let len = out.len();
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fr = &f;
            s.spawn(move || fr(start, head));
            start += take;
            rest = tail;
        }
    });
}

/// Parallel map over indices `0..n` with a bounded worker pool; results are
/// returned in index order. Work is pulled from a shared atomic counter so
/// uneven item costs (e.g. no-screen vs screened path fits) balance out;
/// each worker accumulates `(index, result)` pairs in its own output buffer
/// — no shared lock on the result store — and the buffers are merged into
/// index order after the workers join.
pub fn par_map<R: Send>(n: usize, threads: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("par_map worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("par_map missed an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_fill_covers_everything() {
        let mut v = vec![0usize; 1003];
        for_each_chunk(&mut v, 5, |start, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let r = par_map(100, 7, |i| i * i);
        for (i, v) in r.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_path_works() {
        let r = par_map(5, 1, |i| i + 1);
        assert_eq!(r, vec![1, 2, 3, 4, 5]);
        let mut v = vec![0; 3];
        for_each_chunk(&mut v, 1, |s, c| c.iter_mut().for_each(|x| *x = s + 9));
        assert_eq!(v, vec![9, 9, 9]);
    }

    #[test]
    fn empty_input_is_fine() {
        let r: Vec<usize> = par_map(0, 4, |i| i);
        assert!(r.is_empty());
        let mut v: Vec<u8> = vec![];
        for_each_chunk(&mut v, 4, |_, _| {});
    }
}
