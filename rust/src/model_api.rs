//! High-level model API: the interface a downstream user actually calls.
//!
//! Wraps the pathwise machinery into scikit-style `fit → select → predict`:
//! standardization is handled internally and coefficients are mapped back
//! to the original feature scale (including the intercept), λ is selected
//! by k-fold CV with an optional one-standard-error rule, and predictions
//! support both response families. CV runs through the workspace-pooled
//! [`crate::cv::CvEngine`], including joint `(α, γ)` tuning via
//! [`SglModel::fit_cv_grid`].

use crate::cv::{CvConfig, CvEngine};
use crate::data::{Dataset, Response};
use crate::loss::sigmoid;
use crate::path::{PathConfig, PathFit, PathRunner};
use crate::screen::RuleKind;

/// Model specification.
#[derive(Clone, Debug)]
pub struct SglModel {
    /// Pathwise fit settings (α, path length, solver, adaptive γ).
    pub path: PathConfig,
    /// Screening rule used for every fit.
    pub rule: RuleKind,
    /// CV folds used by [`SglModel::fit_cv`] / [`SglModel::fit_cv_grid`].
    pub cv_folds: usize,
    /// Pick the sparsest λ within one standard error of the CV optimum
    /// (the standard error is measured across folds by the CV engine).
    pub one_se_rule: bool,
    /// Seed for the CV fold split.
    pub seed: u64,
}

impl Default for SglModel {
    fn default() -> Self {
        SglModel {
            path: PathConfig::default(),
            rule: RuleKind::DfrSgl,
            cv_folds: 10,
            one_se_rule: false,
            seed: 42,
        }
    }
}

/// A fitted model: selected coefficients on the ORIGINAL feature scale.
#[derive(Clone, Debug)]
pub struct FittedSgl {
    /// Intercept on the original scale.
    pub intercept: f64,
    /// Coefficients on the original scale (length p).
    pub coefficients: Vec<f64>,
    /// λ selected.
    pub lambda: f64,
    /// Index of the selected path point.
    pub lambda_idx: usize,
    pub response: Response,
    /// The underlying pathwise fit (standardized scale) for inspection.
    pub path_fit: PathFit,
}

impl FittedSgl {
    /// Selected (nonzero) variables, original indexing.
    pub fn selected(&self) -> Vec<usize> {
        self.coefficients
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0.0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Linear predictor `η = intercept + xβ` for one raw observation.
    pub fn decision_function(&self, x_row: &[f64]) -> f64 {
        assert_eq!(x_row.len(), self.coefficients.len());
        self.intercept
            + x_row.iter().zip(&self.coefficients).map(|(x, c)| x * c).sum::<f64>()
    }

    /// Prediction: the conditional mean (identity for linear, σ(η) for
    /// logistic).
    pub fn predict(&self, x_row: &[f64]) -> f64 {
        let eta = self.decision_function(x_row);
        match self.response {
            Response::Linear => eta,
            Response::Logistic => sigmoid(eta),
        }
    }

    /// Batch prediction over raw rows.
    pub fn predict_many(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }
}

impl SglModel {
    /// Fit the path on RAW data (x rows × p cols, row-major rows) and
    /// select λ at a fixed index (e.g. from a previous CV).
    pub fn fit_at(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        lambda_idx: usize,
    ) -> anyhow::Result<FittedSgl> {
        let (ds, centers) = self.prepare(x_rows, y, group_sizes, response)?;
        let fit = PathRunner::new(&ds, self.path.clone()).rule(self.rule).run()?;
        self.finalize(fit, &centers, y, response, lambda_idx)
    }

    /// Fit the path and select λ by k-fold cross-validation.
    pub fn fit_cv(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<FittedSgl> {
        let (ds, centers) = self.prepare(x_rows, y, group_sizes, response)?;
        let engine = CvEngine::with_default_threads();
        let cell = engine.cross_validate(&ds, &self.cv_config())?;
        let idx = if self.one_se_rule { cell.best_1se_idx } else { cell.best_idx };
        let fit = PathRunner::new(&ds, self.path.clone())
            .rule(self.rule)
            .fixed_path(cell.lambdas.clone())
            .run()?;
        self.finalize(fit, &centers, y, response, idx)
    }

    /// Jointly tune `(λ, α)` — and `(γ₁, γ₂)` for aSGL — by k-fold CV over
    /// the given grids, then refit at the winning cell's settings. The
    /// whole grid runs through one workspace-pooled [`CvEngine`] with
    /// shared fold splits, so the cost scales with the number of path fits
    /// rather than the number of cells times the CV overhead.
    pub fn fit_cv_grid(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        alphas: &[f64],
        gammas: &[Option<(f64, f64)>],
    ) -> anyhow::Result<FittedSgl> {
        let (ds, centers) = self.prepare(x_rows, y, group_sizes, response)?;
        let engine = CvEngine::with_default_threads();
        let (cells, best) = engine.grid_search(&ds, &self.cv_config(), alphas, gammas)?;
        let cell = &cells[best];
        let idx = if self.one_se_rule { cell.best_1se_idx } else { cell.best_idx };
        let mut path = self.path.clone();
        path.alpha = cell.alpha;
        path.adaptive = cell.gamma;
        let fit = PathRunner::new(&ds, path)
            .rule(self.rule)
            .fixed_path(cell.lambdas.clone())
            .run()?;
        self.finalize(fit, &centers, y, response, idx)
    }

    /// The CV configuration this model runs with.
    fn cv_config(&self) -> CvConfig {
        CvConfig {
            folds: self.cv_folds,
            path: self.path.clone(),
            rule: self.rule,
            seed: self.seed,
            threads: crate::parallel::default_threads(),
        }
    }

    fn prepare(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<(Dataset, Vec<(f64, f64)>)> {
        anyhow::ensure!(!x_rows.is_empty(), "empty design");
        let n = x_rows.len();
        let p = x_rows[0].len();
        anyhow::ensure!(y.len() == n, "y length mismatch");
        anyhow::ensure!(
            group_sizes.iter().sum::<usize>() == p,
            "group sizes must sum to p"
        );
        let mut x = crate::linalg::Matrix::zeros(n, p);
        for (i, row) in x_rows.iter().enumerate() {
            anyhow::ensure!(row.len() == p, "ragged design row {i}");
            for (j, &v) in row.iter().enumerate() {
                x.set(i, j, v);
            }
        }
        let centers = x.standardize_l2();
        let mut yv = y.to_vec();
        if response == Response::Linear {
            let mean = yv.iter().sum::<f64>() / n as f64;
            yv.iter_mut().for_each(|v| *v -= mean);
        }
        let ds = Dataset {
            x,
            y: yv,
            groups: crate::groups::Groups::from_sizes(group_sizes),
            response,
            name: "user".into(),
        };
        Ok((ds, centers))
    }

    fn finalize(
        &self,
        fit: PathFit,
        centers: &[(f64, f64)],
        y_raw: &[f64],
        response: Response,
        idx: usize,
    ) -> anyhow::Result<FittedSgl> {
        anyhow::ensure!(idx < fit.betas.len(), "lambda index out of range");
        let beta_std = &fit.betas[idx];
        // Unstandardize: x_std_j = (x_j − m_j)/s_j ⇒ β_j = β_std_j / s_j,
        // intercept absorbs −Σ β_std_j m_j / s_j (+ ȳ for linear).
        let mut coefficients = vec![0.0; beta_std.len()];
        let mut shift = 0.0;
        for (j, &b) in beta_std.iter().enumerate() {
            let (m, s) = centers[j];
            coefficients[j] = b / s;
            shift += b * m / s;
        }
        let intercept = match response {
            Response::Linear => {
                let ymean = y_raw.iter().sum::<f64>() / y_raw.len() as f64;
                ymean - shift
            }
            Response::Logistic => -shift,
        };
        Ok(FittedSgl {
            intercept,
            coefficients,
            lambda: fit.lambdas[idx],
            lambda_idx: idx,
            response,
            path_fit: fit,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn raw_problem(seed: u64, n: usize, p: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        // Deliberately unstandardized features (offset + scale).
        let mut rng = Rng::new(seed);
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 4 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..p).map(|j| 5.0 + (j as f64 + 1.0) * rng.gauss()).collect())
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| {
                r.iter().zip(&beta_true).map(|(x, b)| x * b).sum::<f64>() + rng.normal(0.0, 0.5)
            })
            .collect();
        (rows, y, beta_true)
    }

    #[test]
    fn fit_predict_round_trip_linear() {
        let (rows, y, _) = raw_problem(1, 80, 16);
        let model = SglModel {
            path: PathConfig { path_len: 15, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[4, 4, 4, 4], Response::Linear, 14).unwrap();
        // In-sample predictions should correlate strongly with y.
        let preds: Vec<f64> = rows.iter().map(|r| fitted.predict(r)).collect();
        let corr = correlation(&preds, &y);
        assert!(corr > 0.95, "in-sample correlation {corr}");
        assert!(!fitted.selected().is_empty());
    }

    #[test]
    fn unstandardized_coefficients_reproduce_standardized_predictions() {
        let (rows, y, _) = raw_problem(2, 60, 12);
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[3, 3, 3, 3], Response::Linear, 9).unwrap();
        // Rebuild the standardized dataset and compare η computed both ways.
        let (ds, centers) = model.prepare(&rows, &y, &[3, 3, 3, 3], Response::Linear).unwrap();
        let beta_std = &fitted.path_fit.betas[9];
        let ymean = y.iter().sum::<f64>() / y.len() as f64;
        for i in 0..5 {
            let eta_std: f64 = (0..12).map(|j| ds.x.get(i, j) * beta_std[j]).sum::<f64>() + ymean;
            let eta_raw = fitted.decision_function(&rows[i]);
            assert!((eta_std - eta_raw).abs() < 1e-8, "row {i}: {eta_std} vs {eta_raw}");
        }
        let _ = centers;
    }

    #[test]
    fn cv_fit_selects_interior_lambda() {
        let (rows, y, _) = raw_problem(3, 100, 12);
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            cv_folds: 4,
            ..Default::default()
        };
        let fitted = model.fit_cv(&rows, &y, &[4, 4, 4], Response::Linear).unwrap();
        assert!(fitted.lambda_idx > 0);
        assert!(fitted.lambda > 0.0);
    }

    #[test]
    fn one_se_rule_picks_sparser_model() {
        let (rows, y, _) = raw_problem(4, 100, 12);
        let base = SglModel {
            path: PathConfig { path_len: 12, ..PathConfig::default() },
            cv_folds: 4,
            ..Default::default()
        };
        let plain = base.fit_cv(&rows, &y, &[4, 4, 4], Response::Linear).unwrap();
        let one_se = SglModel { one_se_rule: true, ..base }
            .fit_cv(&rows, &y, &[4, 4, 4], Response::Linear)
            .unwrap();
        assert!(one_se.lambda_idx <= plain.lambda_idx, "1-SE must not be denser");
        assert!(one_se.selected().len() <= plain.selected().len() + 1);
    }

    #[test]
    fn cv_grid_fit_selects_a_grid_cell() {
        let (rows, y, _) = raw_problem(6, 90, 12);
        let model = SglModel {
            path: PathConfig { path_len: 8, ..PathConfig::default() },
            cv_folds: 3,
            ..Default::default()
        };
        let alphas = [0.5, 0.95];
        let fitted = model
            .fit_cv_grid(&rows, &y, &[4, 4, 4], Response::Linear, &alphas, &[None])
            .unwrap();
        assert!(fitted.path_fit.lambdas.len() == 8);
        assert!(fitted.lambda > 0.0);
        // The in-sample fit should still track the signal.
        let preds: Vec<f64> = rows.iter().map(|r| fitted.predict(r)).collect();
        assert!(correlation(&preds, &y) > 0.9);
    }

    #[test]
    fn logistic_predictions_are_probabilities() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> =
            (0..90).map(|_| (0..8).map(|_| rng.gauss()).collect()).collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| if r[0] + r[4] + 0.3 * rng.gauss() > 0.0 { 1.0 } else { 0.0 })
            .collect();
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[4, 4], Response::Logistic, 9).unwrap();
        let acc = rows
            .iter()
            .zip(&y)
            .filter(|(r, &yy)| (fitted.predict(r) > 0.5) == (yy == 1.0))
            .count() as f64
            / 90.0;
        assert!(acc > 0.8, "in-sample accuracy {acc}");
        for r in rows.iter().take(10) {
            let pr = fitted.predict(r);
            assert!((0.0..=1.0).contains(&pr));
        }
    }

    fn correlation(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
        let mut num = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for i in 0..a.len() {
            num += (a[i] - ma) * (b[i] - mb);
            va += (a[i] - ma).powi(2);
            vb += (b[i] - mb).powi(2);
        }
        num / (va.sqrt() * vb.sqrt())
    }
}
