//! High-level model API: the interface a downstream user actually calls.
//!
//! Two layers:
//!
//! * [`SglModel`] — the plain configuration struct (path settings,
//!   screening rule, CV folds, selection rule, seed). Cheap to clone,
//!   carries no state.
//! * [`SglFitter`] — the persistent serving object built from a model
//!   ([`SglModel::fitter`]). It owns a [`crate::parallel::WorkspacePool`]
//!   of [`crate::path::PathWorkspace`]s, a workspace-pooled
//!   [`CvEngine`], and a prepared-dataset cache keyed by a content
//!   fingerprint of the input [`Design`], so repeated fits on the same
//!   data — the serving hot path — skip the copy, the standardization,
//!   and (for identical fit settings) the solve itself. Results are
//!   bit-for-bit those of a cold fit; the caches only remove redundant
//!   work.
//!
//! Input designs come in through the [`Design`] enum: borrowed
//! column-major or row-major slices (no per-cell transformation on
//! ingest — column-major is a single `memcpy` into the standardizer),
//! borrowed row vectors, an owned [`Matrix`], or a CSC sparse matrix
//! ([`crate::linalg::CscMatrix`]) whose standardization is computed from
//! the nonzeros alone. A CSC design below the density threshold
//! ([`sparse_density_threshold`], gated by [`SparseMode`]) **solves
//! end-to-end sparse** on the centered-implicit kernels
//! ([`crate::linalg::CenteredSparse`]) — no `n × p` dense standardized
//! matrix is ever allocated. Standardization is handled internally and
//! coefficients are mapped back to the original feature scale (including
//! the intercept); λ is selected by k-fold CV with an optional
//! one-standard-error rule; predictions support both response families
//! and a batch [`FittedSgl::predict_into`] that runs one matvec over the
//! design instead of per-row dot products.
//!
//! The old `SglModel::fit_*` methods remain as deprecated shims that
//! build a throwaway fitter per call, so existing code keeps working and
//! proves behavioural equivalence of the two surfaces.

use crate::cv::{CvCell, CvConfig, CvEngine};
use crate::data::{Dataset, Response};
use crate::error::DfrError;
use crate::linalg::{self, CenteredSparse, CscMatrix, DesignOps, Matrix, OocDesign};
use crate::loss::sigmoid;
use crate::lru::KeyedLru;
use crate::parallel::WorkspacePool;
use crate::path::{PathConfig, PathFit, PathRunner, PathWorkspace};
use crate::screen::RuleKind;
use crate::solver::SolveStatus;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// How a CSC [`Design`] chooses its solve kernel.
///
/// ℓ₂ standardization destroys sparsity (centering fills every implicit
/// zero), so the sparse path stores the raw nonzeros with per-column
/// `(mean, scale)` and evaluates the standardized design implicitly
/// ([`CenteredSparse`]). The implicit kernels cost O(nnz + n) instead of
/// O(n·p), but carry a rank-one correction per pass — below the density
/// threshold they win, above it the dense kernels do.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SparseMode {
    /// Solve sparse iff the CSC density is at most the threshold
    /// (`DFR_SPARSE_DENSITY`, default 0.25). The default.
    #[default]
    Auto,
    /// Always solve CSC inputs through the centered-implicit kernels.
    On,
    /// Always densify CSC inputs (the pre-sparse-path behavior).
    Off,
}

impl SparseMode {
    /// Parse a CLI-style mode name (`auto` | `on` | `off`).
    pub fn parse(s: &str) -> Result<SparseMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SparseMode::Auto),
            "on" | "true" | "yes" => Ok(SparseMode::On),
            "off" | "false" | "no" => Ok(SparseMode::Off),
            other => Err(format!("unknown sparse mode `{other}` (auto|on|off)")),
        }
    }
}

/// Density threshold for [`SparseMode::Auto`]: CSC designs with
/// `nnz/(n·p)` at or below this solve through the centered-implicit
/// kernels. Overridable via the `DFR_SPARSE_DENSITY` environment variable
/// (a fraction in `[0, 1]`; invalid values fall back to the default 0.25).
pub fn sparse_density_threshold() -> f64 {
    std::env::var("DFR_SPARSE_DENSITY")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|t| t.is_finite() && (0.0..=1.0).contains(t))
        .unwrap_or(0.25)
}

/// Model specification.
#[derive(Clone, Debug)]
pub struct SglModel {
    /// Pathwise fit settings (α, path length, solver, adaptive γ).
    pub path: PathConfig,
    /// Screening rule used for every fit.
    pub rule: RuleKind,
    /// CV folds used by [`SglFitter::fit_cv`] / [`SglFitter::fit_cv_grid`].
    pub cv_folds: usize,
    /// Pick the sparsest λ within one standard error of the CV optimum
    /// (the standard error is measured across folds by the CV engine).
    pub one_se_rule: bool,
    /// Seed for the CV fold split.
    pub seed: u64,
    /// Kernel selection for CSC designs (see [`SparseMode`]).
    pub sparse: SparseMode,
}

impl Default for SglModel {
    fn default() -> Self {
        SglModel {
            path: PathConfig::default(),
            rule: RuleKind::DfrSgl,
            cv_folds: 10,
            one_se_rule: false,
            seed: 42,
            sparse: SparseMode::Auto,
        }
    }
}

impl SglModel {
    /// Build a persistent [`SglFitter`] from this configuration — the
    /// entry point of the serving API.
    pub fn fitter(&self) -> SglFitter {
        SglFitter::new(self.clone())
    }

    /// Same model with a different inner solver (FISTA / ATOS / BCD) —
    /// the serving-API leg of end-to-end solver selection
    /// (`path.solver.kind` spelled as a one-liner). Every fit, CV fold,
    /// and grid cell of a fitter built from the result dispatches through
    /// the chosen [`crate::solver::Solver`] implementation.
    pub fn with_solver(mut self, kind: crate::solver::SolverKind) -> Self {
        self.path.solver.kind = kind;
        self
    }

    /// Same model with a different screening rule — the serving-API leg of
    /// end-to-end rule selection. Safe rules ([`RuleKind::needs_kkt`]
    /// `== false`, e.g. [`RuleKind::Tlfre`] and the GAP-safe pair) make
    /// every fit skip the KKT re-entry loop entirely; strong rules keep
    /// the violation→re-solve repair.
    pub fn with_rule(mut self, rule: RuleKind) -> Self {
        self.rule = rule;
        self
    }
}

/// A raw design matrix in whichever layout the caller already has.
///
/// All variants borrow: nothing is copied until the fitter materializes a
/// standardized dataset, and that materialization is cached per content
/// fingerprint, so repeated fits on the same design go straight into
/// screening with zero copies. Layout notes:
///
/// * [`Design::ColMajor`] — `data[j * n + i]` is row `i`, column `j`.
///   The cheapest ingest path: one `memcpy` into the standardizer.
/// * [`Design::RowMajor`] — `data[i * p + j]`; transposed on ingest.
/// * [`Design::Rows`] — one `Vec` per observation (the layout the old
///   `SglModel::fit_*` shims accept).
/// * [`Design::Matrix`] — an already-built dense [`Matrix`].
/// * [`Design::Csc`] — sparse genotype-style designs; standardization
///   stats come from the nonzeros alone
///   ([`CscMatrix::to_standardized_dense`]).
/// * [`Design::Ooc`] — an opened out-of-core pack file
///   ([`OocDesign::open`], created by `dfr pack`). The design streams
///   from disk in column blocks; nothing `n × p`-sized is ever resident.
#[derive(Clone, Copy, Debug)]
pub enum Design<'a> {
    /// Borrowed column-major buffer (`data.len() == n * p`).
    ColMajor {
        /// Number of observations (rows).
        n: usize,
        /// Number of features (columns).
        p: usize,
        /// Column-major entries.
        data: &'a [f64],
    },
    /// Borrowed row-major buffer (`data.len() == n * p`).
    RowMajor {
        /// Number of observations (rows).
        n: usize,
        /// Number of features (columns).
        p: usize,
        /// Row-major entries.
        data: &'a [f64],
    },
    /// Borrowed row vectors (each of length `p`).
    Rows(&'a [Vec<f64>]),
    /// Borrowed dense matrix.
    Matrix(&'a Matrix),
    /// Borrowed CSC sparse matrix.
    Csc(&'a CscMatrix),
    /// Borrowed out-of-core pack-file design (column-block streaming).
    Ooc(&'a OocDesign),
}

impl<'a> Design<'a> {
    /// Column-major view over a flat buffer (asserts `data.len() == n·p`).
    pub fn col_major(n: usize, p: usize, data: &'a [f64]) -> Self {
        assert_eq!(data.len(), n * p, "column-major design length mismatch");
        Design::ColMajor { n, p, data }
    }

    /// Row-major view over a flat buffer (asserts `data.len() == n·p`).
    pub fn row_major(n: usize, p: usize, data: &'a [f64]) -> Self {
        assert_eq!(data.len(), n * p, "row-major design length mismatch");
        Design::RowMajor { n, p, data }
    }

    /// View over per-observation row vectors.
    pub fn rows(rows: &'a [Vec<f64>]) -> Self {
        Design::Rows(rows)
    }

    /// Number of observations.
    pub fn n(&self) -> usize {
        match self {
            Design::ColMajor { n, .. } | Design::RowMajor { n, .. } => *n,
            Design::Rows(rows) => rows.len(),
            Design::Matrix(m) => m.nrows(),
            Design::Csc(s) => s.nrows(),
            Design::Ooc(o) => o.nrows(),
        }
    }

    /// Number of features.
    pub fn p(&self) -> usize {
        match self {
            Design::ColMajor { p, .. } | Design::RowMajor { p, .. } => *p,
            Design::Rows(rows) => rows.first().map(|r| r.len()).unwrap_or(0),
            Design::Matrix(m) => m.ncols(),
            Design::Csc(s) => s.ncols(),
            Design::Ooc(o) => o.ncols(),
        }
    }

    /// Short variant name (used in cache keys and reports).
    pub fn layout_name(&self) -> &'static str {
        match self {
            Design::ColMajor { .. } => "col-major",
            Design::RowMajor { .. } => "row-major",
            Design::Rows(_) => "rows",
            Design::Matrix(_) => "matrix",
            Design::Csc(_) => "csc",
            Design::Ooc(_) => "ooc",
        }
    }

    /// Check internal shape consistency (ragged rows are the only variant
    /// the constructors cannot rule out).
    fn validate(&self) -> anyhow::Result<()> {
        if let Design::Rows(rows) = self {
            let p = self.p();
            for (i, r) in rows.iter().enumerate() {
                anyhow::ensure!(r.len() == p, "ragged design row {i}");
            }
        }
        Ok(())
    }

    /// Structured content validation: reject NaN/±∞ entries with their
    /// exact coordinates, and reject a design whose *every* column is
    /// constant (after centering it is identically zero, so no variable
    /// can ever enter the model). Individual constant columns are benign —
    /// standardization pins them at zero — and are deliberately allowed.
    /// O(n·p); runs once per cold ingest (a fingerprint cache hit means
    /// these exact bytes already passed).
    fn validate_contents(&self) -> Result<(), DfrError> {
        let (n, p) = (self.n(), self.p());
        let mut constant_cols = 0usize;
        match self {
            Design::ColMajor { data, .. } => {
                for j in 0..p {
                    let col = &data[j * n..(j + 1) * n];
                    for (i, &v) in col.iter().enumerate() {
                        if !v.is_finite() {
                            return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v });
                        }
                    }
                    if col.iter().all(|&v| v == col[0]) {
                        constant_cols += 1;
                    }
                }
            }
            Design::RowMajor { data, .. } => {
                for j in 0..p {
                    let mut constant = true;
                    for i in 0..n {
                        let v = data[i * p + j];
                        if !v.is_finite() {
                            return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v });
                        }
                        if v != data[j] {
                            constant = false;
                        }
                    }
                    if constant {
                        constant_cols += 1;
                    }
                }
            }
            Design::Rows(rows) => {
                for j in 0..p {
                    let mut constant = true;
                    for (i, r) in rows.iter().enumerate() {
                        let v = r[j];
                        if !v.is_finite() {
                            return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v });
                        }
                        if v != rows[0][j] {
                            constant = false;
                        }
                    }
                    if constant {
                        constant_cols += 1;
                    }
                }
            }
            Design::Matrix(m) => {
                for j in 0..p {
                    let col = m.col(j);
                    for (i, &v) in col.iter().enumerate() {
                        if !v.is_finite() {
                            return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v });
                        }
                    }
                    if col.iter().all(|&v| v == col[0]) {
                        constant_cols += 1;
                    }
                }
            }
            Design::Csc(s) => {
                for j in 0..p {
                    let mut nnz = 0usize;
                    let mut first = None;
                    let mut constant = true;
                    for (i, v) in s.col_entries(j) {
                        if !v.is_finite() {
                            return Err(DfrError::NonFiniteDesign { row: i, col: j, value: v });
                        }
                        nnz += 1;
                        match first {
                            None => first = Some(v),
                            Some(f) if v != f => constant = false,
                            Some(_) => {}
                        }
                    }
                    // An implicit-zero column (nnz = 0) is constant; a
                    // fully-stored column is constant iff its values
                    // agree; a partially-stored column varies (explicit
                    // stored zeros are treated as variation — the check
                    // only relaxes, never tightens).
                    if nnz == 0 || (nnz == n && constant) {
                        constant_cols += 1;
                    }
                }
            }
            Design::Ooc(_) => {
                // Pack files are validated entry-by-entry when written
                // (`dfr pack`) and shape/stat-checked again on open, so
                // re-streaming the whole file here would only repeat work.
                return Ok(());
            }
        }
        if p > 0 && constant_cols == p {
            return Err(DfrError::AllColumnsConstant { p });
        }
        Ok(())
    }

    /// Full content hash — the design leg of the fitter's
    /// prepared-dataset cache key. Every entry participates (O(n·p), far
    /// cheaper than the copy + standardization a cache hit skips), so any
    /// change to the data — including in-place edits of a previously
    /// fitted buffer — produces a new key, up to 64-bit collision odds.
    fn fingerprint(&self) -> u64 {
        match self {
            Design::ColMajor { data, .. } | Design::RowMajor { data, .. } => {
                linalg::content_hash(data)
            }
            Design::Rows(rows) => {
                let mut h: u64 = 0xcbf29ce484222325;
                for row in rows.iter() {
                    for v in row {
                        h ^= v.to_bits();
                        h = h.wrapping_mul(0x100000001b3);
                    }
                }
                h
            }
            Design::Matrix(m) => linalg::content_hash(m.as_slice()),
            Design::Csc(s) => s.fingerprint(),
            // The pack header stores the same column-major FNV-1a hash
            // `dfr pack` computed at write time — O(1) to read back.
            Design::Ooc(o) => o.content_hash(),
        }
    }

    /// Materialize the ℓ₂-standardized dense design plus the per-column
    /// `(mean, scale)` pairs needed to map coefficients back to the raw
    /// scale. This is the (cached) ingest step of every fit.
    pub fn standardized(&self) -> anyhow::Result<(Matrix, Vec<(f64, f64)>)> {
        self.validate()?;
        let (n, p) = (self.n(), self.p());
        anyhow::ensure!(n > 0 && p > 0, "empty design");
        Ok(match self {
            Design::ColMajor { data, .. } => {
                let mut m = Matrix::from_col_major(n, p, data.to_vec());
                let centers = m.standardize_l2();
                (m, centers)
            }
            Design::RowMajor { data, .. } => {
                let mut m = Matrix::from_fn(n, p, |i, j| data[i * p + j]);
                let centers = m.standardize_l2();
                (m, centers)
            }
            Design::Rows(rows) => {
                let mut m = Matrix::from_fn(n, p, |i, j| rows[i][j]);
                let centers = m.standardize_l2();
                (m, centers)
            }
            Design::Matrix(src) => {
                let mut m = (*src).clone();
                let centers = m.standardize_l2();
                (m, centers)
            }
            Design::Csc(s) => s.to_standardized_dense(),
            Design::Ooc(_) => anyhow::bail!(
                "out-of-core designs cannot materialize a dense standardized matrix; \
                 use `standardized_ops` (streaming kernels)"
            ),
        })
    }

    /// Does this design solve through the centered-implicit sparse
    /// kernels under `mode`? The single (type-checked) routing decision
    /// behind [`Design::resolved_kernel`] and
    /// [`Design::standardized_ops`].
    fn resolves_sparse(&self, mode: SparseMode) -> bool {
        match self {
            Design::Csc(s) => match mode {
                SparseMode::On => true,
                SparseMode::Off => false,
                SparseMode::Auto => s.density() <= sparse_density_threshold(),
            },
            _ => false,
        }
    }

    /// The kernel variant a fit with this design would run under `mode`
    /// ([`linalg::DENSE_KERNEL`], [`linalg::SPARSE_KERNEL`], or
    /// [`linalg::OOC_KERNEL`]) — cheap (no standardization), used for
    /// cache keys and fit reports.
    pub fn resolved_kernel(&self, mode: SparseMode) -> &'static str {
        if matches!(self, Design::Ooc(_)) {
            linalg::OOC_KERNEL
        } else if self.resolves_sparse(mode) {
            linalg::SPARSE_KERNEL
        } else {
            linalg::DENSE_KERNEL
        }
    }

    /// Standardize into the kernel representation `mode` resolves to: a
    /// CSC design below the density threshold (or forced `On`) becomes a
    /// [`CenteredSparse`] — no `n × p` dense allocation anywhere — an
    /// out-of-core design stays out of core (an Arc-cheap [`OocDesign`]
    /// clone whose `(mean, scale)` stats were computed at pack time) —
    /// while every other input takes the exact dense path of
    /// [`Design::standardized`]. Returns the per-column `(mean, scale)`
    /// alongside, as that method does.
    pub fn standardized_ops(
        &self,
        mode: SparseMode,
    ) -> anyhow::Result<(DesignOps, Vec<(f64, f64)>)> {
        if let Design::Ooc(o) = self {
            let centers: Vec<(f64, f64)> =
                o.offsets().iter().zip(o.scales()).map(|(&m, &s)| (m, s)).collect();
            return Ok((DesignOps::Ooc((*o).clone()), centers));
        }
        if let Design::Csc(s) = self {
            if self.resolves_sparse(mode) {
                anyhow::ensure!(self.n() > 0 && self.p() > 0, "empty design");
                let cs = CenteredSparse::from_csc(s);
                let centers = cs.centers();
                return Ok((DesignOps::Sparse(cs), centers));
            }
        }
        let (m, centers) = self.standardized()?;
        Ok((DesignOps::Dense(m), centers))
    }
}

impl<'a> From<&'a Matrix> for Design<'a> {
    fn from(m: &'a Matrix) -> Self {
        Design::Matrix(m)
    }
}

impl<'a> From<&'a CscMatrix> for Design<'a> {
    fn from(s: &'a CscMatrix) -> Self {
        Design::Csc(s)
    }
}

impl<'a> From<&'a OocDesign> for Design<'a> {
    fn from(o: &'a OocDesign) -> Self {
        Design::Ooc(o)
    }
}

/// A fitted model: selected coefficients on the ORIGINAL feature scale.
#[derive(Clone, Debug)]
pub struct FittedSgl {
    /// Intercept on the original scale.
    pub intercept: f64,
    /// Coefficients on the original scale (length p).
    pub coefficients: Vec<f64>,
    /// λ selected.
    pub lambda: f64,
    /// Index of the selected path point.
    pub lambda_idx: usize,
    /// Response family the model was fit under.
    pub response: Response,
    /// The underlying pathwise fit (standardized scale) for inspection.
    /// Shared (`Arc`) with the fitter's path cache, so producing a
    /// `FittedSgl` from a warm fitter never deep-copies the
    /// `path_len × p` coefficient paths.
    pub path_fit: Arc<PathFit>,
}

impl FittedSgl {
    /// The worst per-point [`SolveStatus`] along the underlying path —
    /// [`SolveStatus::Converged`] when every path point solved cleanly.
    /// Anything with `is_success() == false` means the coefficients are a
    /// best-effort iterate rather than a certified optimum; see the README
    /// troubleshooting table for the per-status caller action.
    pub fn status(&self) -> SolveStatus {
        self.path_fit.metrics.worst_status()
    }

    /// Selected (nonzero) variables, original indexing. Exact-zero test —
    /// see [`FittedSgl::selected_with_tol`] for a tolerance-aware support.
    pub fn selected(&self) -> Vec<usize> {
        self.selected_with_tol(0.0)
    }

    /// Variables with `|β_j| > eps`, original indexing. FISTA iterates can
    /// carry near-zero coefficients that the exact-zero test counts as
    /// support; pass a small `eps` (e.g. `1e-8`) to ignore them.
    pub fn selected_with_tol(&self, eps: f64) -> Vec<usize> {
        self.coefficients
            .iter()
            .enumerate()
            .filter(|(_, &c)| c.abs() > eps)
            .map(|(i, _)| i)
            .collect()
    }

    /// Linear predictor `η = intercept + xβ` for one raw observation.
    pub fn decision_function(&self, x_row: &[f64]) -> f64 {
        assert_eq!(x_row.len(), self.coefficients.len());
        self.intercept
            + x_row.iter().zip(&self.coefficients).map(|(x, c)| x * c).sum::<f64>()
    }

    /// Prediction: the conditional mean (identity for linear, σ(η) for
    /// logistic).
    pub fn predict(&self, x_row: &[f64]) -> f64 {
        let eta = self.decision_function(x_row);
        match self.response {
            Response::Linear => eta,
            Response::Logistic => sigmoid(eta),
        }
    }

    /// Batch prediction over raw rows (per-row dot products; prefer
    /// [`FittedSgl::predict_into`] with a [`Design`] for one-matvec batch
    /// serving).
    pub fn predict_many(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    /// Batch linear predictor `η = intercept·1 + Xβ` over a raw design,
    /// written into `out` (length `design.n()`). Column-layout and sparse
    /// designs run one matvec that skips zero coefficients entirely —
    /// O(n · |support|) instead of O(n · p) row dots.
    pub fn decision_function_into(&self, design: &Design, out: &mut [f64]) {
        assert_eq!(design.p(), self.coefficients.len(), "design width mismatch");
        assert_eq!(out.len(), design.n(), "output length mismatch");
        match design {
            Design::ColMajor { n, data, .. } => {
                out.fill(self.intercept);
                for (j, &c) in self.coefficients.iter().enumerate() {
                    if c != 0.0 {
                        linalg::axpy(c, &data[j * n..(j + 1) * n], out);
                    }
                }
            }
            Design::Matrix(m) => {
                out.fill(self.intercept);
                for (j, &c) in self.coefficients.iter().enumerate() {
                    if c != 0.0 {
                        linalg::axpy(c, m.col(j), out);
                    }
                }
            }
            Design::Csc(s) => {
                out.fill(self.intercept);
                for (j, &c) in self.coefficients.iter().enumerate() {
                    if c != 0.0 {
                        for (i, v) in s.col_entries(j) {
                            out[i] += c * v;
                        }
                    }
                }
            }
            Design::RowMajor { p, data, .. } => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = self.intercept
                        + linalg::dot(&data[i * p..(i + 1) * p], &self.coefficients);
                }
            }
            Design::Rows(rows) => {
                for (r, o) in rows.iter().zip(out.iter_mut()) {
                    // Hard length check: `dot` only debug-asserts, and a
                    // ragged row would otherwise yield a silently
                    // truncated prediction in release builds.
                    assert_eq!(r.len(), self.coefficients.len(), "ragged design row");
                    *o = self.intercept + linalg::dot(r, &self.coefficients);
                }
            }
            Design::Ooc(o) => {
                out.fill(self.intercept);
                // Streams only the column blocks intersecting the support,
                // accumulating raw (unstandardized) columns — the
                // coefficients here are already on the original scale.
                o.raw_matvec_acc_into(&self.coefficients, out);
            }
        }
    }

    /// Batch prediction over a raw design (conditional mean), written into
    /// `out` — [`FittedSgl::decision_function_into`] plus the response
    /// link.
    pub fn predict_into(&self, design: &Design, out: &mut [f64]) {
        self.decision_function_into(design, out);
        if self.response == Response::Logistic {
            out.iter_mut().for_each(|v| *v = sigmoid(*v));
        }
    }
}

/// Cache key of a prepared dataset: layout tag, shape, strided content
/// fingerprints of design and response, grouping, response family.
/// Shared by the fitter's own keyed-LRU cache and the multi-tenant
/// caches of [`crate::serve::FitterPool`].
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct DesignKey {
    pub(crate) layout: &'static str,
    /// Resolved kernel variant ("dense" / "centered-sparse" /
    /// "ooc-stream"): a changed sparse mode or density threshold
    /// re-ingests rather than serving a dataset prepared for the other
    /// kernel.
    pub(crate) kernel: &'static str,
    pub(crate) n: usize,
    pub(crate) p: usize,
    pub(crate) x_fp: u64,
    pub(crate) y_fp: u64,
    pub(crate) group_sizes: Vec<usize>,
    pub(crate) response: Response,
}

/// A pathwise fit cached with the settings that produced it.
struct CachedPath {
    rule: RuleKind,
    cfg: PathConfig,
    fixed: Option<Vec<f64>>,
    fit: Arc<PathFit>,
}

/// Integrity stamp of a cache entry: a deterministic fold of the key's
/// content fingerprints, recomputed on every probe. A stored entry whose
/// stamp no longer matches (memory corruption, or an injected fault via
/// [`SglFitter::testkit_poison_cache`]) is demoted to a cold re-ingest
/// instead of being served.
pub(crate) fn stamp_of(key: &DesignKey) -> u64 {
    key.x_fp
        .rotate_left(17)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ key.y_fp
        ^ (((key.n as u64) << 32) | key.p as u64)
}

/// A validated, standardized dataset with everything needed to map fits
/// back to the raw scale — the value type of every prepared-dataset
/// cache (the fitter's keyed-LRU slot and, behind an `Arc`, the shared
/// multi-tenant cache of [`crate::serve::FitterPool`]).
pub(crate) struct PreparedData {
    pub(crate) key: DesignKey,
    /// `stamp_of(&key)` at ingest time; checked on every cache probe.
    pub(crate) stamp: u64,
    pub(crate) ds: Dataset,
    pub(crate) centers: Vec<(f64, f64)>,
    /// Raw response mean (0 for logistic) — the intercept base.
    pub(crate) y_mean: f64,
}

/// Validate shapes and build the content-addressed cache key for one
/// problem. Cheap relative to ingest: O(n·p) hashing, no copies.
pub(crate) fn design_key(
    design: &Design,
    y: &[f64],
    group_sizes: &[usize],
    response: Response,
    sparse: SparseMode,
) -> anyhow::Result<DesignKey> {
    design.validate()?;
    let (n, p) = (design.n(), design.p());
    if n == 0 || p == 0 {
        return Err(DfrError::EmptyDesign { n, p }.into());
    }
    if y.len() != n {
        return Err(DfrError::DimensionMismatch { what: "y", expected: n, got: y.len() }.into());
    }
    if let Some(g) = group_sizes.iter().position(|&s| s == 0) {
        return Err(DfrError::EmptyGroup { group: g }.into());
    }
    let sum: usize = group_sizes.iter().sum();
    if sum != p {
        return Err(DfrError::GroupMismatch { sum, p }.into());
    }
    Ok(DesignKey {
        layout: design.layout_name(),
        kernel: design.resolved_kernel(sparse),
        n,
        p,
        x_fp: design.fingerprint(),
        y_fp: linalg::content_hash(y),
        group_sizes: group_sizes.to_vec(),
        response,
    })
}

/// Cold ingest under a previously computed key: full content validation,
/// standardization (dense or centered-sparse per the key's kernel), and
/// response centering. This is the work a prepared-cache hit skips.
pub(crate) fn prepare_data(
    design: &Design,
    y: &[f64],
    group_sizes: &[usize],
    response: Response,
    sparse: SparseMode,
    key: DesignKey,
) -> anyhow::Result<PreparedData> {
    design.validate_contents()?;
    if let Some(i) = y.iter().position(|v| !v.is_finite()) {
        return Err(DfrError::NonFiniteResponse { index: i, value: y[i] }.into());
    }
    if y.iter().all(|&v| v == y[0]) {
        let detail = match response {
            Response::Linear => {
                format!("constant response y ≡ {} (zero variance)", y[0])
            }
            Response::Logistic => {
                format!("single-class response y ≡ {} (logistic needs both classes)", y[0])
            }
        };
        return Err(DfrError::DegenerateResponse { detail }.into());
    }
    let (x, centers) = design.standardized_ops(sparse)?;
    let mut yv = y.to_vec();
    let y_mean = if response == Response::Linear {
        let m = yv.iter().sum::<f64>() / design.n() as f64;
        yv.iter_mut().for_each(|v| *v -= m);
        m
    } else {
        0.0
    };
    let ds = Dataset {
        x,
        y: yv,
        groups: crate::groups::Groups::from_sizes(group_sizes),
        response,
        name: "user".into(),
    };
    let stamp = stamp_of(&key);
    Ok(PreparedData { key, stamp, ds, centers, y_mean })
}

/// Approximate resident size of a prepared dataset — the byte-accounting
/// leg of the LRU bounds (design + response + center pairs; exact enough
/// for capacity planning, not an allocator audit).
pub(crate) fn prepared_bytes(data: &PreparedData) -> usize {
    let x = match &data.ds.x {
        crate::linalg::DesignOps::Dense(m) => m.nrows() * m.ncols() * 8,
        // Raw nonzeros (index + value) plus per-column affine terms.
        crate::linalg::DesignOps::Sparse(s) => s.nnz() * 16 + data.ds.p() * 16,
        // The data lives on disk; only the per-column `(offset, scale)`
        // stats are resident (streaming block buffers are transient and
        // bounded separately by `DFR_OOC_BLOCK`).
        crate::linalg::DesignOps::Ooc(o) => o.ncols() * 16,
    };
    x + data.ds.y.len() * 8 + data.centers.len() * 16
}

/// A prepared dataset plus the per-dataset sub-caches the fitter layers
/// on top: the last pathwise fit and the last CV cell.
struct Prepared {
    data: PreparedData,
    path: Option<CachedPath>,
    /// Single-cell CV result cached with the exact configuration that
    /// produced it, so repeated `fit_cv` calls skip the k·path_len fold
    /// fits (CV is deterministic given the dataset and config).
    cv_cell: Option<(CvConfig, CvCell)>,
}

/// Shared cache counters: hit/miss statistics readable from any thread
/// without `&mut` access to the fitter that owns them.
///
/// Counters are relaxed atomics behind an `Arc`
/// ([`SglFitter::cache_stats`] hands the handle out), so a monitoring
/// thread — or the serving layer's `stats` verb — can read live values
/// while fits are in flight. Relaxed ordering is deliberate: the counters
/// are telemetry, not synchronization.
#[derive(Debug, Default)]
pub struct CacheStats {
    prepared_hits: AtomicUsize,
    prepared_misses: AtomicUsize,
    path_hits: AtomicUsize,
    cv_hits: AtomicUsize,
}

impl CacheStats {
    /// Prepared-dataset cache hits (fits that skipped copy + standardize).
    pub fn prepared_hits(&self) -> usize {
        self.prepared_hits.load(Ordering::Relaxed)
    }

    /// Prepared-dataset cache misses (cold ingests).
    pub fn prepared_misses(&self) -> usize {
        self.prepared_misses.load(Ordering::Relaxed)
    }

    /// Path-cache hits (fits/refits that skipped the solve entirely).
    pub fn path_hits(&self) -> usize {
        self.path_hits.load(Ordering::Relaxed)
    }

    /// CV-cell cache hits (`fit_cv` calls that skipped the fold fits).
    pub fn cv_hits(&self) -> usize {
        self.cv_hits.load(Ordering::Relaxed)
    }

    pub(crate) fn bump_prepared_hit(&self) {
        self.prepared_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn bump_prepared_miss(&self) {
        self.prepared_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn bump_path_hit(&self) {
        self.path_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn bump_cv_hit(&self) {
        self.cv_hits.fetch_add(1, Ordering::Relaxed);
    }
}

/// Persistent fitting engine: the serving-path counterpart of the plain
/// [`SglModel`] config.
///
/// Construction is cheap; the value is in *holding on to it*. Across
/// repeated calls the fitter reuses, in order of increasing savings:
///
/// 1. its [`WorkspacePool`] of [`PathWorkspace`]s (solver buffers,
///    reduced-design gather cache — allocated once, grow-only),
/// 2. the prepared dataset: copy + ℓ₂ standardization of the input
///    [`Design`] happen once per content fingerprint, so follow-up fits
///    go straight into screening with zero copies,
/// 3. the last pathwise fit: a repeated `fit_at` with unchanged settings,
///    or a [`SglFitter::refit`] at a different λ index, re-selects from
///    the cached path without solving anything.
///
/// All caches are transparent: outputs are identical to a cold fit (the
/// equivalence is pinned by `rust/tests/serving_api.rs`). The fitter is a
/// single-owner object (`&mut self` methods); share work across threads
/// by giving each worker its own fitter, lean on the internal
/// [`CvEngine`] whose pool already spans `threads` workers, or move up to
/// the multi-tenant [`crate::serve::FitterPool`] whose shared caches are
/// built from the same keyed-LRU substrate ([`crate::lru::KeyedLru`]).
///
/// The prepared-dataset cache holds one dataset by default (the original
/// single-slot semantics); [`SglFitter::with_prepared_capacity`] widens
/// it so one fitter can serve several datasets LRU-style, each with its
/// own path and CV sub-caches.
pub struct SglFitter {
    model: SglModel,
    threads: usize,
    pool: WorkspacePool<PathWorkspace>,
    cv: CvEngine,
    /// Keyed-LRU prepared cache; `current` names the entry the last
    /// `prepare` resolved, which follow-up calls (`refit`,
    /// `finalize_cached`) operate on.
    prepared: KeyedLru<DesignKey, Prepared>,
    current: Option<DesignKey>,
    stats: Arc<CacheStats>,
}

impl SglFitter {
    /// Fitter with [`crate::parallel::default_threads`] CV workers.
    pub fn new(model: SglModel) -> Self {
        Self::with_threads(model, crate::parallel::default_threads())
    }

    /// Fitter with an explicit CV worker count (single path fits are
    /// serial either way; `threads` sizes the CV engine's workspace
    /// pool).
    pub fn with_threads(model: SglModel, threads: usize) -> Self {
        let threads = threads.max(1);
        SglFitter {
            model,
            threads,
            pool: WorkspacePool::new(1),
            cv: CvEngine::new(threads),
            prepared: KeyedLru::new(1, usize::MAX),
            current: None,
            stats: Arc::new(CacheStats::default()),
        }
    }

    /// Widen the prepared-dataset cache to hold up to `capacity` datasets
    /// (LRU-evicted beyond that). Capacity 1 — the default — reproduces
    /// the historical single-slot behavior exactly. Existing cached
    /// entries are dropped (the cache is rebuilt with the new bound).
    pub fn with_prepared_capacity(mut self, capacity: usize) -> Self {
        self.prepared = KeyedLru::new(capacity, usize::MAX);
        self.current = None;
        self
    }

    /// The model configuration this fitter runs with.
    pub fn model(&self) -> &SglModel {
        &self.model
    }

    /// The internal workspace-pooled CV engine (pool statistics live
    /// here: [`CvEngine::pool_slots`] / [`CvEngine::pool_checkouts`]).
    pub fn cv_engine(&self) -> &CvEngine {
        &self.cv
    }

    /// Path-workspace pool slots — stays at 1 forever; the witness that
    /// repeated single fits allocate no new workspaces.
    pub fn pool_slots(&self) -> usize {
        self.pool.slots()
    }

    /// Path-workspace checkouts served (one per actual path solve).
    pub fn pool_checkouts(&self) -> usize {
        self.pool.checkouts()
    }

    /// Prepared-dataset cache hits (fits that skipped copy + standardize).
    pub fn prepared_hits(&self) -> usize {
        self.stats.prepared_hits()
    }

    /// Prepared-dataset cache misses (cold ingests).
    pub fn prepared_misses(&self) -> usize {
        self.stats.prepared_misses()
    }

    /// Path-cache hits (fits/refits that skipped the solve entirely).
    pub fn path_hits(&self) -> usize {
        self.stats.path_hits()
    }

    /// CV-cell cache hits (`fit_cv` calls that skipped the fold fits).
    pub fn cv_hits(&self) -> usize {
        self.stats.cv_hits()
    }

    /// Shared handle to the fitter's cache counters ([`CacheStats`]).
    /// Clone-cheap (`Arc`); reads are valid from any thread while the
    /// fitter keeps working — the shareable-stats leg of the serving
    /// layer.
    pub fn cache_stats(&self) -> Arc<CacheStats> {
        Arc::clone(&self.stats)
    }

    /// Datasets currently held by the prepared cache.
    pub fn prepared_len(&self) -> usize {
        self.prepared.len()
    }

    /// Prepared-cache LRU evictions so far (0 until the cache is widened
    /// past its default single slot and overflows).
    pub fn prepared_evictions(&self) -> u64 {
        self.prepared.evictions()
    }

    /// Kernel variant of the currently prepared dataset ("dense" /
    /// "centered-sparse" / "ooc-stream"); `None` before the first fit.
    /// Fit reports echo this so kernel routing is observable.
    pub fn kernel_variant(&self) -> Option<&'static str> {
        self.current.as_ref().map(|k| k.kernel)
    }

    /// Drop every cache (prepared datasets, paths, CV cells). The content
    /// hash already detects any data change — including in-place edits —
    /// so this is an explicit escape hatch (memory release, paranoia),
    /// not a correctness requirement.
    pub fn invalidate(&mut self) {
        self.prepared.clear();
        self.current = None;
    }

    /// Drop only the cached pathwise fit of the current dataset, keeping
    /// the prepared data — forces the next fit to re-solve (benchmarking
    /// aid).
    pub fn clear_path_cache(&mut self) {
        if let Some(key) = self.current.clone() {
            if let Some(prep) = self.prepared.get_mut(&key) {
                prep.path = None;
            }
        }
    }

    /// Fit the whole λ path on a raw design and return it (standardized
    /// scale; use [`SglFitter::fit_at`] / [`SglFitter::refit`] for
    /// raw-scale selections).
    pub fn fit_path(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<&PathFit> {
        self.prepare(design, y, group_sizes, response)?;
        self.ensure_path(self.model.path.clone(), self.model.rule, None)?;
        match self
            .current
            .as_ref()
            .and_then(|k| self.prepared.peek(k))
            .and_then(|prep| prep.path.as_ref())
        {
            Some(cached) => Ok(cached.fit.as_ref()),
            None => anyhow::bail!("path cache empty after ensure_path"),
        }
    }

    /// Fit the path on a raw design and select λ at a fixed index
    /// (e.g. from a previous CV). Repeated calls with the same design and
    /// settings hit the path cache and only re-select.
    pub fn fit_at(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        lambda_idx: usize,
    ) -> anyhow::Result<FittedSgl> {
        self.prepare(design, y, group_sizes, response)?;
        self.ensure_path(self.model.path.clone(), self.model.rule, None)?;
        self.finalize_cached(lambda_idx)
    }

    /// Re-select a different λ index from the cached path — no solve, no
    /// data pass; errors if nothing has been fit on this fitter yet.
    pub fn refit(&mut self, lambda_idx: usize) -> anyhow::Result<FittedSgl> {
        anyhow::ensure!(
            self.current
                .as_ref()
                .and_then(|k| self.prepared.peek(k))
                .is_some_and(|p| p.path.is_some()),
            "refit requires a previous fit on this fitter"
        );
        self.stats.bump_path_hit();
        self.finalize_cached(lambda_idx)
    }

    /// Change the SGL mixing parameter and refit on the cached prepared
    /// dataset (warm workspace, no re-ingest; the λ grid is re-derived
    /// since α moves λ_max). Errors if nothing has been prepared yet.
    pub fn refit_alpha(&mut self, alpha: f64, lambda_idx: usize) -> anyhow::Result<FittedSgl> {
        anyhow::ensure!(
            self.current.as_ref().is_some_and(|k| self.prepared.peek(k).is_some()),
            "refit_alpha requires a previous fit on this fitter"
        );
        self.model.path.alpha = alpha;
        self.ensure_path(self.model.path.clone(), self.model.rule, None)?;
        self.finalize_cached(lambda_idx)
    }

    /// Fit the path and select λ by k-fold cross-validation (raw-scale
    /// held-out scoring; see [`crate::cv::CvFold::holdout_loss`]). The CV
    /// result is cached with its configuration, so a repeated `fit_cv` on
    /// unchanged data skips the fold fits entirely (its `seconds` field
    /// then reports the original run).
    pub fn fit_cv(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<FittedSgl> {
        // Fold extraction gathers row subsets into dense fold designs —
        // exactly the n × p materialization the out-of-core path exists
        // to avoid. Fail up front with an actionable message instead of
        // panicking inside `gather_rows`.
        anyhow::ensure!(
            !matches!(design, Design::Ooc(_)),
            "cross-validation is not supported for out-of-core designs; \
             fit at a fixed λ (fit_at / fit_path) instead"
        );
        self.prepare(design, y, group_sizes, response)?;
        let cfg = self.cv_config();
        let Self { prepared, current, cv, stats, .. } = self;
        let prep = match current.as_ref().and_then(|k| prepared.get_mut(k)) {
            Some(p) => p,
            None => anyhow::bail!("prepare() must run before fit_cv"),
        };
        let mut cell: Option<CvCell> = None;
        if let Some((c, cached)) = prep.cv_cell.as_ref() {
            if *c == cfg {
                cell = Some(cached.clone());
                stats.bump_cv_hit();
            }
        }
        let cell = match cell {
            Some(c) => c,
            None => {
                let fresh = cv.cross_validate(&prep.data.ds, &cfg)?;
                prep.cv_cell = Some((cfg, fresh.clone()));
                fresh
            }
        };
        let idx = if self.model.one_se_rule { cell.best_1se_idx } else { cell.best_idx };
        self.ensure_path(self.model.path.clone(), self.model.rule, Some(cell.lambdas))?;
        self.finalize_cached(idx)
    }

    /// Run the `(α, γ)` CV grid on a raw design and return every cell
    /// plus the winner index — the inspectable half of
    /// [`SglFitter::fit_cv_grid`].
    pub fn cv_grid(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        alphas: &[f64],
        gammas: &[Option<(f64, f64)>],
    ) -> anyhow::Result<(Vec<CvCell>, usize)> {
        // Same constraint as `fit_cv`: folds materialize dense subsets.
        anyhow::ensure!(
            !matches!(design, Design::Ooc(_)),
            "cross-validation is not supported for out-of-core designs; \
             fit at a fixed λ (fit_at / fit_path) instead"
        );
        self.prepare(design, y, group_sizes, response)?;
        let cfg = self.cv_config();
        let prep = match self.current.as_ref().and_then(|k| self.prepared.peek(k)) {
            Some(p) => p,
            None => anyhow::bail!("prepare() must run before cv_grid"),
        };
        self.cv.grid_search(&prep.data.ds, &cfg, alphas, gammas)
    }

    /// Jointly tune `(λ, α)` — and `(γ₁, γ₂)` for aSGL — by k-fold CV
    /// over the given grids, then refit at the winning cell's settings.
    /// The whole grid runs through the fitter's persistent [`CvEngine`]
    /// with shared fold splits and pooled workspaces.
    pub fn fit_cv_grid(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        alphas: &[f64],
        gammas: &[Option<(f64, f64)>],
    ) -> anyhow::Result<FittedSgl> {
        let (cells, best) = self.cv_grid(design, y, group_sizes, response, alphas, gammas)?;
        let cell = &cells[best];
        let idx = if self.model.one_se_rule { cell.best_1se_idx } else { cell.best_idx };
        let mut path = self.model.path.clone();
        path.alpha = cell.alpha;
        path.adaptive = cell.gamma;
        self.ensure_path(path, self.model.rule, Some(cell.lambdas.clone()))?;
        self.finalize_cached(idx)
    }

    /// The CV configuration this fitter runs with.
    fn cv_config(&self) -> CvConfig {
        CvConfig {
            folds: self.model.cv_folds,
            path: self.model.path.clone(),
            rule: self.model.rule,
            seed: self.model.seed,
            threads: self.threads,
        }
    }

    /// Validate the inputs and make sure the prepared-dataset cache holds
    /// this exact problem (fingerprint-keyed; hit = no copy, no
    /// standardization).
    fn prepare(
        &mut self,
        design: &Design,
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<()> {
        let key = design_key(design, y, group_sizes, response, self.model.sparse)?;
        // A hit must also pass the integrity stamp: a poisoned or
        // corrupted entry falls through to a cold re-ingest.
        let hit = self
            .prepared
            .get(&key)
            .is_some_and(|prep| prep.data.stamp == stamp_of(&prep.data.key));
        if hit {
            self.stats.bump_prepared_hit();
            self.current = Some(key);
            return Ok(());
        }
        self.stats.bump_prepared_miss();
        let data = prepare_data(design, y, group_sizes, response, self.model.sparse, key.clone())?;
        let bytes = prepared_bytes(&data);
        self.prepared.insert(key.clone(), Prepared { data, path: None, cv_cell: None }, bytes);
        self.current = Some(key);
        Ok(())
    }

    /// Corrupt the prepared-dataset cache's integrity stamp — a
    /// fault-injection hook for the robustness suite. The next `prepare`
    /// on the same data must detect the mismatch and re-ingest (a cache
    /// *miss*) instead of serving the poisoned entry; results stay
    /// bit-identical to a cold fit. No-op when nothing is cached.
    #[doc(hidden)]
    pub fn testkit_poison_cache(&mut self) {
        let Self { prepared, current, .. } = self;
        if let Some(prep) = current.as_ref().and_then(|k| prepared.get_mut(k)) {
            prep.data.stamp ^= 0x5eed_bad_c0ffee;
        }
    }

    /// Make sure the path cache holds a fit with exactly these settings,
    /// solving (with a pooled workspace) only on a miss.
    fn ensure_path(
        &mut self,
        cfg: PathConfig,
        rule: RuleKind,
        fixed: Option<Vec<f64>>,
    ) -> anyhow::Result<()> {
        let Self { prepared, current, pool, stats, .. } = self;
        let prep = match current.as_ref().and_then(|k| prepared.get_mut(k)) {
            Some(p) => p,
            None => anyhow::bail!("prepare() must run before ensure_path()"),
        };
        if prep
            .path
            .as_ref()
            .is_some_and(|c| c.rule == rule && c.cfg == cfg && c.fixed == fixed)
        {
            stats.bump_path_hit();
            return Ok(());
        }
        let mut runner = PathRunner::new(&prep.data.ds, cfg.clone()).rule(rule);
        if let Some(lambdas) = fixed.clone() {
            runner = runner.fixed_path(lambdas);
        }
        let mut ws = pool.checkout();
        let fit = runner.run_with_workspace(&mut ws)?;
        prep.path = Some(CachedPath { rule, cfg, fixed, fit: Arc::new(fit) });
        Ok(())
    }

    /// Unstandardize the cached path's coefficients at `idx` into a
    /// raw-scale [`FittedSgl`].
    fn finalize_cached(&self, idx: usize) -> anyhow::Result<FittedSgl> {
        let prep = match self.current.as_ref().and_then(|k| self.prepared.peek(k)) {
            Some(p) => p,
            None => anyhow::bail!("no prepared dataset (fit before refit)"),
        };
        let cached = match prep.path.as_ref() {
            Some(c) => c,
            None => anyhow::bail!("no cached path fit (fit before refit)"),
        };
        finalize(&cached.fit, &prep.data.centers, prep.data.y_mean, prep.data.ds.response, idx)
    }
}

/// Map a standardized-scale path point back to the original feature
/// scale: `x_std_j = (x_j − m_j)/s_j ⇒ β_j = β_std_j / s_j`, intercept
/// absorbs `−Σ β_std_j m_j / s_j` (+ ȳ for linear). The path is attached
/// by `Arc`, never deep-copied.
pub(crate) fn finalize(
    fit: &Arc<PathFit>,
    centers: &[(f64, f64)],
    y_mean: f64,
    response: Response,
    idx: usize,
) -> anyhow::Result<FittedSgl> {
    anyhow::ensure!(idx < fit.betas.len(), "lambda index out of range");
    let beta_std = &fit.betas[idx];
    let mut coefficients = vec![0.0; beta_std.len()];
    let mut shift = 0.0;
    for (j, &b) in beta_std.iter().enumerate() {
        let (m, s) = centers[j];
        coefficients[j] = b / s;
        shift += b * m / s;
    }
    let intercept = match response {
        Response::Linear => y_mean - shift,
        Response::Logistic => -shift,
    };
    Ok(FittedSgl {
        intercept,
        coefficients,
        lambda: fit.lambdas[idx],
        lambda_idx: idx,
        response,
        path_fit: Arc::clone(fit),
    })
}

impl SglModel {
    /// Fit the path on RAW data (x rows × p cols, row-major rows) and
    /// select λ at a fixed index (e.g. from a previous CV).
    #[deprecated(
        since = "0.2.0",
        note = "build a persistent `SglFitter` (`SglModel::fitter`) and call `fit_at` with a `Design`; this shim constructs a throwaway fitter per call"
    )]
    pub fn fit_at(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        lambda_idx: usize,
    ) -> anyhow::Result<FittedSgl> {
        self.fitter().fit_at(&Design::rows(x_rows), y, group_sizes, response, lambda_idx)
    }

    /// Fit the path and select λ by k-fold cross-validation.
    #[deprecated(
        since = "0.2.0",
        note = "build a persistent `SglFitter` (`SglModel::fitter`) and call `fit_cv` with a `Design`; this shim constructs a throwaway fitter per call"
    )]
    pub fn fit_cv(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
    ) -> anyhow::Result<FittedSgl> {
        self.fitter().fit_cv(&Design::rows(x_rows), y, group_sizes, response)
    }

    /// Jointly tune `(λ, α)` — and `(γ₁, γ₂)` for aSGL — by k-fold CV
    /// over the given grids, then refit at the winning cell's settings.
    #[deprecated(
        since = "0.2.0",
        note = "build a persistent `SglFitter` (`SglModel::fitter`) and call `fit_cv_grid` with a `Design`; this shim constructs a throwaway fitter per call"
    )]
    pub fn fit_cv_grid(
        &self,
        x_rows: &[Vec<f64>],
        y: &[f64],
        group_sizes: &[usize],
        response: Response,
        alphas: &[f64],
        gammas: &[Option<(f64, f64)>],
    ) -> anyhow::Result<FittedSgl> {
        self.fitter().fit_cv_grid(&Design::rows(x_rows), y, group_sizes, response, alphas, gammas)
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims stay under test to pin parity

    use super::*;
    use crate::rng::Rng;

    fn raw_problem(seed: u64, n: usize, p: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        // Deliberately unstandardized features (offset + scale).
        let mut rng = Rng::new(seed);
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 4 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..p).map(|j| 5.0 + (j as f64 + 1.0) * rng.gauss()).collect())
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| {
                r.iter().zip(&beta_true).map(|(x, b)| x * b).sum::<f64>() + rng.normal(0.0, 0.5)
            })
            .collect();
        (rows, y, beta_true)
    }

    /// Flatten row vectors into a column-major buffer.
    fn col_major_of(rows: &[Vec<f64>]) -> Vec<f64> {
        let (n, p) = (rows.len(), rows[0].len());
        let mut data = vec![0.0; n * p];
        for (i, r) in rows.iter().enumerate() {
            for (j, &v) in r.iter().enumerate() {
                data[j * n + i] = v;
            }
        }
        data
    }

    #[test]
    fn fit_predict_round_trip_linear() {
        let (rows, y, _) = raw_problem(1, 80, 16);
        let model = SglModel {
            path: PathConfig { path_len: 15, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[4, 4, 4, 4], Response::Linear, 14).unwrap();
        // In-sample predictions should correlate strongly with y.
        let preds: Vec<f64> = rows.iter().map(|r| fitted.predict(r)).collect();
        let corr = correlation(&preds, &y);
        assert!(corr > 0.95, "in-sample correlation {corr}");
        assert!(!fitted.selected().is_empty());
    }

    /// `with_rule` threads a screening rule through the serving API, and a
    /// safe rule's fit records zero KKT re-entry rounds while matching the
    /// default strong rule's solution.
    #[test]
    fn with_rule_selects_safe_rule_end_to_end() {
        assert_eq!(SglModel::default().rule, RuleKind::DfrSgl);
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            ..Default::default()
        };
        assert_eq!(
            model.clone().with_rule(RuleKind::Tlfre).rule,
            RuleKind::Tlfre
        );
        let (rows, y, _) = raw_problem(12, 80, 16);
        let strong = model.fit_at(&rows, &y, &[4, 4, 4, 4], Response::Linear, 8).unwrap();
        let safe = model
            .with_rule(RuleKind::Tlfre)
            .fit_at(&rows, &y, &[4, 4, 4, 4], Response::Linear, 8)
            .unwrap();
        assert_eq!(safe.path_fit.rule, RuleKind::Tlfre);
        assert_eq!(safe.path_fit.metrics.total_kkt_reentries(), 0);
        crate::testkit::assert_close(
            &safe.path_fit.betas[8],
            &strong.path_fit.betas[8],
            1e-4,
            "TLFre vs DFR serving-API solution",
        );
    }

    #[test]
    fn unstandardized_coefficients_reproduce_standardized_predictions() {
        let (rows, y, _) = raw_problem(2, 60, 12);
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[3, 3, 3, 3], Response::Linear, 9).unwrap();
        // Rebuild the standardized design and compare η computed both ways.
        let (x_std, _centers) = Design::rows(&rows).standardized().unwrap();
        let beta_std = &fitted.path_fit.betas[9];
        let ymean = y.iter().sum::<f64>() / y.len() as f64;
        for i in 0..5 {
            let eta_std: f64 =
                (0..12).map(|j| x_std.get(i, j) * beta_std[j]).sum::<f64>() + ymean;
            let eta_raw = fitted.decision_function(&rows[i]);
            assert!((eta_std - eta_raw).abs() < 1e-8, "row {i}: {eta_std} vs {eta_raw}");
        }
    }

    #[test]
    fn cv_fit_selects_interior_lambda() {
        let (rows, y, _) = raw_problem(3, 100, 12);
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            cv_folds: 4,
            ..Default::default()
        };
        let fitted = model.fit_cv(&rows, &y, &[4, 4, 4], Response::Linear).unwrap();
        assert!(fitted.lambda_idx > 0);
        assert!(fitted.lambda > 0.0);
    }

    #[test]
    fn one_se_rule_picks_sparser_model() {
        let (rows, y, _) = raw_problem(4, 100, 12);
        let base = SglModel {
            path: PathConfig { path_len: 12, ..PathConfig::default() },
            cv_folds: 4,
            ..Default::default()
        };
        let plain = base.fit_cv(&rows, &y, &[4, 4, 4], Response::Linear).unwrap();
        let one_se = SglModel { one_se_rule: true, ..base }
            .fit_cv(&rows, &y, &[4, 4, 4], Response::Linear)
            .unwrap();
        assert!(one_se.lambda_idx <= plain.lambda_idx, "1-SE must not be denser");
        assert!(one_se.selected().len() <= plain.selected().len() + 1);
    }

    #[test]
    fn cv_grid_fit_selects_a_grid_cell() {
        let (rows, y, _) = raw_problem(6, 90, 12);
        let model = SglModel {
            path: PathConfig { path_len: 8, ..PathConfig::default() },
            cv_folds: 3,
            ..Default::default()
        };
        let alphas = [0.5, 0.95];
        let fitted = model
            .fit_cv_grid(&rows, &y, &[4, 4, 4], Response::Linear, &alphas, &[None])
            .unwrap();
        assert!(fitted.path_fit.lambdas.len() == 8);
        assert!(fitted.lambda > 0.0);
        // The in-sample fit should still track the signal.
        let preds: Vec<f64> = rows.iter().map(|r| fitted.predict(r)).collect();
        assert!(correlation(&preds, &y) > 0.9);
    }

    #[test]
    fn logistic_predictions_are_probabilities() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> =
            (0..90).map(|_| (0..8).map(|_| rng.gauss()).collect()).collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| if r[0] + r[4] + 0.3 * rng.gauss() > 0.0 { 1.0 } else { 0.0 })
            .collect();
        let model = SglModel {
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[4, 4], Response::Logistic, 9).unwrap();
        let acc = rows
            .iter()
            .zip(&y)
            .filter(|(r, &yy)| (fitted.predict(r) > 0.5) == (yy == 1.0))
            .count() as f64
            / 90.0;
        assert!(acc > 0.8, "in-sample accuracy {acc}");
        for r in rows.iter().take(10) {
            let pr = fitted.predict(r);
            assert!((0.0..=1.0).contains(&pr));
        }
    }

    #[test]
    fn design_layouts_agree_on_standardization() {
        let (rows, _, _) = raw_problem(7, 30, 6);
        let cm = col_major_of(&rows);
        let rm: Vec<f64> = rows.iter().flatten().copied().collect();
        let dense = Matrix::from_fn(30, 6, |i, j| rows[i][j]);
        let csc = CscMatrix::from_dense(&dense, 0.0);
        let (want, want_centers) = Design::rows(&rows).standardized().unwrap();
        for d in [
            Design::col_major(30, 6, &cm),
            Design::row_major(30, 6, &rm),
            Design::Matrix(&dense),
            Design::Csc(&csc),
        ] {
            let (got, centers) = d.standardized().unwrap();
            for j in 0..6 {
                let (wm, ws) = want_centers[j];
                let (gm, gs) = centers[j];
                assert!((wm - gm).abs() < 1e-10, "{}: col {j} mean", d.layout_name());
                assert!((ws - gs).abs() < 1e-10, "{}: col {j} scale", d.layout_name());
                for i in 0..30 {
                    assert!(
                        (want.get(i, j) - got.get(i, j)).abs() < 1e-10,
                        "{}: entry ({i}, {j})",
                        d.layout_name()
                    );
                }
            }
        }
    }

    #[test]
    fn fitter_caches_prepared_dataset_and_path() {
        let (rows, y, _) = raw_problem(8, 50, 8);
        let model = SglModel {
            path: PathConfig { path_len: 8, ..PathConfig::default() },
            ..Default::default()
        };
        let mut fitter = model.fitter();
        let a = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 7).unwrap();
        assert_eq!(fitter.prepared_misses(), 1);
        assert_eq!(fitter.pool_checkouts(), 1);
        // Same design, different λ index: prepared + path cache hits.
        let b = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 4).unwrap();
        assert_eq!(fitter.prepared_hits(), 1);
        assert_eq!(fitter.path_hits(), 1);
        assert_eq!(fitter.pool_checkouts(), 1, "path cache hit must not solve");
        assert_eq!(b.lambda, a.path_fit.lambdas[4]);
        // refit re-selects without touching data at all.
        let c = fitter.refit(7).unwrap();
        assert_eq!(c.coefficients, a.coefficients);
        assert_eq!(c.intercept, a.intercept);
    }

    #[test]
    fn fitter_refit_alpha_reuses_prepared_dataset() {
        let (rows, y, _) = raw_problem(9, 50, 8);
        let model = SglModel {
            path: PathConfig { path_len: 8, ..PathConfig::default() },
            ..Default::default()
        };
        let mut fitter = model.fitter();
        fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 7).unwrap();
        let refit = fitter.refit_alpha(0.5, 7).unwrap();
        assert_eq!(fitter.prepared_misses(), 1, "refit_alpha must not re-ingest");
        assert_eq!(fitter.pool_checkouts(), 2, "α change must re-solve");
        // Matches a cold fit at α = 0.5.
        let cold_model = SglModel {
            path: PathConfig { alpha: 0.5, path_len: 8, ..PathConfig::default() },
            ..Default::default()
        };
        let cold = cold_model.fit_at(&rows, &y, &[4, 4], Response::Linear, 7).unwrap();
        let d = crate::linalg::l2_distance(&refit.coefficients, &cold.coefficients);
        assert!(d <= 1e-10, "refit_alpha drifted from cold fit: ℓ₂ = {d}");
    }

    #[test]
    fn refit_without_fit_errors() {
        let mut fitter = SglModel::default().fitter();
        assert!(fitter.refit(0).is_err());
        assert!(fitter.refit_alpha(0.5, 0).is_err());
    }

    #[test]
    fn selected_with_tol_filters_near_zeros() {
        let fitted = FittedSgl {
            intercept: 0.0,
            coefficients: vec![0.0, 1e-12, -0.5, 3.0e-9, 2.0],
            lambda: 0.1,
            lambda_idx: 0,
            response: Response::Linear,
            path_fit: Arc::new(PathFit {
                rule: RuleKind::DfrSgl,
                lambdas: vec![0.1],
                betas: vec![vec![0.0; 5]],
                metrics: Default::default(),
            }),
        };
        assert_eq!(fitted.selected(), vec![1, 2, 3, 4]);
        assert_eq!(fitted.selected_with_tol(1e-8), vec![2, 4]);
    }

    #[test]
    fn predict_into_matches_predict_many_across_layouts() {
        let (rows, y, _) = raw_problem(10, 40, 8);
        let model = SglModel {
            path: PathConfig { path_len: 8, ..PathConfig::default() },
            ..Default::default()
        };
        let fitted = model.fit_at(&rows, &y, &[4, 4], Response::Linear, 7).unwrap();
        let want = fitted.predict_many(&rows);
        let cm = col_major_of(&rows);
        let rm: Vec<f64> = rows.iter().flatten().copied().collect();
        let dense = Matrix::from_fn(40, 8, |i, j| rows[i][j]);
        let csc = CscMatrix::from_dense(&dense, 0.0);
        let mut out = vec![0.0; 40];
        for d in [
            Design::rows(&rows),
            Design::col_major(40, 8, &cm),
            Design::row_major(40, 8, &rm),
            Design::Matrix(&dense),
            Design::Csc(&csc),
        ] {
            fitted.predict_into(&d, &mut out);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-12, "{} drifted", d.layout_name());
            }
        }
    }

    #[test]
    fn sparse_mode_parses_and_defaults() {
        assert_eq!(SparseMode::parse("auto").unwrap(), SparseMode::Auto);
        assert_eq!(SparseMode::parse("ON").unwrap(), SparseMode::On);
        assert_eq!(SparseMode::parse("off").unwrap(), SparseMode::Off);
        assert!(SparseMode::parse("sometimes").is_err());
        assert_eq!(SglModel::default().sparse, SparseMode::Auto);
        // Without an env override the threshold is the documented default.
        if std::env::var("DFR_SPARSE_DENSITY").is_err() {
            assert!((sparse_density_threshold() - 0.25).abs() < 1e-15);
        }
    }

    #[test]
    fn dense_designs_always_resolve_dense() {
        let (rows, _, _) = raw_problem(11, 10, 4);
        for mode in [SparseMode::Auto, SparseMode::On, SparseMode::Off] {
            assert_eq!(Design::rows(&rows).resolved_kernel(mode), "dense");
        }
    }

    fn correlation(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
        let mut num = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for i in 0..a.len() {
            num += (a[i] - ma) * (b[i] - mb);
            va += (a[i] - ma).powi(2);
            vb += (b[i] - mb).powi(2);
        }
        num / (va.sqrt() * vb.sqrt())
    }
}
