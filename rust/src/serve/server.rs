//! The `dfr serve` loop: blocking NDJSON over any `Read`/`Write` pair.
//!
//! A detached reader thread pulls lines into a bounded channel; the
//! dispatch loop blocks on the first line of a batch, then drains
//! whatever else has already arrived (up to `batch_max`) so concurrent
//! clients piping bursts get admission batching — fits round-robin
//! across tenants, predicts coalesced — without any latency penalty for
//! a lone request (nothing waits for a timer).
//!
//! The reader thread is deliberately *detached*: a `shutdown` verb must
//! not block on a reader stuck in `read_line` on an idle pipe. After
//! shutdown the channel is dropped; the reader notices on its next send
//! and exits. EOF on the input ends the loop the same way a `shutdown`
//! does, so `dfr serve < script.ndjson` terminates cleanly.

use crate::serve::pool::FitterPool;
use crate::serve::protocol::{Reply, Request};
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::mpsc;

/// Serve-loop tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Max requests dispatched as one batch (admission window).
    pub batch_max: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { batch_max: 64 }
    }
}

/// What the loop did before returning.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    /// Non-blank request lines seen (including parse failures).
    pub requests: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// True when a `shutdown` verb ended the loop (false = input EOF).
    pub shutdown: bool,
}

/// Run the NDJSON serve loop until `shutdown` or EOF. Generic over the
/// input so tests drive it with an `io::Cursor` script; `dfr serve`
/// passes `std::io::stdin()`.
pub fn serve<R, W>(
    pool: &FitterPool,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
) -> anyhow::Result<ServeSummary>
where
    R: Read + Send + 'static,
    W: Write,
{
    let (tx, rx) = mpsc::sync_channel::<String>(1024);
    std::thread::spawn(move || {
        let reader = BufReader::new(input);
        for line in reader.lines() {
            let ok = match line {
                Ok(l) => tx.send(l).is_ok(),
                Err(_) => false,
            };
            if !ok {
                break;
            }
        }
    });

    let batch_max = opts.batch_max.max(1);
    let mut summary = ServeSummary::default();
    loop {
        // Block for the first line; drain the rest of the burst.
        let first = match rx.recv() {
            Ok(l) => l,
            Err(_) => break, // EOF: reader hung up
        };
        let mut lines = vec![first];
        while lines.len() < batch_max {
            match rx.try_recv() {
                Ok(l) => lines.push(l),
                Err(_) => break,
            }
        }
        summary.batches += 1;

        // Parse; parse failures answer in place without reaching the pool.
        let mut parsed: Vec<Result<Request, String>> = Vec::new();
        for l in &lines {
            if l.trim().is_empty() {
                continue;
            }
            summary.requests += 1;
            parsed.push(Request::parse(l).map_err(|e| e.to_string()));
        }
        let mut replies: Vec<Option<Reply>> = parsed.iter().map(|_| None).collect();
        let mut good = Vec::new();
        let mut slots = Vec::new();
        for (i, p) in parsed.into_iter().enumerate() {
            match p {
                Ok(r) => {
                    slots.push(i);
                    good.push(r);
                }
                Err(e) => replies[i] = Some(Reply::err(None, "parse", None, e)),
            }
        }
        let shutdown = good.iter().any(|r| matches!(r, Request::Shutdown { .. }));
        for (slot, reply) in slots.into_iter().zip(pool.submit_batch(good)) {
            replies[slot] = Some(reply);
        }
        for reply in replies.into_iter().flatten() {
            writeln!(out, "{}", reply.render())?;
        }
        out.flush()?;
        if shutdown {
            summary.shutdown = true;
            break;
        }
    }
    Ok(summary)
}
