//! Multi-tenant serving subsystem: a [`FitterPool`] service layer over
//! the single-owner [`crate::model_api::SglFitter`] pieces, plus the
//! long-lived `dfr serve` NDJSON loop.
//!
//! Three layers:
//!
//! * [`protocol`] — the NDJSON wire format: one JSON request per line
//!   (verbs `fit`, `predict`, `cv`, `stats`, `evict`, `shutdown`), one
//!   JSON reply per line, parsed/rendered with the crate's own
//!   [`crate::report::Json`] (no `serde` offline).
//! * [`pool`] — [`FitterPool`]: content-hash-keyed, LRU-bounded caches of
//!   prepared datasets, pathwise fits, and CV cells **shared across
//!   tenants** (two tenants posting byte-identical data hit the same
//!   entry), per-tenant fitted models behind a read-mostly `RwLock`,
//!   round-robin fair admission for fit/CV requests contending on the
//!   shared workspace pool, and coalescing of concurrent predict calls
//!   against the same model into one stacked matvec. Live statistics —
//!   per-verb latency histograms, per-tenant hit/miss/eviction counters —
//!   are lock-free atomics, dumped by the `stats` verb.
//! * [`server`] — the blocking read → batch → dispatch → reply loop,
//!   generic over `Read` so tests drive it with an in-memory script.
//!
//! Equivalence guarantee: the pool's fit pipeline is built from the exact
//! same crate-internal pieces as `SglFitter` (`design_key` →
//! `prepare_data` → `PathRunner` → `finalize`), so a fit served through
//! the pool is bit-identical to one from a dedicated per-tenant fitter —
//! pinned by `rust/tests/serve_pool.rs`.

pub mod pool;
pub mod protocol;
pub mod server;

pub use pool::{CvOutcome, FitOutcome, FitterPool, PoolConfig, TenantStats};
pub use protocol::{CvRequest, FitRequest, PredictRequest, Reply, Request};
pub use server::{serve, ServeOptions, ServeSummary};
