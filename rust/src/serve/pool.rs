//! [`FitterPool`] — the multi-tenant service layer.
//!
//! Shared state, by access pattern:
//!
//! * **Content caches** (prepared datasets, pathwise fits, CV cells):
//!   `Mutex<KeyedLru<..>>` with *short* critical sections — locks are
//!   held to probe or insert, never across a solve. Two tenants racing a
//!   cold key may both compute it (the second insert replaces the
//!   first); that duplicate work is accepted in exchange for never
//!   serializing solves behind a lock. Values ride in `Arc`s tagged with
//!   the inserting tenant, so LRU evictions are attributed to owners.
//! * **Model map** (tenant → fitted model): `RwLock<BTreeMap>` —
//!   read-mostly; `predict` takes the read lock only long enough to
//!   clone an `Arc`.
//! * **Statistics** (per-verb latency histograms, per-tenant counters,
//!   coalescing counters): lock-free atomics, readable while fits are in
//!   flight.
//!
//! Fairness: heavy requests (`fit`, `cv`) within a batch are admitted
//! round-robin across tenants — starting from a rotating offset — before
//! being fanned out over the worker pool, so one tenant posting many
//! fits cannot starve the rest. The same rotor idea lives one level
//! down in [`WorkspacePool::checkout`].
//!
//! Coalescing: predict requests against the same tenant's model are
//! stacked into a single design and served by **one**
//! [`FittedSgl::predict_into`] matvec, then split back per request.

use crate::cv::{CvCell, CvConfig, CvEngine};
use crate::data::Response;
use crate::lru::KeyedLru;
use crate::metrics::LatencyHistogram;
use crate::model_api::{
    design_key, finalize, prepare_data, prepared_bytes, Design, DesignKey, FittedSgl,
    PreparedData, SglModel,
};
use crate::parallel::{par_map, WorkspacePool};
use crate::path::{PathConfig, PathFit, PathRunner, PathWorkspace};
use crate::report::Json;
use crate::screen::RuleKind;
use crate::serve::protocol::{CvRequest, FitRequest, Reply, Request};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::Instant;

/// Pool configuration: the shared model defaults plus resource bounds.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Default model settings (rule, α, path, folds, seed, sparse mode);
    /// requests may override rule/α/path-length per call.
    pub model: SglModel,
    /// Worker threads for batch fan-out and CV fold fits.
    pub threads: usize,
    /// Entry bound of each content cache (prepared / paths / CV).
    pub max_entries: usize,
    /// Byte bound of each content cache.
    pub max_bytes: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            model: SglModel::default(),
            threads: crate::parallel::default_threads(),
            max_entries: 8,
            max_bytes: 512 << 20,
        }
    }
}

/// Per-tenant counters (relaxed atomics — telemetry, not sync).
#[derive(Debug, Default)]
pub struct TenantStats {
    fits: AtomicU64,
    predicts: AtomicU64,
    cvs: AtomicU64,
    prepared_hits: AtomicU64,
    prepared_misses: AtomicU64,
    path_hits: AtomicU64,
    cv_hits: AtomicU64,
    /// Cache entries this tenant inserted that were later LRU-evicted.
    evictions: AtomicU64,
}

macro_rules! tenant_counters {
    ($($field:ident),+) => {$(
        pub fn $field(&self) -> u64 {
            self.$field.load(Ordering::Relaxed)
        }
    )+};
}

impl TenantStats {
    tenant_counters!(
        fits, predicts, cvs, prepared_hits, prepared_misses, path_hits, cv_hits, evictions
    );

    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("fits", Json::Num(self.fits() as f64)),
            ("predicts", Json::Num(self.predicts() as f64)),
            ("cvs", Json::Num(self.cvs() as f64)),
            ("prepared_hits", Json::Num(self.prepared_hits() as f64)),
            ("prepared_misses", Json::Num(self.prepared_misses() as f64)),
            ("path_hits", Json::Num(self.path_hits() as f64)),
            ("cv_hits", Json::Num(self.cv_hits() as f64)),
            ("evictions", Json::Num(self.evictions() as f64)),
        ])
    }
}

/// Key of a cached pathwise fit: the dataset key plus every setting that
/// shapes the path.
#[derive(Clone, PartialEq)]
struct PathKey {
    design: DesignKey,
    rule: RuleKind,
    cfg: PathConfig,
    fixed: Option<Vec<f64>>,
}

/// Cached values carry the inserting tenant for eviction attribution.
type Owned<T> = (String, Arc<T>);

/// Outcome of a pool `fit` (the payload of the wire reply).
#[derive(Clone, Debug)]
pub struct FitOutcome {
    pub lambda: f64,
    pub lambda_idx: usize,
    /// Nonzero coefficients at the selected λ.
    pub active: usize,
    /// Safe rule silently degraded to full candidates on logistic loss.
    pub screening_fallback: bool,
    pub prepared_cached: bool,
    pub path_cached: bool,
}

/// Outcome of a pool `cv`.
#[derive(Clone, Debug)]
pub struct CvOutcome {
    pub best_idx: usize,
    pub best_1se_idx: usize,
    /// Index actually selected (respects `one_se`).
    pub chosen_idx: usize,
    pub lambda: f64,
    pub active: usize,
    pub cv_cached: bool,
    pub prepared_cached: bool,
}

/// Multi-tenant serving pool. All methods take `&self`; the pool is
/// `Sync` and meant to be shared (or driven by [`crate::serve::serve`]).
pub struct FitterPool {
    cfg: PoolConfig,
    prepared: Mutex<KeyedLru<DesignKey, Owned<PreparedData>>>,
    paths: Mutex<KeyedLru<PathKey, Owned<PathFit>>>,
    cv_cells: Mutex<KeyedLru<(DesignKey, CvConfig), Owned<CvCell>>>,
    models: RwLock<BTreeMap<String, Arc<FittedSgl>>>,
    tenants: RwLock<BTreeMap<String, Arc<TenantStats>>>,
    workspaces: WorkspacePool<PathWorkspace>,
    cv_engine: CvEngine,
    /// Round-robin offset for heavy-request admission.
    rr: AtomicUsize,
    lat_fit: LatencyHistogram,
    lat_predict: LatencyHistogram,
    lat_cv: LatencyHistogram,
    coalesced_batches: AtomicU64,
    coalesced_predicts: AtomicU64,
    started: Instant,
}

/// Mutex lock that shrugs off poisoning: cached values are plain data
/// (a panicked inserter leaves the map structurally sound), and the
/// no-unwrap discipline forbids propagating the poison as a panic.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl FitterPool {
    pub fn new(cfg: PoolConfig) -> Self {
        let threads = cfg.threads.max(1);
        let (me, mb) = (cfg.max_entries, cfg.max_bytes);
        FitterPool {
            cfg: PoolConfig { threads, ..cfg },
            prepared: Mutex::new(KeyedLru::new(me, mb)),
            paths: Mutex::new(KeyedLru::new(me, mb)),
            cv_cells: Mutex::new(KeyedLru::new(me, mb)),
            models: RwLock::new(BTreeMap::new()),
            tenants: RwLock::new(BTreeMap::new()),
            workspaces: WorkspacePool::new(threads),
            cv_engine: CvEngine::new(threads),
            rr: AtomicUsize::new(0),
            lat_fit: LatencyHistogram::new(),
            lat_predict: LatencyHistogram::new(),
            lat_cv: LatencyHistogram::new(),
            coalesced_batches: AtomicU64::new(0),
            coalesced_predicts: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// The pool's configuration.
    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Per-tenant counters handle (created on first touch).
    pub fn tenant_stats(&self, name: &str) -> Arc<TenantStats> {
        if let Some(t) = read(&self.tenants).get(name) {
            return Arc::clone(t);
        }
        let mut map = write(&self.tenants);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// The tenant's current fitted model, if any.
    pub fn model_of(&self, tenant: &str) -> Option<Arc<FittedSgl>> {
        read(&self.models).get(tenant).cloned()
    }

    /// (entries, bytes, evictions) of the prepared-dataset cache.
    pub fn prepared_cache_stats(&self) -> (usize, usize, u64) {
        let c = lock(&self.prepared);
        (c.len(), c.bytes(), c.evictions())
    }

    /// (entries, bytes, evictions) of the path cache.
    pub fn path_cache_stats(&self) -> (usize, usize, u64) {
        let c = lock(&self.paths);
        (c.len(), c.bytes(), c.evictions())
    }

    // ---- fit / cv pipeline (shared pieces) -----------------------------

    fn prepared_for(
        &self,
        tenant: &str,
        ts: &TenantStats,
        design: &Design,
        y: &[f64],
        groups: &[usize],
        response: Response,
    ) -> anyhow::Result<(Arc<PreparedData>, bool)> {
        let key = design_key(design, y, groups, response, self.cfg.model.sparse)?;
        if let Some((_, p)) = lock(&self.prepared).get(&key) {
            TenantStats::bump(&ts.prepared_hits);
            return Ok((Arc::clone(p), true));
        }
        TenantStats::bump(&ts.prepared_misses);
        // Ingest OUTSIDE the lock: a large standardization must not
        // serialize every other tenant's cache probe behind it.
        let data = Arc::new(prepare_data(
            design,
            y,
            groups,
            response,
            self.cfg.model.sparse,
            key.clone(),
        )?);
        let bytes = prepared_bytes(&data);
        let evicted =
            lock(&self.prepared).insert(key, (tenant.to_string(), Arc::clone(&data)), bytes);
        self.attribute_evictions(evicted.into_iter().map(|(_, (owner, _))| owner));
        Ok((data, false))
    }

    fn path_for(
        &self,
        tenant: &str,
        ts: &TenantStats,
        prep: &PreparedData,
        cfg: PathConfig,
        rule: RuleKind,
        fixed: Option<Vec<f64>>,
    ) -> anyhow::Result<(Arc<PathFit>, bool)> {
        let key =
            PathKey { design: prep.key.clone(), rule, cfg: cfg.clone(), fixed: fixed.clone() };
        if let Some((_, f)) = lock(&self.paths).get(&key) {
            TenantStats::bump(&ts.path_hits);
            return Ok((Arc::clone(f), true));
        }
        let mut runner = PathRunner::new(&prep.ds, cfg).rule(rule);
        if let Some(lambdas) = fixed {
            runner = runner.fixed_path(lambdas);
        }
        // Solve outside the cache lock, on a checked-out pooled workspace.
        let mut ws = self.workspaces.checkout();
        let fit = Arc::new(runner.run_with_workspace(&mut ws)?);
        drop(ws);
        let bytes = path_bytes(&fit);
        let evicted = lock(&self.paths).insert(key, (tenant.to_string(), Arc::clone(&fit)), bytes);
        self.attribute_evictions(evicted.into_iter().map(|(_, (owner, _))| owner));
        Ok((fit, false))
    }

    fn attribute_evictions(&self, owners: impl Iterator<Item = String>) {
        for owner in owners {
            TenantStats::bump(&self.tenant_stats(&owner).evictions);
        }
    }

    /// Serve one fit request: prepared-cache → path-cache → finalize,
    /// storing the raw-scale model under the tenant's name.
    pub fn fit(&self, req: &FitRequest) -> anyhow::Result<FitOutcome> {
        let ts = self.tenant_stats(&req.tenant);
        TenantStats::bump(&ts.fits);
        let design = Design::rows(&req.x);
        let (prep, prepared_cached) =
            self.prepared_for(&req.tenant, &ts, &design, &req.y, &req.groups, req.response)?;
        let (cfg, rule) = self.path_settings(req.alpha, req.path_len, req.rule)?;
        let idx = req.lambda_idx.unwrap_or(cfg.path_len / 2);
        anyhow::ensure!(
            idx < cfg.path_len,
            "lambda_idx {idx} out of range (path_len {})",
            cfg.path_len
        );
        let (fit, path_cached) = self.path_for(&req.tenant, &ts, &prep, cfg, rule, None)?;
        let fitted =
            Arc::new(finalize(&fit, &prep.centers, prep.y_mean, prep.ds.response, idx)?);
        let out = FitOutcome {
            lambda: fitted.lambda,
            lambda_idx: idx,
            active: fitted.coefficients.iter().filter(|&&c| c != 0.0).count(),
            screening_fallback: fit.metrics.screening_fallback,
            prepared_cached,
            path_cached,
        };
        write(&self.models).insert(req.tenant.clone(), fitted);
        Ok(out)
    }

    /// Serve one CV request: fold fits through the shared [`CvEngine`],
    /// cell cached by (dataset, config), winner refit from the path cache.
    pub fn cv(&self, req: &CvRequest) -> anyhow::Result<CvOutcome> {
        let ts = self.tenant_stats(&req.tenant);
        TenantStats::bump(&ts.cvs);
        let design = Design::rows(&req.x);
        let (prep, prepared_cached) =
            self.prepared_for(&req.tenant, &ts, &design, &req.y, &req.groups, req.response)?;
        let (cfg, rule) = self.path_settings(req.alpha, None, req.rule)?;
        let ccfg = CvConfig {
            folds: req.folds.unwrap_or(self.cfg.model.cv_folds),
            path: cfg.clone(),
            rule,
            seed: self.cfg.model.seed,
            threads: self.cfg.threads,
        };
        let ckey = (prep.key.clone(), ccfg.clone());
        let mut cv_cached = true;
        // Probe in its own statement: a `match` on the locked lookup
        // would hold the guard across the miss arm's re-lock (deadlock).
        let probed = lock(&self.cv_cells).get(&ckey).map(|(_, c)| Arc::clone(c));
        let cell = match probed {
            Some(c) => {
                TenantStats::bump(&ts.cv_hits);
                c
            }
            None => {
                cv_cached = false;
                let fresh = Arc::new(self.cv_engine.cross_validate(&prep.ds, &ccfg)?);
                let bytes = fresh.lambdas.len() * 32 + 256;
                let evicted = lock(&self.cv_cells).insert(
                    ckey,
                    (req.tenant.clone(), Arc::clone(&fresh)),
                    bytes,
                );
                self.attribute_evictions(evicted.into_iter().map(|(_, (owner, _))| owner));
                fresh
            }
        };
        let chosen = if req.one_se { cell.best_1se_idx } else { cell.best_idx };
        let (fit, _) =
            self.path_for(&req.tenant, &ts, &prep, cfg, rule, Some(cell.lambdas.clone()))?;
        let fitted =
            Arc::new(finalize(&fit, &prep.centers, prep.y_mean, prep.ds.response, chosen)?);
        let out = CvOutcome {
            best_idx: cell.best_idx,
            best_1se_idx: cell.best_1se_idx,
            chosen_idx: chosen,
            lambda: fitted.lambda,
            active: fitted.coefficients.iter().filter(|&&c| c != 0.0).count(),
            cv_cached,
            prepared_cached,
        };
        write(&self.models).insert(req.tenant.clone(), fitted);
        Ok(out)
    }

    fn path_settings(
        &self,
        alpha: Option<f64>,
        path_len: Option<usize>,
        rule: Option<RuleKind>,
    ) -> anyhow::Result<(PathConfig, RuleKind)> {
        let mut cfg = self.cfg.model.path.clone();
        if let Some(a) = alpha {
            anyhow::ensure!((0.0..=1.0).contains(&a), "alpha {a} outside [0, 1]");
            cfg.alpha = a;
        }
        if let Some(l) = path_len {
            anyhow::ensure!(l >= 2, "path_len must be at least 2, got {l}");
            cfg.path_len = l;
        }
        Ok((cfg, rule.unwrap_or(self.cfg.model.rule)))
    }

    /// Predict with the tenant's current model. `rows` may stack several
    /// coalesced requests; `requests` is how many it represents (counter
    /// attribution only).
    fn predict_stacked(
        &self,
        tenant: &str,
        rows: &[Vec<f64>],
        requests: u64,
    ) -> anyhow::Result<Vec<f64>> {
        let ts = self.tenant_stats(tenant);
        ts.predicts.fetch_add(requests, Ordering::Relaxed);
        let model = self
            .model_of(tenant)
            .ok_or_else(|| anyhow::anyhow!("no model for tenant `{tenant}` (fit first)"))?;
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let p = model.coefficients.len();
        if let Some(bad) = rows.iter().position(|r| r.len() != p) {
            anyhow::bail!("row {bad} has {} features, model expects {p}", rows[bad].len());
        }
        let mut out = vec![0.0; rows.len()];
        model.predict_into(&Design::rows(rows), &mut out);
        Ok(out)
    }

    /// Predict for one request (the uncoalesced path).
    pub fn predict(&self, tenant: &str, rows: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        self.predict_stacked(tenant, rows, 1)
    }

    /// Drop the tenant's model and every cache entry it inserted.
    /// Returns (had a model, cache entries dropped). Explicit drops are
    /// not counted as LRU evictions.
    pub fn evict(&self, tenant: &str) -> (bool, usize) {
        let had = write(&self.models).remove(tenant).is_some();
        let mut dropped = 0;
        dropped += lock(&self.prepared).retain(|_, v| v.0 != tenant);
        dropped += lock(&self.paths).retain(|_, v| v.0 != tenant);
        dropped += lock(&self.cv_cells).retain(|_, v| v.0 != tenant);
        (had, dropped)
    }

    /// Live statistics dump — the `stats` verb payload.
    pub fn stats_json(&self) -> Json {
        let tenants: Vec<(String, Json)> = read(&self.tenants)
            .iter()
            .map(|(name, ts)| (name.clone(), ts.json()))
            .collect();
        Json::obj(vec![
            ("uptime_seconds", Json::Num(self.started.elapsed().as_secs_f64())),
            ("threads", Json::Num(self.cfg.threads as f64)),
            (
                "verbs",
                Json::obj(vec![
                    ("fit", hist_json(&self.lat_fit)),
                    ("predict", hist_json(&self.lat_predict)),
                    ("cv", hist_json(&self.lat_cv)),
                ]),
            ),
            (
                "caches",
                Json::obj(vec![
                    ("prepared", cache_json(&self.prepared)),
                    ("paths", cache_json(&self.paths)),
                    ("cv", cache_json(&self.cv_cells)),
                ]),
            ),
            (
                "coalescing",
                Json::obj(vec![
                    (
                        "batches",
                        Json::Num(self.coalesced_batches.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "predicts",
                        Json::Num(self.coalesced_predicts.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("models", Json::Num(read(&self.models).len() as f64)),
            ("tenants", Json::Obj(tenants)),
            (
                "workspace_checkouts",
                Json::Num(self.workspaces.checkouts() as f64),
            ),
        ])
    }

    // ---- batch admission ----------------------------------------------

    /// Execute one batch of requests, returning replies in request order.
    ///
    /// Admission: heavy requests (fit/cv) first, round-robin interleaved
    /// across tenants and fanned out over the worker pool; then predicts,
    /// coalesced per tenant into one stacked matvec each; then control
    /// verbs (`stats`, `evict`, `shutdown`) in request order, so a
    /// scripted `fit → predict → stats` pipeline works in a single batch.
    pub fn submit_batch(&self, mut reqs: Vec<Request>) -> Vec<Reply> {
        let mut replies: Vec<Option<Reply>> = reqs.iter().map(|_| None).collect();

        // Heavy verbs: queue per tenant (first-come order within one).
        let mut heavy: Vec<(String, VecDeque<usize>)> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if matches!(r, Request::Fit(_) | Request::Cv(_)) {
                let tenant = r.tenant().unwrap_or_default().to_string();
                match heavy.iter_mut().find(|(t, _)| *t == tenant) {
                    Some((_, q)) => q.push_back(i),
                    None => heavy.push((tenant, VecDeque::from([i]))),
                }
            }
        }
        if !heavy.is_empty() {
            let lanes = heavy.len();
            let start = self.rr.fetch_add(1, Ordering::Relaxed) % lanes;
            let total: usize = heavy.iter().map(|(_, q)| q.len()).sum();
            let mut order = Vec::with_capacity(total);
            while order.len() < total {
                for k in 0..lanes {
                    if let Some(i) = heavy[(start + k) % lanes].1.pop_front() {
                        order.push(i);
                    }
                }
            }
            let reqs_ref: &[Request] = &reqs;
            let done = par_map(order.len(), self.cfg.threads, |j| {
                let i = order[j];
                let t0 = Instant::now();
                let reply = match &reqs_ref[i] {
                    Request::Fit(f) => {
                        let r = self.fit(f).map(fit_fields);
                        self.lat_fit.record(t0.elapsed());
                        to_reply(f.id, "fit", Some(&f.tenant), r)
                    }
                    Request::Cv(c) => {
                        let r = self.cv(c).map(cv_fields);
                        self.lat_cv.record(t0.elapsed());
                        to_reply(c.id, "cv", Some(&c.tenant), r)
                    }
                    other => Reply::err(
                        other.id(),
                        other.verb(),
                        other.tenant(),
                        "internal: non-heavy request in heavy lane",
                    ),
                };
                (i, reply)
            });
            for (i, reply) in done {
                replies[i] = Some(reply);
            }
        }

        // Predicts: coalesce per tenant into one stacked matvec.
        let mut pred: Vec<(String, Vec<usize>)> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            if let Request::Predict(p) = r {
                match pred.iter_mut().find(|(t, _)| *t == p.tenant) {
                    Some((_, idxs)) => idxs.push(i),
                    None => pred.push((p.tenant.clone(), vec![i])),
                }
            }
        }
        for (tenant, idxs) in pred {
            let t0 = Instant::now();
            let mut stacked: Vec<Vec<f64>> = Vec::new();
            let mut spans: Vec<(usize, Option<f64>, usize)> = Vec::new();
            for &i in &idxs {
                if let Request::Predict(p) = &mut reqs[i] {
                    let rows = std::mem::take(&mut p.x);
                    spans.push((i, p.id, rows.len()));
                    stacked.extend(rows);
                }
            }
            let coalesced = idxs.len();
            if coalesced > 1 {
                TenantStats::bump(&self.coalesced_batches);
                self.coalesced_predicts.fetch_add(coalesced as u64, Ordering::Relaxed);
            }
            match self.predict_stacked(&tenant, &stacked, coalesced as u64) {
                Ok(all) => {
                    let mut offset = 0;
                    for (i, id, len) in spans {
                        let preds =
                            all[offset..offset + len].iter().map(|&v| Json::Num(v)).collect();
                        offset += len;
                        replies[i] = Some(Reply::ok(
                            id,
                            "predict",
                            Some(&tenant),
                            vec![
                                ("predictions", Json::Arr(preds)),
                                ("coalesced", Json::Num(coalesced as f64)),
                            ],
                        ));
                    }
                }
                Err(e) => {
                    for (i, id, _) in spans {
                        replies[i] =
                            Some(Reply::err(id, "predict", Some(&tenant), e.to_string()));
                    }
                }
            }
            self.lat_predict.record(t0.elapsed());
        }

        // Control verbs, in request order.
        for (i, r) in reqs.iter().enumerate() {
            match r {
                Request::Stats { id } => {
                    replies[i] =
                        Some(Reply::ok(*id, "stats", None, vec![("stats", self.stats_json())]));
                }
                Request::Evict { id, tenant } => {
                    let (had_model, dropped) = self.evict(tenant);
                    replies[i] = Some(Reply::ok(
                        *id,
                        "evict",
                        Some(tenant),
                        vec![
                            ("had_model", Json::Bool(had_model)),
                            ("dropped_entries", Json::Num(dropped as f64)),
                        ],
                    ));
                }
                Request::Shutdown { id } => {
                    replies[i] = Some(Reply::ok(*id, "shutdown", None, vec![]));
                }
                _ => {}
            }
        }

        replies
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                r.unwrap_or_else(|| {
                    Reply::err(None, "internal", None, format!("request {i} was not scheduled"))
                })
            })
            .collect()
    }
}

fn read<'a, K, V>(l: &'a RwLock<BTreeMap<K, V>>) -> std::sync::RwLockReadGuard<'a, BTreeMap<K, V>> {
    l.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn write<'a, K, V>(
    l: &'a RwLock<BTreeMap<K, V>>,
) -> std::sync::RwLockWriteGuard<'a, BTreeMap<K, V>> {
    l.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn cache_json<K: PartialEq, V>(c: &Mutex<KeyedLru<K, V>>) -> Json {
    let c = lock(c);
    Json::obj(vec![
        ("entries", Json::Num(c.len() as f64)),
        ("bytes", Json::Num(c.bytes() as f64)),
        ("max_entries", Json::Num(c.max_entries() as f64)),
        ("max_bytes", Json::Num(c.max_bytes() as f64)),
        ("evictions", Json::Num(c.evictions() as f64)),
    ])
}

fn path_bytes(fit: &PathFit) -> usize {
    fit.betas.iter().map(|b| b.len() * 8).sum::<usize>() + fit.lambdas.len() * 8 + 256
}

fn hist_json(h: &LatencyHistogram) -> Json {
    Json::obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("mean_seconds", Json::Num(h.mean_seconds())),
        ("p50_seconds", Json::Num(h.p50())),
        ("p95_seconds", Json::Num(h.p95())),
        ("p99_seconds", Json::Num(h.p99())),
    ])
}

fn fit_fields(o: FitOutcome) -> Vec<(&'static str, Json)> {
    vec![
        ("lambda", Json::Num(o.lambda)),
        ("lambda_idx", Json::Num(o.lambda_idx as f64)),
        ("active", Json::Num(o.active as f64)),
        ("screening_fallback", Json::Bool(o.screening_fallback)),
        ("prepared_cached", Json::Bool(o.prepared_cached)),
        ("path_cached", Json::Bool(o.path_cached)),
    ]
}

fn cv_fields(o: CvOutcome) -> Vec<(&'static str, Json)> {
    vec![
        ("best_idx", Json::Num(o.best_idx as f64)),
        ("best_1se_idx", Json::Num(o.best_1se_idx as f64)),
        ("chosen_idx", Json::Num(o.chosen_idx as f64)),
        ("lambda", Json::Num(o.lambda)),
        ("active", Json::Num(o.active as f64)),
        ("cv_cached", Json::Bool(o.cv_cached)),
        ("prepared_cached", Json::Bool(o.prepared_cached)),
    ]
}

fn to_reply(
    id: Option<f64>,
    verb: &'static str,
    tenant: Option<&str>,
    result: anyhow::Result<Vec<(&'static str, Json)>>,
) -> Reply {
    match result {
        Ok(fields) => Reply::ok(id, verb, tenant, fields),
        Err(e) => Reply::err(id, verb, tenant, e.to_string()),
    }
}
