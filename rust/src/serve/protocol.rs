//! NDJSON wire format of `dfr serve`: request parsing and reply
//! rendering over [`crate::report::Json`].
//!
//! One request per line. Every request carries a `verb` and optionally a
//! numeric `id` echoed back in the reply, so a pipelining client can
//! match responses to requests even though batching may reorder
//! execution (replies always come back in request order within a batch).
//!
//! ```text
//! {"verb":"fit","tenant":"a","x":[[..],..],"y":[..],"groups":[2,2]}
//! {"verb":"predict","tenant":"a","x":[[..]]}
//! {"verb":"stats"}
//! {"verb":"shutdown"}
//! ```

use crate::cli::parse_rule;
use crate::data::Response;
use crate::report::Json;
use crate::screen::RuleKind;

/// A `fit` request: pathwise fit on inline row-major data, model stored
/// under the tenant's name for follow-up `predict` calls.
#[derive(Debug)]
pub struct FitRequest {
    pub id: Option<f64>,
    pub tenant: String,
    /// Row-major design (one inner array per observation).
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    /// Group sizes (must sum to the row width).
    pub groups: Vec<usize>,
    pub response: Response,
    /// Screening rule override (pool default when absent).
    pub rule: Option<RuleKind>,
    /// α override (pool default when absent).
    pub alpha: Option<f64>,
    /// Path-length override (pool default when absent).
    pub path_len: Option<usize>,
    /// λ index to select; defaults to the middle of the path.
    pub lambda_idx: Option<usize>,
}

/// A `predict` request against the tenant's current model.
#[derive(Debug)]
pub struct PredictRequest {
    pub id: Option<f64>,
    pub tenant: String,
    pub x: Vec<Vec<f64>>,
}

/// A `cv` request: k-fold CV λ selection, winning model stored under the
/// tenant's name.
#[derive(Debug)]
pub struct CvRequest {
    pub id: Option<f64>,
    pub tenant: String,
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    pub groups: Vec<usize>,
    pub response: Response,
    pub rule: Option<RuleKind>,
    pub alpha: Option<f64>,
    /// Fold count override (pool default when absent).
    pub folds: Option<usize>,
    /// Select by the one-standard-error rule instead of the CV optimum.
    pub one_se: bool,
}

/// One parsed request line.
#[derive(Debug)]
pub enum Request {
    Fit(FitRequest),
    Predict(PredictRequest),
    Cv(CvRequest),
    Stats { id: Option<f64> },
    Evict { id: Option<f64>, tenant: String },
    Shutdown { id: Option<f64> },
}

impl Request {
    /// Parse one NDJSON line.
    pub fn parse(line: &str) -> anyhow::Result<Request> {
        let j = Json::parse(line)?;
        let verb = j
            .get("verb")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field `verb`"))?;
        let id = j.get("id").and_then(Json::as_f64);
        match verb {
            "fit" => Ok(Request::Fit(FitRequest {
                id,
                tenant: tenant_field(&j)?,
                x: rows_field(&j)?,
                y: f64s_field(&j, "y")?,
                groups: usizes_field(&j, "groups")?,
                response: response_field(&j)?,
                rule: rule_field(&j)?,
                alpha: j.get("alpha").and_then(Json::as_f64),
                path_len: opt_usize_field(&j, "path_len")?,
                lambda_idx: opt_usize_field(&j, "lambda_idx")?,
            })),
            "predict" => Ok(Request::Predict(PredictRequest {
                id,
                tenant: tenant_field(&j)?,
                x: rows_field(&j)?,
            })),
            "cv" => Ok(Request::Cv(CvRequest {
                id,
                tenant: tenant_field(&j)?,
                x: rows_field(&j)?,
                y: f64s_field(&j, "y")?,
                groups: usizes_field(&j, "groups")?,
                response: response_field(&j)?,
                rule: rule_field(&j)?,
                alpha: j.get("alpha").and_then(Json::as_f64),
                folds: opt_usize_field(&j, "folds")?,
                one_se: j.get("one_se").and_then(Json::as_bool).unwrap_or(false),
            })),
            "stats" => Ok(Request::Stats { id }),
            "evict" => Ok(Request::Evict { id, tenant: tenant_field(&j)? }),
            "shutdown" => Ok(Request::Shutdown { id }),
            other => anyhow::bail!(
                "unknown verb `{other}` (fit|predict|cv|stats|evict|shutdown)"
            ),
        }
    }

    /// The request's echo id, if any.
    pub fn id(&self) -> Option<f64> {
        match self {
            Request::Fit(r) => r.id,
            Request::Predict(r) => r.id,
            Request::Cv(r) => r.id,
            Request::Stats { id } | Request::Evict { id, .. } | Request::Shutdown { id } => *id,
        }
    }

    /// Wire name of the verb.
    pub fn verb(&self) -> &'static str {
        match self {
            Request::Fit(_) => "fit",
            Request::Predict(_) => "predict",
            Request::Cv(_) => "cv",
            Request::Stats { .. } => "stats",
            Request::Evict { .. } => "evict",
            Request::Shutdown { .. } => "shutdown",
        }
    }

    /// Tenant the request addresses (`None` for pool-wide verbs).
    pub fn tenant(&self) -> Option<&str> {
        match self {
            Request::Fit(r) => Some(&r.tenant),
            Request::Predict(r) => Some(&r.tenant),
            Request::Cv(r) => Some(&r.tenant),
            Request::Evict { tenant, .. } => Some(tenant),
            Request::Stats { .. } | Request::Shutdown { .. } => None,
        }
    }
}

fn tenant_field(j: &Json) -> anyhow::Result<String> {
    let t = j
        .get("tenant")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing string field `tenant`"))?;
    anyhow::ensure!(!t.is_empty(), "`tenant` must be non-empty");
    Ok(t.to_string())
}

fn rows_field(j: &Json) -> anyhow::Result<Vec<Vec<f64>>> {
    let arr = j
        .get("x")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing array field `x`"))?;
    arr.iter()
        .enumerate()
        .map(|(i, row)| {
            row.as_arr()
                .ok_or_else(|| anyhow::anyhow!("`x[{i}]` is not an array"))?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("`x[{i}]` holds a non-number")))
                .collect()
        })
        .collect()
}

fn f64s_field(j: &Json, key: &str) -> anyhow::Result<Vec<f64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing array field `{key}`"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("`{key}` holds a non-number")))
        .collect()
}

fn usizes_field(j: &Json, key: &str) -> anyhow::Result<Vec<usize>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing array field `{key}`"))?
        .iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| anyhow::anyhow!("`{key}` holds a non-integer"))
        })
        .collect()
}

fn opt_usize_field(j: &Json, key: &str) -> anyhow::Result<Option<usize>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("`{key}` must be a non-negative integer")),
    }
}

fn response_field(j: &Json) -> anyhow::Result<Response> {
    match j.get("response").and_then(Json::as_str) {
        None => Ok(Response::Linear),
        Some("linear") => Ok(Response::Linear),
        Some("logistic") => Ok(Response::Logistic),
        Some(other) => anyhow::bail!("unknown response `{other}` (linear|logistic)"),
    }
}

fn rule_field(j: &Json) -> anyhow::Result<Option<RuleKind>> {
    match j.get("rule").and_then(Json::as_str) {
        None => Ok(None),
        Some(name) => parse_rule(name).map(Some).map_err(anyhow::Error::msg),
    }
}

/// One reply line: verb + ok flag + echoed id/tenant + either payload
/// fields or an error message.
#[derive(Debug)]
pub struct Reply {
    pub id: Option<f64>,
    pub verb: &'static str,
    pub tenant: Option<String>,
    pub result: Result<Vec<(String, Json)>, String>,
}

impl Reply {
    /// Successful reply with payload fields.
    pub fn ok(
        id: Option<f64>,
        verb: &'static str,
        tenant: Option<&str>,
        fields: Vec<(&str, Json)>,
    ) -> Reply {
        Reply {
            id,
            verb,
            tenant: tenant.map(str::to_string),
            result: Ok(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect()),
        }
    }

    /// Error reply.
    pub fn err(
        id: Option<f64>,
        verb: &'static str,
        tenant: Option<&str>,
        msg: impl Into<String>,
    ) -> Reply {
        Reply { id, verb, tenant: tenant.map(str::to_string), result: Err(msg.into()) }
    }

    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// Reply as a [`Json`] object.
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(String, Json)> = vec![
            ("verb".into(), Json::Str(self.verb.into())),
            ("ok".into(), Json::Bool(self.result.is_ok())),
        ];
        if let Some(id) = self.id {
            kv.push(("id".into(), Json::Num(id)));
        }
        if let Some(t) = &self.tenant {
            kv.push(("tenant".into(), Json::Str(t.clone())));
        }
        match &self.result {
            Ok(fields) => kv.extend(fields.iter().cloned()),
            Err(e) => kv.push(("error".into(), Json::Str(e.clone()))),
        }
        Json::Obj(kv)
    }

    /// Render as one NDJSON line (no trailing newline).
    pub fn render(&self) -> String {
        self.to_json().render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_request_parses_with_defaults_and_overrides() {
        let line = r#"{"verb":"fit","id":3,"tenant":"a","x":[[1,2],[3,4]],"y":[0.5,1.5],
                      "groups":[1,1],"rule":"tlfre","alpha":0.5,"lambda_idx":7}"#
            .replace('\n', " ");
        let r = Request::parse(&line).unwrap();
        assert_eq!(r.verb(), "fit");
        assert_eq!(r.id(), Some(3.0));
        assert_eq!(r.tenant(), Some("a"));
        match r {
            Request::Fit(f) => {
                assert_eq!(f.x, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
                assert_eq!(f.groups, vec![1, 1]);
                assert_eq!(f.response, Response::Linear);
                assert_eq!(f.rule, Some(RuleKind::Tlfre));
                assert_eq!(f.alpha, Some(0.5));
                assert_eq!(f.path_len, None);
                assert_eq!(f.lambda_idx, Some(7));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn control_verbs_parse() {
        assert!(matches!(Request::parse(r#"{"verb":"stats"}"#).unwrap(), Request::Stats { .. }));
        assert!(matches!(
            Request::parse(r#"{"verb":"shutdown","id":9}"#).unwrap(),
            Request::Shutdown { id: Some(x) } if x == 9.0
        ));
        match Request::parse(r#"{"verb":"evict","tenant":"b"}"#).unwrap() {
            Request::Evict { tenant, .. } => assert_eq!(tenant, "b"),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_are_rejected() {
        for bad in [
            "not json",
            r#"{"no_verb":1}"#,
            r#"{"verb":"dance"}"#,
            r#"{"verb":"fit","tenant":"a","x":[[1]],"y":[1]}"#, // missing groups
            r#"{"verb":"fit","tenant":"","x":[[1]],"y":[1],"groups":[1]}"#,
            r#"{"verb":"predict","tenant":"a","x":[1]}"#, // rows not arrays
            r#"{"verb":"fit","tenant":"a","x":[[1]],"y":[1],"groups":[1.5]}"#,
            r#"{"verb":"fit","tenant":"a","x":[[1]],"y":[1],"groups":[1],"response":"poisson"}"#,
            r#"{"verb":"evict"}"#,
        ] {
            assert!(Request::parse(bad).is_err(), "should reject `{bad}`");
        }
    }

    #[test]
    fn reply_renders_ok_and_error() {
        let ok = Reply::ok(Some(1.0), "fit", Some("a"), vec![("lambda", Json::Num(0.25))]);
        let parsed = Json::parse(&ok.render()).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("id").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("lambda").and_then(Json::as_f64), Some(0.25));

        let err = Reply::err(None, "predict", Some("a"), "no model");
        let parsed = Json::parse(&err.render()).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(parsed.get("error").and_then(Json::as_str), Some("no model"));
        assert!(parsed.get("id").is_none());
    }
}
