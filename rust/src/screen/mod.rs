//! Feature-reduction (screening) rules for the pathwise SGL / aSGL fit.
//!
//! The paper's contribution — **DFR**, the bi-level strong rule — plus the
//! two competitors it is evaluated against and a no-screen baseline:
//!
//! | Rule | Kind | Layers | Reference |
//! |---|---|---|---|
//! | [`dfr`] | strong (heuristic) | group + variable | Eqs. 5–8 |
//! | [`sparsegl`] | strong (heuristic) | group only | Liang et al. '22, Eq. 29 |
//! | [`gap_safe`] | exact (safe) | group + variable | Ndiaye et al. '16, Eqs. 30–33 |
//! | [`tlfre`] | exact (safe) | group + variable | Wang & Ye '14 (TLFre) |
//! | `NoScreen` | — | none | baseline |
//!
//! Strong rules may err, so every strong rule is paired with its KKT check
//! ([`kkt`]); the pathwise coordinator re-solves with violating variables
//! added back until no violation remains (Algorithm 1). Safe rules
//! (`needs_kkt() == false`) certify their exclusions, so the coordinator
//! skips the violation→re-entry loop entirely for them.

pub mod dfr;
pub mod gap_safe;
pub mod kkt;
pub mod sparsegl;
pub mod tlfre;

use crate::data::Response;
use crate::linalg::DesignRef;
use crate::penalty::Penalty;

/// Which screening rule to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// No screening: the solver always sees the full design.
    NoScreen,
    /// DFR for (plain) SGL — the paper's Eqs. 5–6.
    DfrSgl,
    /// DFR for adaptive SGL — the paper's Eqs. 7–8 (requires an adaptive
    /// penalty; with unit weights it coincides with `DfrSgl`).
    DfrAsgl,
    /// Group-level strong rule of the `sparsegl` R package.
    Sparsegl,
    /// GAP safe sphere rule, sequential variant (screen once per λ).
    GapSafeSeq,
    /// GAP safe sphere rule, dynamic variant (re-screen during solving).
    GapSafeDyn,
    /// TLFre — the two-layer *safe* rule (Wang & Ye '14): sequential
    /// (E)DPP balls on the decomposed SGL dual feasible set, group then
    /// variable elimination, adaptive weights included.
    Tlfre,
}

impl RuleKind {
    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::NoScreen => "no-screen",
            RuleKind::DfrSgl => "DFR-SGL",
            RuleKind::DfrAsgl => "DFR-aSGL",
            RuleKind::Sparsegl => "sparsegl",
            RuleKind::GapSafeSeq => "GAP-safe-seq",
            RuleKind::GapSafeDyn => "GAP-safe-dyn",
            RuleKind::Tlfre => "TLFre",
        }
    }

    /// Does the rule need KKT verification (strong rules only)?
    pub fn needs_kkt(&self) -> bool {
        matches!(self, RuleKind::DfrSgl | RuleKind::DfrAsgl | RuleKind::Sparsegl)
    }

    /// Does the rule silently degrade to no screening on a logistic
    /// response? The safe rules' exclusion certificates (TLFre's (E)DPP
    /// balls, the GAP-safe spheres as implemented here) are squared-loss
    /// constructions; on logistic loss they return full candidate sets
    /// rather than risk an unsafe exclusion. Fits where this happens set
    /// [`crate::metrics::PathMetrics::screening_fallback`] so the
    /// degradation is observable instead of silent.
    pub fn logistic_fallback(&self) -> bool {
        matches!(self, RuleKind::GapSafeSeq | RuleKind::GapSafeDyn | RuleKind::Tlfre)
    }

    /// All rules compared in the paper's figures.
    pub const ALL: [RuleKind; 7] = [
        RuleKind::NoScreen,
        RuleKind::DfrSgl,
        RuleKind::DfrAsgl,
        RuleKind::Sparsegl,
        RuleKind::GapSafeSeq,
        RuleKind::GapSafeDyn,
        RuleKind::Tlfre,
    ];
}

/// Everything a sequential screening rule may look at when predicting the
/// candidate sets for `λ_{k+1}` from the solution at `λ_k`.
pub struct ScreenContext<'a> {
    pub penalty: &'a Penalty,
    /// `∇f(β̂(λ_k))` over the full design.
    pub grad_prev: &'a [f64],
    /// `β̂(λ_k)` (full length).
    pub beta_prev: &'a [f64],
    pub lambda_prev: f64,
    pub lambda_next: f64,
    /// Design/response — needed by the exact (GAP safe) rules. A kernel
    /// view, so safe screening runs sparse on centered-implicit designs.
    pub x: DesignRef<'a>,
    pub y: &'a [f64],
    pub response: Response,
}

/// Output of a screening pass: sorted candidate group ids and sorted
/// candidate variable ids (before unioning with the previously-active set).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Candidates {
    pub groups: Vec<usize>,
    pub vars: Vec<usize>,
}

impl Candidates {
    /// Everything is a candidate (the no-screen limit).
    pub fn full(penalty: &Penalty) -> Candidates {
        Candidates {
            groups: (0..penalty.groups.m()).collect(),
            vars: (0..penalty.groups.p()).collect(),
        }
    }
}

/// Dispatch a screening rule.
pub fn screen(kind: RuleKind, ctx: &ScreenContext) -> Candidates {
    match kind {
        RuleKind::NoScreen => Candidates::full(ctx.penalty),
        RuleKind::DfrSgl | RuleKind::DfrAsgl => dfr::screen(ctx),
        RuleKind::Sparsegl => sparsegl::screen(ctx),
        RuleKind::GapSafeSeq | RuleKind::GapSafeDyn => gap_safe::screen(ctx),
        RuleKind::Tlfre => tlfre::screen(ctx),
    }
}

/// Union of sorted index lists (used for `O_v = C_v ∪ A_v(λ_k)` and the
/// KKT re-entry loop).
pub fn union_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    union_sorted_into(a, b, &mut out);
    out
}

/// Union of sorted index lists into a caller-provided buffer (cleared
/// first) — the allocation-free form the pathwise coordinator rotates
/// through its workspace.
pub fn union_sorted_into(a: &[usize], b: &[usize], out: &mut Vec<usize>) {
    out.clear();
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let pick_a = match (a.get(i), b.get(j)) {
            (Some(&x), Some(&y)) => {
                if x == y {
                    j += 1;
                    true
                } else {
                    x < y
                }
            }
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if pick_a {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
}

/// Active variables of a coefficient vector.
pub fn active_vars(beta: &[f64]) -> Vec<usize> {
    let mut out = Vec::new();
    active_vars_into(beta, &mut out);
    out
}

/// Active variables written into a caller-provided buffer (cleared first)
/// — the allocation-free form for workspace-carried hot loops.
pub fn active_vars_into(beta: &[f64], out: &mut Vec<usize>) {
    out.clear();
    out.extend(beta.iter().enumerate().filter(|(_, &b)| b != 0.0).map(|(i, _)| i));
}

/// Active groups of a coefficient vector.
pub fn active_groups(beta: &[f64], groups: &crate::groups::Groups) -> Vec<usize> {
    groups
        .iter()
        .filter(|(_, r)| beta[r.clone()].iter().any(|&b| b != 0.0))
        .map(|(g, _)| g)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_sorted_merges_and_dedups() {
        assert_eq!(union_sorted(&[1, 3, 5], &[2, 3, 6]), vec![1, 2, 3, 5, 6]);
        assert_eq!(union_sorted(&[], &[4]), vec![4]);
        assert_eq!(union_sorted(&[4], &[]), vec![4]);
        let e: Vec<usize> = vec![];
        assert_eq!(union_sorted(&[], &[]), e);
    }

    #[test]
    fn union_sorted_into_clears_stale_contents() {
        let mut out = vec![9usize, 9, 9];
        union_sorted_into(&[1, 2], &[2, 5], &mut out);
        assert_eq!(out, vec![1, 2, 5]);
    }

    #[test]
    fn active_sets_from_beta() {
        let g = crate::groups::Groups::from_sizes(&[2, 2]);
        let beta = [0.0, 1.0, 0.0, 0.0];
        assert_eq!(active_vars(&beta), vec![1]);
        assert_eq!(active_groups(&beta, &g), vec![0]);
    }

    #[test]
    fn active_vars_into_clears_stale_contents() {
        let mut out = vec![7usize, 7, 7, 7];
        active_vars_into(&[0.5, 0.0, -1.0], &mut out);
        assert_eq!(out, vec![0, 2]);
    }

    #[test]
    fn rule_names_and_kkt_flags() {
        assert!(RuleKind::DfrSgl.needs_kkt());
        assert!(RuleKind::Sparsegl.needs_kkt());
        assert!(!RuleKind::GapSafeSeq.needs_kkt());
        assert!(!RuleKind::Tlfre.needs_kkt());
        assert!(!RuleKind::NoScreen.needs_kkt());
        assert_eq!(RuleKind::DfrAsgl.name(), "DFR-aSGL");
        assert_eq!(RuleKind::Tlfre.name(), "TLFre");
        assert_eq!(RuleKind::ALL.len(), 7);
        // Exactly the three strong rules require KKT verification.
        let strong: Vec<_> =
            RuleKind::ALL.iter().filter(|r| r.needs_kkt()).collect();
        assert_eq!(strong.len(), 3);
    }

    #[test]
    fn logistic_fallback_is_exactly_the_safe_rules() {
        // The safe rules carry squared-loss certificates only; strong
        // rules and the no-screen baseline never fall back.
        for r in RuleKind::ALL {
            assert_eq!(
                r.logistic_fallback(),
                matches!(r, RuleKind::Tlfre | RuleKind::GapSafeSeq | RuleKind::GapSafeDyn),
                "{}",
                r.name()
            );
        }
    }
}
