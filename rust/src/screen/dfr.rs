//! Dual Feature Reduction — the paper's bi-level strong screening rule.
//!
//! **Group layer** (Eq. 5 / 7): discard group g for `λ_{k+1}` if
//!
//! ```text
//!     ‖∇_g f(β̂(λ_k))‖_{ε_g}  ≤  τ_g (2λ_{k+1} − λ_k)          (SGL)
//!     ‖∇_g f(β̂(λ_k))‖_{ε'_g} ≤  γ_g (2λ_{k+1} − λ_k)          (aSGL)
//! ```
//!
//! **Variable layer** (Eq. 6 / 8): within surviving groups discard i if
//!
//! ```text
//!     |∇_i f(β̂(λ_k))|  ≤  α vᵢ (2λ_{k+1} − λ_k).
//! ```
//!
//! Both layers derive from the ε-norm form of the (a)SGL dual norm plus a
//! Lipschitz assumption on the gradient path (Propositions 2.2 / 2.4 and
//! B.2 / B.4); failures of the assumption are caught by the KKT check.
//! With unit weights the aSGL quantities reduce exactly to the SGL ones
//! (`γ_g = τ_g`, `ε'_g = ε_g`), so one implementation serves both rules.
//!
//! The special cases of Appendix A.4 fall out naturally: `α = 0` skips the
//! variable layer (group lasso strong rule), `α = 1` with singleton groups
//! reduces to the lasso strong rule.

use super::{Candidates, ScreenContext};
use crate::norms::{epsilon_norm, eps_g_adaptive, gamma_g};

/// Run the DFR screen (both SGL and aSGL, depending on the penalty's
/// weights).
pub fn screen(ctx: &ScreenContext) -> Candidates {
    let pen = ctx.penalty;
    let groups = &pen.groups;
    let alpha = pen.alpha;
    let thresh_scale = 2.0 * ctx.lambda_next - ctx.lambda_prev;

    // ---- Layer 1: group reduction ----
    let mut cand_groups = Vec::new();
    for (g, r) in groups.iter() {
        let grad_g = &ctx.grad_prev[r.clone()];
        let beta_g = &ctx.beta_prev[r.clone()];
        let v_g = &pen.v[r.clone()];
        // γ_g (τ_g when v ≡ w ≡ 1) and its ε.
        let gam = gamma_g(beta_g, v_g, pen.w[g], alpha);
        let eps = eps_g_adaptive(gam, pen.w[g], alpha, groups.size(g));
        let lhs = epsilon_norm(grad_g, eps);
        if lhs > gam * thresh_scale {
            cand_groups.push(g);
        }
    }

    // ---- Layer 2: variable reduction within candidate groups ----
    let mut cand_vars = Vec::new();
    if alpha == 0.0 {
        // Group-lasso limit: no variable screening (Appendix A.4).
        for &g in &cand_groups {
            cand_vars.extend(groups.range(g));
        }
    } else {
        for &g in &cand_groups {
            for i in groups.range(g) {
                if ctx.grad_prev[i].abs() > alpha * pen.v[i] * thresh_scale {
                    cand_vars.push(i);
                }
            }
        }
    }

    Candidates { groups: cand_groups, vars: cand_vars }
}

/// The *theoretical* rules (Propositions 2.1 / 2.3 / B.1 / B.3): identify
/// the exact support using the gradient at `λ_{k+1}` itself. Not usable in
/// practice (the gradient at the next point is unknown); exposed for the
/// property tests that verify the support-recovery claims.
///
/// Boundary note: at an exact solution, *active* groups/variables satisfy
/// the dual constraint with **equality** (`‖∇_g‖_{ε_g} = τ_g λ`, the KKT
/// stationarity geometry), so the propositions' strict inequality is a
/// knife-edge in floating point. We include the boundary with a small
/// relative slack — without it, solver noise of either sign would flip
/// active groups out of the candidate set.
pub fn screen_theoretical(
    pen: &crate::penalty::Penalty,
    grad_next: &[f64],
    beta_next: &[f64],
    lambda_next: f64,
) -> Candidates {
    const SLACK: f64 = 1.0 - 1e-6;
    let groups = &pen.groups;
    let alpha = pen.alpha;
    let mut cand_groups = Vec::new();
    for (g, r) in groups.iter() {
        let gam = gamma_g(&beta_next[r.clone()], &pen.v[r.clone()], pen.w[g], alpha);
        let eps = eps_g_adaptive(gam, pen.w[g], alpha, groups.size(g));
        if epsilon_norm(&grad_next[r.clone()], eps) > gam * lambda_next * SLACK {
            cand_groups.push(g);
        }
    }
    let mut cand_vars = Vec::new();
    if alpha == 0.0 {
        for &g in &cand_groups {
            cand_vars.extend(groups.range(g));
        }
    } else {
        for &g in &cand_groups {
            for i in groups.range(g) {
                if grad_next[i].abs() > lambda_next * alpha * pen.v[i] * SLACK {
                    cand_vars.push(i);
                }
            }
        }
    }
    Candidates { groups: cand_groups, vars: cand_vars }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Response;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::penalty::Penalty;
    use crate::rng::Rng;

    fn ctx_fixture(
        alpha: f64,
    ) -> (Matrix, Vec<f64>, Penalty, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(42);
        let mut x = Matrix::from_fn(30, 12, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(30);
        let pen = Penalty::sgl(Groups::even(12, 4), alpha);
        let beta = vec![0.0; 12];
        let loss = crate::loss::Loss::new(crate::loss::LossKind::Squared, &x, &y);
        let grad = loss.gradient(&beta);
        (x, y, pen, beta, grad)
    }

    #[test]
    fn at_lambda_max_everything_is_screened_out() {
        let (x, y, pen, beta, grad) = ctx_fixture(0.95);
        let lam_max = crate::norms::dual_sgl_norm(&grad, &pen.groups, 0.95);
        // Sequential step from λ_max to λ_max (no decrease): every group's
        // ε-norm is ≤ τ_g·λ_max by definition of the dual norm.
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: lam_max,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        assert!(c.groups.is_empty(), "groups {:?}", c.groups);
        assert!(c.vars.is_empty());
    }

    #[test]
    fn tiny_lambda_keeps_everything() {
        let (x, y, pen, beta, grad) = ctx_fixture(0.95);
        let lam_max = crate::norms::dual_sgl_norm(&grad, &pen.groups, 0.95);
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: 1e-9 * lam_max,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        // 2λ' − λ < 0 ⇒ thresholds negative ⇒ nothing can be discarded.
        assert_eq!(c.groups.len(), pen.groups.m());
        assert_eq!(c.vars.len(), pen.groups.p());
    }

    #[test]
    fn alpha_zero_reduces_to_group_lasso_rule() {
        let (x, y, pen, beta, grad) = ctx_fixture(0.0);
        let lam_max = crate::norms::dual_sgl_norm(&grad, &pen.groups, 0.0);
        let lam_next = 0.8 * lam_max;
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: lam_next,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        // Compare with a direct group-lasso strong rule: keep g iff
        // ‖∇_g‖₂ > √p_g (2λ' − λ)  (ε_g = 1 at α = 0).
        let mut expect = Vec::new();
        for (g, r) in pen.groups.iter() {
            let n2: f64 = grad[r].iter().map(|v| v * v).sum::<f64>().sqrt();
            if n2 > (pen.groups.size(g) as f64).sqrt() * (2.0 * lam_next - lam_max) {
                expect.push(g);
            }
        }
        assert_eq!(c.groups, expect);
        // All variables of candidate groups are candidates at α = 0.
        let nvars: usize = c.groups.iter().map(|&g| pen.groups.size(g)).sum();
        assert_eq!(c.vars.len(), nvars);
    }

    #[test]
    fn alpha_one_singletons_reduce_to_lasso_rule() {
        let mut rng = Rng::new(7);
        let mut x = Matrix::from_fn(25, 10, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(25);
        let pen = Penalty::sgl(Groups::singletons(10), 1.0);
        let beta = vec![0.0; 10];
        let loss = crate::loss::Loss::new(crate::loss::LossKind::Squared, &x, &y);
        let grad = loss.gradient(&beta);
        let lam_max = grad.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let lam_next = 0.85 * lam_max;
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: lam_next,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        let expect: Vec<usize> = (0..10)
            .filter(|&i| grad[i].abs() > 2.0 * lam_next - lam_max)
            .collect();
        assert_eq!(c.vars, expect);
    }

    #[test]
    fn candidate_vars_subset_of_candidate_groups() {
        let (x, y, pen, beta, grad) = ctx_fixture(0.5);
        let lam_max = crate::norms::dual_sgl_norm(&grad, &pen.groups, 0.5);
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: 0.7 * lam_max,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        for &v in &c.vars {
            assert!(c.groups.contains(&pen.groups.group_of(v)));
        }
    }
}
