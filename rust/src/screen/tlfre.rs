//! TLFre — two-layer *safe* feature reduction for SGL / aSGL (Wang & Ye,
//! arXiv:1410.4210), the safe counterpart of the paper's strong DFR rule.
//!
//! The dual of the 1/(2n)-scaled squared loss with penalty `λΩ(β)` is, in
//! the scaled variable `η = θ/λ` with `ỹ = y/n`,
//!
//! ```text
//!     η*(λ) = P_C(ỹ/λ),   C = { η : ‖S(X_gᵀη, α v^(g))‖₂ ≤ ρ_g ∀g } ,
//! ```
//!
//! with `ρ_g = (1−α) w_g √p_g` — the decomposition of the SGL dual-norm
//! unit ball into per-group soft-threshold cylinders (TLFre's "decomposition
//! of convex sets"; with the adaptive weights `v, w` it covers aSGL). Since
//! `η*(λ)` is a Euclidean projection, the (E)DPP machinery localizes
//! `η*(λ_{k+1})` in a ball built from the previous path solution:
//!
//! * **DPP** (nonexpansiveness): with `u = ỹ(1/λ_{k+1} − 1/λ_k)`,
//!   `η*(λ_{k+1}) ∈ B(η*(λ_k) + u/2, ‖u‖/2)`.
//! * **EDPP** (the tighter variant): with `v₁ = ỹ/λ_k − η*(λ_k)`,
//!   `v₂ = ỹ/λ_{k+1} − η*(λ_k)` and `v₂⊥` the component of `v₂`
//!   orthogonal to `v₁`, `η*(λ_{k+1}) ∈ B(η*(λ_k) + v₂⊥/2, ‖v₂⊥‖/2)`.
//!
//! `η*(λ_k)` is never exact in practice, so both balls are inflated by the
//! GAP-safe certificate: a dual-feasible `η̂` built from the previous
//! iterate (exact per-group gauge scaling, [`feasibility_gauge`]) has
//! `‖η̂ − η*(λ_k)‖ ≤ δ = √(2·gap/n)/λ_k`, the same center/radius plumbing
//! as [`super::gap_safe`]. The inflation keeps the rule **safe under
//! inexact solves** — the property `rust/tests/screening_safety.rs` pins.
//!
//! Given a ball `B(c, r)` containing `η*(λ_{k+1})`, the two layers are
//!
//! * group:    `sup_B ‖S(X_gᵀη, αv)‖₂ ≤ ‖S(X_gᵀc, αv)‖₂ + r‖X_g‖_F < ρ_g`
//!   ⟹ `β̂_g(λ_{k+1}) = 0` (an active group sits exactly on `ρ_g`),
//! * variable: `sup_B |X_iᵀη| ≤ |X_iᵀc| + r‖X_i‖₂ < α vᵢ`
//!   ⟹ `β̂ᵢ(λ_{k+1}) = 0` (an active variable has `|X_iᵀη*| ≥ αvᵢ`).
//!
//! Ties keep (strict `<` discards), so boundary cases stay safe. Defined
//! for the linear model only; logistic responses degrade to no screening,
//! exactly like GAP safe.

use super::{Candidates, ScreenContext};
use crate::data::Response;
use crate::linalg::DesignRef;
use crate::norms::soft_threshold;
use crate::penalty::Penalty;

/// Sequential TLFre: screen for `λ_{k+1}` using the solution at `λ_k`.
pub fn screen(ctx: &ScreenContext) -> Candidates {
    if ctx.response != Response::Linear {
        return Candidates::full(ctx.penalty);
    }
    screen_between(
        ctx.penalty,
        ctx.x,
        ctx.y,
        ctx.beta_prev,
        ctx.lambda_prev,
        ctx.lambda_next,
    )
}

/// TLFre test for `lambda_next` from the primal point `beta_prev` at
/// `lambda_prev > lambda_next`. Generic over the kernel view, so the safe
/// rule never densifies a sparse design. Any degenerate input (non-finite
/// intermediates, a non-descending λ pair, an infeasible gauge) falls back
/// to the full candidate set — the rule may only ever *shrink* safely.
pub fn screen_between<'a>(
    pen: &Penalty,
    x: impl Into<DesignRef<'a>>,
    y: &[f64],
    beta_prev: &[f64],
    lambda_prev: f64,
    lambda_next: f64,
) -> Candidates {
    let x = x.into();
    let n = y.len() as f64;
    if !lambda_prev.is_finite()
        || !lambda_next.is_finite()
        || lambda_prev <= 0.0
        || lambda_next <= 0.0
        || lambda_next >= lambda_prev
    {
        return Candidates::full(pen);
    }

    // Residual and correlations of the previous solution.
    let xb = x.matvec(beta_prev);
    let resid: Vec<f64> = y.iter().zip(&xb).map(|(yi, xi)| yi - xi).collect();
    let threads = crate::parallel::default_threads();
    let xtr = x.t_matvec_par(&resid, threads);

    // Dual-feasible η̂: scale η̂_raw = resid/(nλ_k) into C by the exact
    // gauge of the decomposed feasible set (X̃ᵀη̂_raw = xtr/(nλ_k)).
    let raw_scale = n * lambda_prev;
    let xi_raw: Vec<f64> = xtr.iter().map(|v| v / raw_scale).collect();
    let gauge = match feasibility_gauge(&xi_raw, pen) {
        Some(g) if g.is_finite() => g.max(1.0),
        _ => return Candidates::full(pen),
    };
    let eta: Vec<f64> = resid.iter().map(|r| r / (raw_scale * gauge)).collect();

    // δ = ‖η̂ − η*(λ_k)‖ bound from the duality gap at (β_prev, θ̂ = λ_k η̂):
    // the dual D(θ) = θᵀy − n/2‖θ‖² is n-strongly concave, so
    // ‖θ̂ − θ*‖ ≤ √(2·gap/n); divide by λ_k for the η scale.
    let primal = {
        let f: f64 = resid.iter().map(|r| r * r).sum::<f64>() / (2.0 * n);
        f + lambda_prev * pen.value(beta_prev)
    };
    let dual_obj = {
        let ty: f64 = eta.iter().zip(y).map(|(e, yi)| e * yi).sum::<f64>() * lambda_prev;
        let tt: f64 =
            eta.iter().map(|e| e * e).sum::<f64>() * lambda_prev * lambda_prev;
        ty - n / 2.0 * tt
    };
    let gap = (primal - dual_obj).max(0.0);
    let delta = (2.0 * gap / n).sqrt() / lambda_prev;

    // (E)DPP balls in η scale, inflated by δ for the inexact center.
    let inv_prev = 1.0 / (n * lambda_prev);
    let inv_next = 1.0 / (n * lambda_next);
    let mut v1_sq = 0.0;
    let mut v2_sq = 0.0;
    let mut v12 = 0.0;
    for (i, &yi) in y.iter().enumerate() {
        let a = yi * inv_prev - eta[i];
        let b = yi * inv_next - eta[i];
        v1_sq += a * a;
        v2_sq += b * b;
        v12 += a * b;
    }
    let v1_norm = v1_sq.sqrt();
    let v2_norm = v2_sq.sqrt();

    // DPP: center shift u/2, radius ‖u‖/2 + δ, with u = ỹ(1/λ' − 1/λ_k)
    // independent of η̂ — rigorous even when v₁ ≈ 0 (λ_k = λ_max).
    let u_norm = {
        let s: f64 = y.iter().map(|yi| yi * yi).sum::<f64>().sqrt();
        s * (inv_next - inv_prev)
    };
    let r_dpp = 0.5 * u_norm + delta;

    // EDPP: project v₂ off v₁; only trustworthy when v₁ clears the
    // uncertainty δ by a wide margin, and inflated for the error the
    // inexact (v̂₁, v̂₂) pair induces in the projection.
    let mut use_edpp = false;
    let mut r_edpp = f64::INFINITY;
    let mut kappa = 0.0;
    if v1_norm > 10.0 * delta && v1_norm > 0.0 {
        kappa = v12 / v1_sq;
        let v2perp_sq = (v2_sq - kappa * v12).max(0.0);
        r_edpp =
            0.5 * v2perp_sq.sqrt() + 3.0 * delta + 2.0 * delta * v2_norm / (v1_norm - delta);
        use_edpp = r_edpp < r_dpp;
    }

    // Ball center as an n-vector; one transpose pass gives every X_iᵀc.
    let radius = if use_edpp { r_edpp } else { r_dpp };
    let center: Vec<f64> = if use_edpp {
        // c = η̂ + v̂₂⊥/2 with v̂₂⊥ = v̂₂ − κ v̂₁.
        y.iter()
            .zip(&eta)
            .map(|(yi, e)| {
                let a = yi * inv_prev - e;
                let b = yi * inv_next - e;
                e + 0.5 * (b - kappa * a)
            })
            .collect()
    } else {
        y.iter()
            .zip(&eta)
            .map(|(yi, e)| e + 0.5 * yi * (inv_next - inv_prev))
            .collect()
    };
    let xt_c = x.t_matvec_par(&center, threads);
    let col_norms = x.col_norms();
    if !radius.is_finite() || xt_c.iter().any(|v| !v.is_finite()) {
        return Candidates::full(pen);
    }

    // Two-layer elimination over the ball; ties keep.
    let alpha = pen.alpha;
    let groups = &pen.groups;
    let mut cand_groups = Vec::new();
    let mut cand_vars = Vec::new();
    for (g, rr) in groups.iter() {
        let rho_g = (1.0 - alpha) * pen.w[g] * (groups.size(g) as f64).sqrt();
        let mut s_sq = 0.0;
        let mut frob_sq = 0.0;
        for i in rr.clone() {
            let s = soft_threshold(xt_c[i], alpha * pen.v[i]);
            s_sq += s * s;
            frob_sq += col_norms[i] * col_norms[i];
        }
        // sup over the ball of the group dual response; an active group
        // attains exactly ρ_g, so a strict shortfall certifies inactivity.
        if s_sq.sqrt() + radius * frob_sq.sqrt() < rho_g {
            continue;
        }
        cand_groups.push(g);
        for i in rr {
            // An active variable has |X_iᵀη*| ≥ αvᵢ; keep unless the whole
            // ball falls strictly short (α = 0 keeps everything since the
            // sup is nonnegative).
            if xt_c[i].abs() + radius * col_norms[i] >= alpha * pen.v[i] {
                cand_vars.push(i);
            }
        }
    }
    Candidates { groups: cand_groups, vars: cand_vars }
}

/// Exact gauge of the decomposed dual-feasible set at `ξ = X̃ᵀη`: the
/// smallest `s > 0` with `‖S(ξ^(g)/s, α v^(g))‖₂ ≤ ρ_g` for every group —
/// i.e. the (a)SGL dual norm of `ξ`, evaluated per group by bisection on
/// the monotone constraint function rather than through the ε-norm
/// identities, so it stays exact for arbitrary adaptive weights.
///
/// Returns `None` when no finite scaling is feasible (only possible when
/// `ρ_g = 0` and some `α vᵢ = 0` with `ξᵢ ≠ 0`). The returned gauge errs
/// on the feasible (larger) side of the bisection bracket.
pub fn feasibility_gauge(xi: &[f64], pen: &Penalty) -> Option<f64> {
    let alpha = pen.alpha;
    let mut worst: f64 = 0.0;
    for (g, rr) in pen.groups.iter() {
        let rho_g = (1.0 - alpha) * pen.w[g] * (pen.groups.size(g) as f64).sqrt();
        let xi_g = &xi[rr.clone()];
        let v_g = &pen.v[rr];
        let s = group_gauge(xi_g, v_g, alpha, rho_g)?;
        worst = worst.max(s);
    }
    Some(worst)
}

/// Per-group gauge: smallest `s` with `‖S(ξ/s, αv)‖₂ ≤ ρ`.
fn group_gauge(xi: &[f64], v: &[f64], alpha: f64, rho: f64) -> Option<f64> {
    let fits = |s: f64| -> bool {
        let mut nsq = 0.0;
        for (x, vi) in xi.iter().zip(v) {
            let t = soft_threshold(x / s, alpha * vi);
            nsq += t * t;
        }
        nsq.sqrt() <= rho
    };
    if fits(1.0) {
        return Some(1.0);
    }
    // A feasible bracket endpoint: ‖S(ξ/s, ·)‖ ≤ ‖ξ‖/s ≤ ρ, or the scale
    // that thresholds every coordinate to zero outright.
    let l2 = xi.iter().map(|x| x * x).sum::<f64>().sqrt();
    let mut hi = f64::INFINITY;
    if rho > 0.0 {
        hi = l2 / rho;
    }
    let mut all_vanish: f64 = 1.0;
    let mut vanishable = true;
    for (x, vi) in xi.iter().zip(v) {
        let t = alpha * vi;
        if t > 0.0 {
            all_vanish = all_vanish.max(x.abs() / t);
        } else if x.abs() > 0.0 {
            vanishable = false;
        }
    }
    if vanishable {
        hi = hi.min(all_vanish);
    }
    if !hi.is_finite() {
        return None;
    }
    let mut lo = 1.0;
    debug_assert!(fits(hi), "bracket endpoint must be feasible");
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if fits(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // Return the feasible end of the bracket — conservative by construction.
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::loss::{Loss, LossKind};
    use crate::penalty::AdaptiveWeights;
    use crate::rng::Rng;
    use crate::solver::{solve, SolverConfig};

    fn problem(seed: u64, n: usize, p: usize) -> (crate::linalg::Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = crate::linalg::Matrix::from_fn(n, p, |_, _| rng.gauss());
        x.standardize_l2();
        let beta_true: Vec<f64> =
            (0..p).map(|j| if j % 5 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
        let mut y = x.matvec(&beta_true);
        y.iter_mut().for_each(|v| *v += rng.normal(0.0, 0.2));
        let ymean = y.iter().sum::<f64>() / y.len() as f64;
        y.iter_mut().for_each(|v| *v -= ymean);
        (x, y)
    }

    /// The safety property: TLFre must never discard a variable that is
    /// active at the optimal solution for the λ it screens for.
    #[test]
    fn never_discards_active_variables() {
        for trial in 0..5u64 {
            let (x, y) = problem(31 + trial, 40, 24);
            let g = Groups::even(24, 6);
            let pen = Penalty::sgl(g.clone(), 0.9);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let lam_max =
                crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 24]), &g, 0.9);
            let lam_prev = 0.5 * lam_max;
            let lam_next = 0.4 * lam_max;
            let cfg = SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
            let prev = solve(&loss, &pen, lam_prev, &vec![0.0; 24], &cfg);
            let next = solve(&loss, &pen, lam_next, &prev.beta, &cfg);

            let cands = screen_between(&pen, &x, &y, &prev.beta, lam_prev, lam_next);
            for (i, &b) in next.beta.iter().enumerate() {
                if b.abs() > 1e-7 {
                    assert!(
                        cands.vars.contains(&i),
                        "trial {trial}: active var {i} (β={b}) was unsafely discarded"
                    );
                }
            }
        }
    }

    /// Safety must survive a *sloppy* previous solution — the δ inflation
    /// is what carries the certificate under inexact solves.
    #[test]
    fn safe_under_inexact_previous_solution() {
        let (x, y) = problem(77, 50, 30);
        let g = Groups::even(30, 5);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 30]), &g, 0.95);
        let lam_prev = 0.6 * lam_max;
        let lam_next = 0.45 * lam_max;
        // Deliberately loose previous solve.
        let sloppy = SolverConfig { tol: 1e-3, max_iters: 40, ..Default::default() };
        let prev = solve(&loss, &pen, lam_prev, &vec![0.0; 30], &sloppy);
        let tight = SolverConfig { tol: 1e-11, max_iters: 100_000, ..Default::default() };
        let next = solve(&loss, &pen, lam_next, &vec![0.0; 30], &tight);
        let cands = screen_between(&pen, &x, &y, &prev.beta, lam_prev, lam_next);
        for (i, &b) in next.beta.iter().enumerate() {
            if b.abs() > 1e-7 {
                assert!(cands.vars.contains(&i), "inexact-center discard of active var {i}");
            }
        }
    }

    /// From λ_max with the exact null solution the rule must both stay safe
    /// and actually discard something on a reasonable step.
    #[test]
    fn screens_from_lambda_max_null_model() {
        let (x, y) = problem(12, 60, 40);
        let g = Groups::even(40, 8);
        let pen = Penalty::sgl(g.clone(), 0.9);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; 40]), &g, 0.9);
        let lam_next = 0.9 * lam_max;
        let cands = screen_between(&pen, &x, &y, &vec![0.0; 40], lam_max, lam_next);
        assert!(
            cands.vars.len() < 40,
            "no reduction at all from the null model ({} vars kept)",
            cands.vars.len()
        );
        let tight = SolverConfig { tol: 1e-11, max_iters: 100_000, ..Default::default() };
        let next = solve(&loss, &pen, lam_next, &vec![0.0; 40], &tight);
        for (i, &b) in next.beta.iter().enumerate() {
            if b.abs() > 1e-7 {
                assert!(cands.vars.contains(&i), "λ_max step discarded active var {i}");
            }
        }
    }

    /// Adaptive-weight variant: safety holds under aSGL weights too.
    #[test]
    fn adaptive_variant_is_safe() {
        let (x, y) = problem(55, 50, 30);
        let g = Groups::even(30, 6);
        let aw = AdaptiveWeights::from_design(&x, &g, 0.1, 0.1);
        let pen = Penalty::asgl(g.clone(), 0.9, aw.v, aw.w);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let grad0 = loss.gradient(&vec![0.0; 30]);
        let lam_max = crate::path::lambda_max(&pen, &grad0);
        let lam_prev = 0.5 * lam_max;
        let lam_next = 0.4 * lam_max;
        let cfg = SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
        let prev = solve(&loss, &pen, lam_prev, &vec![0.0; 30], &cfg);
        let next = solve(&loss, &pen, lam_next, &prev.beta, &cfg);
        let cands = screen_between(&pen, &x, &y, &prev.beta, lam_prev, lam_next);
        for (i, &b) in next.beta.iter().enumerate() {
            if b.abs() > 1e-7 {
                assert!(cands.vars.contains(&i), "aSGL: discarded active var {i} (β={b})");
            }
        }
    }

    /// α edge cases: the pure-lasso limit (group layer can never fire,
    /// ρ_g = 0) and the pure-group-lasso limit (variable layer keeps every
    /// variable of a surviving group).
    #[test]
    fn alpha_limits_degrade_gracefully() {
        let (x, y) = problem(91, 40, 20);
        let g = Groups::even(20, 4);
        for alpha in [0.0, 1.0] {
            let pen = Penalty::sgl(g.clone(), alpha);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let grad0 = loss.gradient(&vec![0.0; 20]);
            let lam_max = crate::path::lambda_max(&pen, &grad0);
            let (lam_prev, lam_next) = (0.6 * lam_max, 0.5 * lam_max);
            let tight = SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
            let prev = solve(&loss, &pen, lam_prev, &vec![0.0; 20], &tight);
            let next = solve(&loss, &pen, lam_next, &prev.beta, &tight);
            let cands = screen_between(&pen, &x, &y, &prev.beta, lam_prev, lam_next);
            for (i, &b) in next.beta.iter().enumerate() {
                if b.abs() > 1e-7 {
                    assert!(cands.vars.contains(&i), "α={alpha}: discarded active var {i}");
                }
            }
            if alpha == 0.0 {
                // Variable layer inert: kept groups contribute all columns.
                for &gid in &cands.groups {
                    for i in g.range(gid) {
                        assert!(cands.vars.contains(&i), "α=0 dropped var {i} of kept group");
                    }
                }
            }
        }
    }

    /// Logistic and degenerate λ inputs fall back to the full set.
    #[test]
    fn degenerate_inputs_fall_back_to_full() {
        let (x, y) = problem(7, 20, 8);
        let g = Groups::even(8, 4);
        let pen = Penalty::sgl(g, 0.9);
        // Non-descending λ pair.
        let c = screen_between(&pen, &x, &y, &vec![0.0; 8], 0.5, 0.5);
        assert_eq!(c.vars.len(), 8);
        // NaN λ.
        let c = screen_between(&pen, &x, &y, &vec![0.0; 8], f64::NAN, 0.2);
        assert_eq!(c.vars.len(), 8);
        // Logistic response through the dispatcher entry point.
        let grad = vec![0.0; 8];
        let beta = vec![0.0; 8];
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: 1.0,
            lambda_next: 0.9,
            x: (&x).into(),
            y: &y,
            response: Response::Logistic,
        };
        assert_eq!(screen(&ctx).vars.len(), 8);
    }

    /// The gauge scaling really does produce a dual-feasible point, and for
    /// unit weights it coincides with the ε-norm dual norm.
    #[test]
    fn gauge_matches_dual_norm_on_unit_weights() {
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let p = 12;
            let g = Groups::even(p, 4);
            let alpha = [0.0, 0.3, 0.7, 0.95, 1.0][rng.below(5)];
            let pen = Penalty::sgl(g.clone(), alpha);
            let xi: Vec<f64> = rng.gauss_vec(p);
            let gauge = feasibility_gauge(&xi, &pen).expect("finite gauge");
            let dual = crate::norms::dual_sgl_norm(&xi, &g, alpha);
            // The gauge is clamped at 1 from below only in screen_between;
            // here it is the raw max over groups, which equals the dual
            // norm whenever the dual norm exceeds the bracket floor.
            if dual > 1.0 {
                assert!(
                    (gauge - dual).abs() <= 1e-9 * (1.0 + dual),
                    "gauge {gauge} vs dual norm {dual} at α={alpha}"
                );
            } else {
                assert_eq!(gauge, 1.0, "sub-unit dual norm must report gauge 1");
            }
            // Feasibility of the scaled point.
            for (gid, rr) in g.iter() {
                let mut nsq = 0.0;
                for i in rr {
                    let t = soft_threshold(xi[i] / gauge, alpha * pen.v[i]);
                    nsq += t * t;
                }
                let rho = (1.0 - alpha) * pen.w[gid] * 2.0;
                assert!(
                    nsq.sqrt() <= rho + 1e-9,
                    "scaled point infeasible in group {gid}: {} > {rho}",
                    nsq.sqrt()
                );
            }
        }
    }
}
