//! KKT optimality checks (§2.3.3, Appendix A.2 / B.2.4, and the sparsegl
//! group check of Appendix C).
//!
//! Strong rules can err when their Lipschitz assumptions fail; after the
//! reduced solve, every *excluded* variable is checked against the KKT
//! inactivity condition at the new solution. For DFR the variable-level
//! check is (Eq. 17 / 26)
//!
//! ```text
//!     |S(∇_i f(β̂(λ_{k+1})), λ_{k+1}(1−α) w_g √p_g)|  ≤  λ_{k+1} α vᵢ ,
//! ```
//!
//! where the `√p_g` slack is the worst case of the unknown group-ℓ2
//! subgradient (|Ψᵢ| ≤ √p_g on the ℓ2 unit ball, Appendix A.2). For
//! sparsegl the check is at group level (Eq. 27):
//! `‖S(∇_g f, λα)‖₂ ≤ √p_g(1−α)λ`.

use crate::norms::soft_threshold;
use crate::penalty::Penalty;

/// DFR variable-level check: return the (sorted) violating variables among
/// `excluded` given the gradient and the solution at the new λ.
///
/// For variables in groups that are *inactive* in `beta_new`, the group-ℓ2
/// subgradient is unknown and bounded by `√p_g` on the ℓ2 ball, giving the
/// paper's soft-threshold slack (Eq. 17 / Appendix A.2). For variables in
/// *active* groups, `‖β_g‖₂ > 0` makes the group norm differentiable, the
/// subgradient coordinate is exactly `β_i/‖β_g‖ = 0`, and the condition
/// tightens to `|∇_i f| ≤ λαvᵢ` — using the tight form here is what keeps
/// the screened path solutions exactly equal to the no-screen ones.
pub fn variable_violations(
    pen: &Penalty,
    grad_new: &[f64],
    beta_new: &[f64],
    lambda: f64,
    excluded: impl Iterator<Item = usize>,
) -> Vec<usize> {
    let mut group_active = Vec::new();
    let mut out = Vec::new();
    variable_violations_into(pen, grad_new, beta_new, lambda, excluded, &mut group_active, &mut out);
    out
}

/// [`variable_violations`] into caller-provided buffers (both cleared
/// first) — the allocation-free form for the pathwise KKT re-entry loop.
/// `group_active` is scratch for the per-group activity flags.
pub fn variable_violations_into(
    pen: &Penalty,
    grad_new: &[f64],
    beta_new: &[f64],
    lambda: f64,
    excluded: impl Iterator<Item = usize>,
    group_active: &mut Vec<bool>,
    out: &mut Vec<usize>,
) {
    let alpha = pen.alpha;
    group_active.clear();
    group_active.extend(
        pen.groups.iter().map(|(_, r)| beta_new[r].iter().any(|&b| b != 0.0)),
    );
    out.clear();
    for i in excluded {
        let g = pen.groups.group_of(i);
        let s = if group_active[g] {
            grad_new[i]
        } else {
            let slack =
                lambda * (1.0 - alpha) * pen.w[g] * (pen.groups.size(g) as f64).sqrt();
            soft_threshold(grad_new[i], slack)
        };
        if s.abs() > lambda * alpha * pen.v[i] + KKT_TOL {
            out.push(i);
        }
    }
}

/// sparsegl group-level check: return the variables of every *excluded
/// group* that violates the group inactivity condition (sparsegl re-adds
/// whole groups).
pub fn group_violations(
    pen: &Penalty,
    grad_new: &[f64],
    lambda: f64,
    excluded_groups: impl Iterator<Item = usize>,
) -> (Vec<usize>, usize) {
    let mut vars = Vec::new();
    let count = group_violations_into(pen, grad_new, lambda, excluded_groups, &mut vars);
    (vars, count)
}

/// [`group_violations`] into a caller-provided buffer (cleared first);
/// returns the number of violating groups.
pub fn group_violations_into(
    pen: &Penalty,
    grad_new: &[f64],
    lambda: f64,
    excluded_groups: impl Iterator<Item = usize>,
    vars: &mut Vec<usize>,
) -> usize {
    let alpha = pen.alpha;
    vars.clear();
    let mut count = 0;
    for g in excluded_groups {
        let r = pen.groups.range(g);
        let mut nsq = 0.0;
        for i in r.clone() {
            let s = soft_threshold(grad_new[i], lambda * alpha * pen.v[i]);
            nsq += s * s;
        }
        let rhs = (pen.groups.size(g) as f64).sqrt() * pen.w[g] * (1.0 - alpha) * lambda;
        if nsq.sqrt() > rhs + KKT_TOL {
            count += 1;
            vars.extend(r);
        }
    }
    count
}

/// Numerical slack on the KKT inequalities: the inner solver is accurate to
/// its tolerance, so exact-zero tests would flag spurious violations.
pub const KKT_TOL: f64 = 1e-7;

/// Worst-case stationarity residual of `(β, ∇f(β))` at `λ` — the audit
/// number behind the KKT-audit harness ([`crate::testkit::KktAudit`]) and
/// the per-point `kkt_residual` metric. Zero at an exact optimum; a small
/// positive value bounds how far the solution sits from satisfying the
/// full (a)SGL KKT system:
///
/// * active variable `i` in group `g`:
///   `|∇ᵢf + λαvᵢ·sgn(βᵢ) + λ(1−α)w_g√p_g·βᵢ/‖β^(g)‖₂|`,
/// * zero variable in an *active* group: `(|∇ᵢf| − λαvᵢ)₊` (the group
///   subgradient coordinate is exactly 0 there),
/// * fully inactive group: `(‖S(∇_gf, λαv)‖₂ − λ(1−α)w_g√p_g)₊`.
///
/// The maximum over all three families is returned.
pub fn stationarity_residual(
    pen: &Penalty,
    grad: &[f64],
    beta: &[f64],
    lambda: f64,
) -> f64 {
    let alpha = pen.alpha;
    let mut worst: f64 = 0.0;
    for (g, r) in pen.groups.iter() {
        let rho = lambda * (1.0 - alpha) * pen.w[g] * (pen.groups.size(g) as f64).sqrt();
        let norm = beta[r.clone()].iter().map(|b| b * b).sum::<f64>().sqrt();
        if norm > 0.0 {
            for i in r {
                let res = if beta[i] != 0.0 {
                    (grad[i] + lambda * alpha * pen.v[i] * beta[i].signum()
                        + rho * beta[i] / norm)
                        .abs()
                } else {
                    (grad[i].abs() - lambda * alpha * pen.v[i]).max(0.0)
                };
                worst = worst.max(res);
            }
        } else {
            let mut nsq = 0.0;
            for i in r {
                let s = soft_threshold(grad[i], lambda * alpha * pen.v[i]);
                nsq += s * s;
            }
            worst = worst.max((nsq.sqrt() - rho).max(0.0));
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::rng::Rng;
    use crate::solver::{solve, SolverConfig};

    /// At an exact solution, no excluded variable that is truly zero may be
    /// flagged — the KKT condition holds by optimality.
    #[test]
    fn no_false_violations_at_exact_solution() {
        let mut rng = Rng::new(20);
        let p = 20;
        let mut x = Matrix::from_fn(40, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(40);
        let g = Groups::even(p, 5);
        let pen = Penalty::sgl(g.clone(), 0.9);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
        let lam = 0.4 * lam_max;
        let cfg = SolverConfig { tol: 1e-12, max_iters: 100000, ..Default::default() };
        let sol = solve(&loss, &pen, lam, &vec![0.0; p], &cfg);
        let grad = loss.gradient(&sol.beta);
        let excluded: Vec<usize> =
            (0..p).filter(|&i| sol.beta[i] == 0.0).collect();
        // Variables in fully-inactive groups must pass the check.
        let viol = variable_violations(
            &pen,
            &grad,
            &sol.beta,
            lam,
            excluded.iter().copied().filter(|&i| {
                let gg = g.group_of(i);
                sol.beta[g.range(gg)].iter().all(|&b| b == 0.0)
            }),
        );
        assert!(viol.is_empty(), "false violations: {viol:?}");
    }

    /// A variable with a large gradient must be flagged.
    #[test]
    fn detects_planted_violation() {
        let g = Groups::from_sizes(&[2, 2]);
        let pen = Penalty::sgl(g, 0.5);
        // λ = 1: slack = (1−α)√2 ≈ 0.707, threshold λα = 0.5.
        let mut grad = vec![0.0; 4];
        grad[3] = 5.0; // |S(5, .707)| = 4.29 > 0.5 → violation
        let beta = vec![0.0; 4];
        let viol = variable_violations(&pen, &grad, &beta, 1.0, [2usize, 3].into_iter());
        assert_eq!(viol, vec![3]);
    }

    #[test]
    fn group_check_flags_whole_group() {
        let g = Groups::from_sizes(&[3, 3]);
        let pen = Penalty::sgl(g, 0.5);
        let mut grad = vec![0.0; 6];
        grad[4] = 10.0;
        let (vars, count) = group_violations(&pen, &grad, 1.0, [1usize].into_iter());
        assert_eq!(count, 1);
        assert_eq!(vars, vec![3, 4, 5]);
    }

    /// A tightly-solved problem has a near-zero stationarity residual; a
    /// perturbed copy of the same solution does not.
    #[test]
    fn stationarity_residual_vanishes_at_optimum() {
        let mut rng = Rng::new(21);
        let p = 20;
        let mut x = Matrix::from_fn(40, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(40);
        let g = Groups::even(p, 5);
        let pen = Penalty::sgl(g.clone(), 0.9);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
        let lam = 0.4 * lam_max;
        let cfg = SolverConfig { tol: 1e-12, max_iters: 200_000, ..Default::default() };
        let sol = solve(&loss, &pen, lam, &vec![0.0; p], &cfg);
        let grad = loss.gradient(&sol.beta);
        let res = stationarity_residual(&pen, &grad, &sol.beta, lam);
        assert!(res <= 1e-6, "residual {res} at a tight solve");
        // Perturb one active coordinate: the residual must light up.
        let mut bad = sol.beta.clone();
        if let Some(i) = bad.iter().position(|&b| b != 0.0) {
            bad[i] += 0.5;
            let grad_bad = loss.gradient(&bad);
            let res_bad = stationarity_residual(&pen, &grad_bad, &bad, lam);
            assert!(res_bad > 1e-2, "perturbed residual {res_bad} too small");
        }
        // The null model at λ ≥ λ_max is exactly stationary.
        let grad0 = loss.gradient(&vec![0.0; p]);
        let res0 = stationarity_residual(&pen, &grad0, &vec![0.0; p], lam_max * 1.0001);
        assert_eq!(res0, 0.0, "null model above λ₁ must have zero residual");
    }

    #[test]
    fn group_check_passes_quiet_groups() {
        let g = Groups::from_sizes(&[3]);
        let pen = Penalty::sgl(g, 0.95);
        let grad = vec![0.01; 3];
        let (vars, count) = group_violations(&pen, &grad, 1.0, [0usize].into_iter());
        assert!(vars.is_empty());
        assert_eq!(count, 0);
    }
}
