//! GAP safe sphere screening for SGL (Ndiaye et al., NeurIPS 2016; paper
//! Appendix C, Eqs. 30–33).
//!
//! An *exact* rule: using any primal point β and the dual-feasible point
//!
//! ```text
//!     θ_c = (y − Xβ) / max(n, ‖Xᵀ(y − Xβ)‖*_sgl / λ) ,
//! ```
//!
//! the optimal dual solution lies in the sphere `B(θ_c, r)` with
//! `r = √(2·gap / n)` (the dual objective of the 1/(2n)-scaled squared loss
//! is n-strongly concave). Any variable/group whose worst case over the
//! sphere still satisfies the inactivity condition is *guaranteed*
//! inactive:
//!
//! * variable: `|X_jᵀθ_c| + r‖X_j‖₂ ≤ λ α v_j`,
//! * group:    `‖S(X_gᵀθ_c, λα)‖₂ + r‖X_g‖_F ≤ λ (1−α) √p_g` (the
//!   Frobenius norm upper-bounds the operator norm, keeping the test safe).
//!
//! Defined for the linear model only, as in the paper; for logistic
//! responses the rule degrades to no screening. The sequential variant
//! screens once per path point from β̂(λ_k); the dynamic variant re-screens
//! inside the solver loop every few iterations (driven by the path
//! coordinator via [`screen_dynamic`]).

use super::{Candidates, ScreenContext};
use crate::data::Response;
use crate::linalg::DesignRef;
use crate::norms::soft_threshold;
use crate::penalty::Penalty;

/// Sequential GAP safe: screen for `λ_{k+1}` using the previous solution.
pub fn screen(ctx: &ScreenContext) -> Candidates {
    if ctx.response != Response::Linear {
        return Candidates::full(ctx.penalty);
    }
    screen_at(ctx.penalty, ctx.x, ctx.y, ctx.beta_prev, ctx.lambda_next)
}

/// GAP safe test at `lambda` using primal point `beta` (shared by the
/// sequential rule and the dynamic re-screens). Generic over the kernel
/// view, so the exact rule never densifies a sparse design.
pub fn screen_at<'a>(
    pen: &Penalty,
    x: impl Into<DesignRef<'a>>,
    y: &[f64],
    beta: &[f64],
    lambda: f64,
) -> Candidates {
    let x = x.into();
    let n = y.len() as f64;
    let groups = &pen.groups;
    let alpha = pen.alpha;

    // Residual and its correlation vector.
    let xb = x.matvec(beta);
    let resid: Vec<f64> = y.iter().zip(&xb).map(|(yi, xi)| yi - xi).collect();
    let xtr = x.t_matvec_par(&resid, crate::parallel::default_threads());

    // Dual-feasible point θ_c = resid / max(n, ‖Xᵀresid‖*_sgl / λ).
    let dual_norm = dual_sgl_weighted(&xtr, pen);
    let scale = (dual_norm / lambda).max(n);
    let theta: Vec<f64> = resid.iter().map(|r| r / scale).collect();
    // X_jᵀθ_c for all j.
    let xt_theta: Vec<f64> = xtr.iter().map(|v| v / scale).collect();

    // Duality gap: P(β) − D(θ) with f = 1/(2n)‖y−Xβ‖², D(θ) = θᵀy − n/2‖θ‖².
    let primal = {
        let f: f64 = resid.iter().map(|r| r * r).sum::<f64>() / (2.0 * n);
        f + lambda * pen.value(beta)
    };
    let dual = {
        let ty: f64 = theta.iter().zip(y).map(|(t, yi)| t * yi).sum();
        let tt: f64 = theta.iter().map(|t| t * t).sum();
        ty - n / 2.0 * tt
    };
    let gap = (primal - dual).max(0.0);
    let r_safe = (2.0 * gap / n).sqrt();

    // Column norms (‖X_j‖₂ = 1 after standardization, but compute anyway).
    let col_norms = x.col_norms();

    let mut cand_groups = Vec::new();
    let mut cand_vars = Vec::new();
    for (g, rr) in groups.iter() {
        // Group test.
        let mut s_sq = 0.0;
        let mut frob_sq = 0.0;
        for i in rr.clone() {
            let s = soft_threshold(xt_theta[i], lambda * alpha * pen.v[i]);
            s_sq += s * s;
            frob_sq += col_norms[i] * col_norms[i];
        }
        let t_g = s_sq.sqrt() + r_safe * frob_sq.sqrt();
        let group_rhs =
            lambda * (1.0 - alpha) * pen.w[g] * (groups.size(g) as f64).sqrt();
        let group_survives = t_g > group_rhs || (1.0 - alpha) == 0.0;
        if !group_survives {
            continue;
        }
        cand_groups.push(g);
        // Variable test within surviving groups.
        for i in rr {
            let worst = xt_theta[i].abs() + r_safe * col_norms[i];
            if worst > lambda * alpha * pen.v[i] || alpha == 0.0 {
                cand_vars.push(i);
            }
        }
    }
    Candidates { groups: cand_groups, vars: cand_vars }
}

/// Dynamic GAP safe: given the current inner-solver iterate on the reduced
/// problem (scattered back to full length by the caller), re-derive a safe
/// sphere and return a (possibly smaller) candidate set.
pub fn screen_dynamic<'a>(
    pen: &Penalty,
    x: impl Into<DesignRef<'a>>,
    y: &[f64],
    beta_full: &[f64],
    lambda: f64,
) -> Candidates {
    screen_at(pen, x, y, beta_full, lambda)
}

/// Weighted SGL dual norm `max_g γ_g⁻¹‖ξ^(g)‖_{ε'_g}` used to scale the
/// dual point for adaptive penalties as well (γ evaluated at β = 0 limits).
fn dual_sgl_weighted(xi: &[f64], pen: &Penalty) -> f64 {
    let mut best: f64 = 0.0;
    for (g, r) in pen.groups.iter() {
        let zeros = vec![0.0; pen.groups.size(g)];
        let gam = crate::norms::gamma_g(&zeros, &pen.v[r.clone()], pen.w[g], pen.alpha);
        let eps = crate::norms::eps_g_adaptive(gam, pen.w[g], pen.alpha, pen.groups.size(g));
        let v = crate::norms::epsilon_norm(&xi[r], eps);
        if gam > 0.0 {
            best = best.max(v / gam);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::loss::{Loss, LossKind};
    use crate::rng::Rng;
    use crate::solver::{solve, SolverConfig};

    /// The safety property: GAP safe must never discard a variable that is
    /// active at the optimal solution for the λ it screens for.
    #[test]
    fn never_discards_active_variables() {
        let mut rng = Rng::new(9);
        for trial in 0..5 {
            let p = 24;
            let mut x = crate::linalg::Matrix::from_fn(40, p, |_, _| rng.gauss());
            x.standardize_l2();
            let beta_true: Vec<f64> =
                (0..p).map(|j| if j % 5 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
            let mut y = x.matvec(&beta_true);
            y.iter_mut().for_each(|v| *v += rng.normal(0.0, 0.2));
            let ymean = y.iter().sum::<f64>() / y.len() as f64;
            y.iter_mut().for_each(|v| *v -= ymean);

            let g = Groups::even(p, 6);
            let pen = Penalty::sgl(g.clone(), 0.9);
            let loss = Loss::new(LossKind::Squared, &x, &y);
            let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.9);
            let lam_prev = 0.5 * lam_max;
            let lam_next = 0.4 * lam_max;
            let cfg = SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
            let prev = solve(&loss, &pen, lam_prev, &vec![0.0; p], &cfg);
            let next = solve(&loss, &pen, lam_next, &prev.beta, &cfg);

            let cands = screen_at(&pen, &x, &y, &prev.beta, lam_next);
            for (i, &b) in next.beta.iter().enumerate() {
                if b.abs() > 1e-7 {
                    assert!(
                        cands.vars.contains(&i),
                        "trial {trial}: active var {i} (β={b}) was unsafely discarded"
                    );
                }
            }
        }
    }

    #[test]
    fn gap_shrinks_with_better_primal_point() {
        // Screening from the exact solution at the same λ should keep fewer
        // variables than screening from the null vector.
        let mut rng = Rng::new(10);
        let p = 30;
        let mut x = crate::linalg::Matrix::from_fn(50, p, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(50);
        let g = Groups::even(p, 5);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max = crate::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), &g, 0.95);
        let lam = 0.5 * lam_max;
        let cfg = SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
        let sol = solve(&loss, &pen, lam, &vec![0.0; p], &cfg);
        let from_null = screen_at(&pen, &x, &y, &vec![0.0; p], lam);
        let from_sol = screen_at(&pen, &x, &y, &sol.beta, lam);
        assert!(
            from_sol.vars.len() <= from_null.vars.len(),
            "dynamic refinement failed: {} > {}",
            from_sol.vars.len(),
            from_null.vars.len()
        );
    }

    #[test]
    fn logistic_falls_back_to_full() {
        let mut rng = Rng::new(11);
        let x = crate::linalg::Matrix::from_fn(20, 8, |_, _| rng.gauss());
        let y: Vec<f64> = (0..20).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let pen = Penalty::sgl(Groups::even(8, 4), 0.9);
        let beta = vec![0.0; 8];
        let grad = vec![0.0; 8];
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: 1.0,
            lambda_next: 0.9,
            x: (&x).into(),
            y: &y,
            response: Response::Logistic,
        };
        let c = screen(&ctx);
        assert_eq!(c.vars.len(), 8);
    }
}
