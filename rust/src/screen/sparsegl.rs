//! The `sparsegl` group-level strong rule (Liang et al. 2022; paper
//! Appendix C, Eqs. 27–29).
//!
//! A single layer of *group* screening based on Simon et al.'s first-order
//! inactivity condition `‖S(∇_g f, λα)‖₂ ≤ √p_g (1−α) λ` and a Lipschitz
//! assumption on the ℓ2 (group-lasso) part of the penalty only:
//!
//! ```text
//!     discard g  ⇔  ‖S(∇_g f(β̂(λ_k)), λ_{k+1} α)‖₂ ≤ √p_g (1−α)(2λ_{k+1} − λ_k).
//! ```
//!
//! No variable layer: once a group survives, *all* of its variables enter
//! the optimization set — this is exactly the gap DFR's second layer
//! closes, and the source of the large `O_v` gaps in Tables A3/A6/A9.

use super::{Candidates, ScreenContext};
use crate::norms::soft_threshold;

pub fn screen(ctx: &ScreenContext) -> Candidates {
    let pen = ctx.penalty;
    let groups = &pen.groups;
    let alpha = pen.alpha;
    let thresh_scale = 2.0 * ctx.lambda_next - ctx.lambda_prev;

    let mut cand_groups = Vec::new();
    let mut cand_vars = Vec::new();
    for (g, r) in groups.iter() {
        // Soft-threshold level uses the ℓ1 part at the *new* λ, following
        // the sparsegl package (Eq. 27 evaluated at λ_{k+1}).
        let mut nsq = 0.0;
        for i in r.clone() {
            let s = soft_threshold(ctx.grad_prev[i], ctx.lambda_next * alpha * pen.v[i]);
            nsq += s * s;
        }
        let rhs =
            pen.w[g] * (groups.size(g) as f64).sqrt() * (1.0 - alpha) * thresh_scale;
        if nsq.sqrt() > rhs {
            cand_groups.push(g);
            cand_vars.extend(r);
        }
    }
    Candidates { groups: cand_groups, vars: cand_vars }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Response;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::penalty::Penalty;
    use crate::rng::Rng;

    #[test]
    fn keeps_whole_groups() {
        let mut rng = Rng::new(3);
        let mut x = Matrix::from_fn(40, 20, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(40);
        let pen = Penalty::sgl(Groups::even(20, 5), 0.95);
        let beta = vec![0.0; 20];
        let loss = crate::loss::Loss::new(crate::loss::LossKind::Squared, &x, &y);
        let grad = loss.gradient(&beta);
        let lam_max = crate::norms::dual_sgl_norm(&grad, &pen.groups, 0.95);
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: lam_max,
            lambda_next: 0.6 * lam_max,
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        // Every candidate group contributes all of its variables.
        let expect: usize = c.groups.iter().map(|&g| pen.groups.size(g)).sum();
        assert_eq!(c.vars.len(), expect);
    }

    #[test]
    fn no_screening_possible_when_lambda_rises() {
        // 2λ' − λ < 0 ⇒ RHS negative ⇒ every group stays (‖S‖ ≥ 0); except
        // ‖S‖ = 0 = RHS edge — allow full retention only.
        let mut rng = Rng::new(4);
        let mut x = Matrix::from_fn(30, 8, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(30);
        let pen = Penalty::sgl(Groups::even(8, 4), 0.5);
        let beta = vec![0.0; 8];
        let loss = crate::loss::Loss::new(crate::loss::LossKind::Squared, &x, &y);
        let grad = loss.gradient(&beta);
        let ctx = ScreenContext {
            penalty: &pen,
            grad_prev: &grad,
            beta_prev: &beta,
            lambda_prev: 1.0,
            lambda_next: 0.2, // 2·0.2 − 1 < 0
            x: (&x).into(),
            y: &y,
            response: Response::Linear,
        };
        let c = screen(&ctx);
        assert_eq!(c.groups.len(), 2);
    }
}
