//! Report writers: paper-style tables, per-path CSV series (for the
//! figure benches), and a minimal JSON emitter for machine-readable
//! experiment records (no `serde` offline).

use crate::metrics::PathMetrics;

/// Render per-path-point metrics as CSV (one row per λ) — the series behind
//  Figure 5 / A13-style plots.
pub fn path_metrics_csv(m: &PathMetrics) -> String {
    let mut s = String::from(
        "lambda,a_v,a_g,c_v,c_g,o_v,o_g,kkt_violations,iterations,status,fit_seconds,input_proportion\n",
    );
    for pt in &m.points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            pt.lambda,
            pt.a_v,
            pt.a_g,
            pt.c_v,
            pt.c_g,
            pt.o_v,
            pt.o_g,
            pt.kkt_violations,
            pt.solver_iterations,
            pt.status.label(),
            pt.fit_seconds,
            pt.o_v as f64 / m.p.max(1) as f64,
        ));
    }
    s
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: &str, content: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)
}

/// Minimal JSON value builder (strings, numbers, bools, arrays, objects) —
/// enough for experiment records without a serde dependency.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Num(x) => {
                if x.is_finite() {
                    format!("{x}")
                } else {
                    "null".into()
                }
            }
            Json::Str(s) => format!("\"{}\"", escape(s)),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(kv) => {
                let inner: Vec<String> =
                    kv.iter().map(|(k, v)| format!("\"{}\":{}", escape(k), v.render())).collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Summary record for one (dataset, rule) run — what the CLI prints and
/// the benches append to their JSON log.
pub fn run_record(
    dataset: &str,
    rule: &str,
    m: &PathMetrics,
    improvement_factor: Option<f64>,
    l2_distance: Option<f64>,
) -> Json {
    Json::obj(vec![
        ("dataset", Json::Str(dataset.into())),
        ("rule", Json::Str(rule.into())),
        ("total_seconds", Json::Num(m.total_seconds)),
        ("input_proportion", Json::Num(m.input_proportion())),
        ("group_input_proportion", Json::Num(m.group_input_proportion())),
        ("kkt_violations", Json::Num(m.total_kkt_violations() as f64)),
        ("failed_convergences", Json::Num(m.failed_convergences() as f64)),
        ("status", Json::Str(m.worst_status().label().into())),
        ("mean_iterations", Json::Num(m.mean_iterations())),
        (
            "improvement_factor",
            improvement_factor.map(Json::Num).unwrap_or(Json::Null),
        ),
        ("l2_distance", l2_distance.map(Json::Num).unwrap_or(Json::Null)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::PointMetrics;

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = PathMetrics { p: 10, m: 2, ..Default::default() };
        m.points.push(PointMetrics { lambda: 0.5, o_v: 5, ..Default::default() });
        let csv = path_metrics_csv(&m);
        assert!(csv.starts_with("lambda,"));
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("0.5"));
    }

    #[test]
    fn json_renders_and_escapes() {
        let j = Json::obj(vec![
            ("name", Json::Str("a\"b".into())),
            ("v", Json::Arr(vec![Json::Num(1.0), Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(j.render(), "{\"name\":\"a\\\"b\",\"v\":[1,true,null]}");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
