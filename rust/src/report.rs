//! Report writers: paper-style tables, per-path CSV series (for the
//! figure benches), and a minimal JSON emitter for machine-readable
//! experiment records (no `serde` offline).

use crate::metrics::PathMetrics;

/// Render per-path-point metrics as CSV (one row per λ) — the series behind
//  Figure 5 / A13-style plots.
pub fn path_metrics_csv(m: &PathMetrics) -> String {
    let mut s = String::from(
        "lambda,a_v,a_g,c_v,c_g,o_v,o_g,kkt_violations,iterations,status,fit_seconds,input_proportion\n",
    );
    for pt in &m.points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            pt.lambda,
            pt.a_v,
            pt.a_g,
            pt.c_v,
            pt.c_g,
            pt.o_v,
            pt.o_g,
            pt.kkt_violations,
            pt.solver_iterations,
            pt.status.label(),
            pt.fit_seconds,
            pt.o_v as f64 / m.p.max(1) as f64,
        ));
    }
    s
}

/// Write a string to a file, creating parent directories.
pub fn write_file(path: &str, content: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)
}

/// Minimal JSON value builder (strings, numbers, bools, arrays, objects) —
/// enough for experiment records without a serde dependency.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Parse a JSON document (the read half the NDJSON serving protocol
    /// needs; no `serde` offline). Strict on structure — trailing garbage,
    /// unterminated strings, and nesting deeper than 64 levels are errors
    /// — and lossy only where [`Json`] itself is: every number becomes
    /// `f64`.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing characters at byte {pos}");
        Ok(value)
    }

    /// Object field lookup (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a number representing one.
    pub fn as_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        (x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64)
            .then_some(x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Num(x) => {
                if x.is_finite() {
                    format!("{x}")
                } else {
                    "null".into()
                }
            }
            Json::Str(s) => format!("\"{}\"", escape(s)),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(kv) => {
                let inner: Vec<String> =
                    kv.iter().map(|(k, v)| format!("\"{}\":{}", escape(k), v.render())).collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

const MAX_DEPTH: usize = 64;

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "expected `{lit}` at byte {}",
        *pos
    );
    *pos += lit.len();
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> anyhow::Result<Json> {
    anyhow::ensure!(depth < MAX_DEPTH, "JSON nested deeper than {MAX_DEPTH} levels");
    skip_ws(b, pos);
    match b.get(*pos) {
        None => anyhow::bail!("unexpected end of input"),
        Some(b'n') => expect(b, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|()| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => anyhow::bail!("expected `,` or `]` at byte {}", *pos),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut kv = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(kv));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                kv.push((key, parse_value(b, pos, depth + 1)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(kv));
                    }
                    _ => anyhow::bail!("expected `,` or `}}` at byte {}", *pos),
                }
            }
        }
        Some(_) => parse_number(b, pos).map(Json::Num),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    expect(b, pos, "\"")?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => anyhow::bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = parse_hex4(b, *pos + 1)?;
                        *pos += 4;
                        // Surrogate pairs arrive as two adjacent \uXXXX.
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            anyhow::ensure!(
                                b.get(*pos + 1..*pos + 3).is_some_and(|s| s == b"\\u"),
                                "lone high surrogate"
                            );
                            let lo = parse_hex4(b, *pos + 3)?;
                            *pos += 6;
                            anyhow::ensure!(
                                (0xDC00..0xE000).contains(&lo),
                                "invalid low surrogate"
                            );
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            anyhow::ensure!(
                                !(0xDC00..0xE000).contains(&hi),
                                "lone low surrogate"
                            );
                            hi
                        };
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            None => anyhow::bail!("invalid unicode escape"),
                        }
                    }
                    _ => anyhow::bail!("invalid escape at byte {}", *pos),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => anyhow::bail!("raw control byte in string"),
            Some(_) => {
                // Copy one UTF-8 scalar (input is &str, so boundaries are valid).
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && (b[*pos] & 0xC0) == 0x80 {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
}

fn parse_hex4(b: &[u8], at: usize) -> anyhow::Result<u32> {
    let hex = b
        .get(at..at + 4)
        .and_then(|s| std::str::from_utf8(s).ok())
        .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
    u32::from_str_radix(hex, 16).map_err(|_| anyhow::anyhow!("bad \\u escape `{hex}`"))
}

fn parse_number(b: &[u8], pos: &mut usize) -> anyhow::Result<f64> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while b
        .get(*pos)
        .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = text
        .parse()
        .map_err(|_| anyhow::anyhow!("invalid number `{text}` at byte {start}"))?;
    anyhow::ensure!(x.is_finite(), "non-finite number `{text}`");
    Ok(x)
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Summary record for one (dataset, rule) run — what the CLI prints and
/// the benches append to their JSON log.
pub fn run_record(
    dataset: &str,
    rule: &str,
    m: &PathMetrics,
    improvement_factor: Option<f64>,
    l2_distance: Option<f64>,
) -> Json {
    Json::obj(vec![
        ("dataset", Json::Str(dataset.into())),
        ("rule", Json::Str(rule.into())),
        ("total_seconds", Json::Num(m.total_seconds)),
        ("input_proportion", Json::Num(m.input_proportion())),
        ("group_input_proportion", Json::Num(m.group_input_proportion())),
        ("kkt_violations", Json::Num(m.total_kkt_violations() as f64)),
        ("failed_convergences", Json::Num(m.failed_convergences() as f64)),
        ("status", Json::Str(m.worst_status().label().into())),
        ("screening_fallback", Json::Bool(m.screening_fallback)),
        ("mean_iterations", Json::Num(m.mean_iterations())),
        (
            "improvement_factor",
            improvement_factor.map(Json::Num).unwrap_or(Json::Null),
        ),
        ("l2_distance", l2_distance.map(Json::Num).unwrap_or(Json::Null)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::PointMetrics;

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = PathMetrics { p: 10, m: 2, ..Default::default() };
        m.points.push(PointMetrics { lambda: 0.5, o_v: 5, ..Default::default() });
        let csv = path_metrics_csv(&m);
        assert!(csv.starts_with("lambda,"));
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("0.5"));
    }

    #[test]
    fn json_renders_and_escapes() {
        let j = Json::obj(vec![
            ("name", Json::Str("a\"b".into())),
            ("v", Json::Arr(vec![Json::Num(1.0), Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(j.render(), "{\"name\":\"a\\\"b\",\"v\":[1,true,null]}");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn run_record_carries_screening_fallback() {
        let m = PathMetrics { p: 3, m: 1, screening_fallback: true, ..Default::default() };
        let rec = run_record("d", "TLFre", &m, None, None);
        assert_eq!(rec.get("screening_fallback").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn parse_round_trips_render() {
        let j = Json::obj(vec![
            ("verb", Json::Str("fit".into())),
            ("n", Json::Num(12.0)),
            ("x", Json::Arr(vec![Json::Num(1.5), Json::Num(-2e3), Json::Null])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.render(), j.render());
        assert_eq!(back.get("verb").and_then(Json::as_str), Some("fit"));
        assert_eq!(back.get("n").and_then(Json::as_usize), Some(12));
        assert_eq!(back.get("x").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        assert_eq!(
            back.get("nested").and_then(|n| n.get("ok")).and_then(Json::as_bool),
            Some(true)
        );
    }

    #[test]
    fn parse_handles_escapes_and_whitespace() {
        let j = Json::parse(" { \"a\\n\\\"b\" : \"\\u00e9\\ud83d\\ude00\" } ").unwrap();
        assert_eq!(j.get("a\n\"b").and_then(Json::as_str), Some("é😀"));
        let esc = Json::Str("tab\t né😀".into());
        assert_eq!(
            Json::parse(&esc.render()).unwrap().as_str(),
            Some("tab\t né😀")
        );
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2", "{}extra",
            "\"\\ud800\"", "nan", "1e999",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject `{bad}`");
        }
        // Depth limit holds instead of blowing the stack.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn parse_accepts_empty_containers_and_negatives() {
        assert!(matches!(Json::parse("[]").unwrap(), Json::Arr(v) if v.is_empty()));
        assert!(matches!(Json::parse("{}").unwrap(), Json::Obj(v) if v.is_empty()));
        assert_eq!(Json::parse("-3.25e-2").unwrap().as_f64(), Some(-0.0325));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
