//! SGL / aSGL penalties, their exact proximal operator, and the PCA-based
//! adaptive weights of Mendez-Civieta et al. (Appendix B.3).
//!
//! Both penalties are represented in one weighted form
//!
//! ```text
//!     Ω(β) = α Σᵢ vᵢ|βᵢ| + (1−α) Σ_g w_g √p_g ‖β^(g)‖₂ ,
//! ```
//!
//! with `v ≡ 1, w ≡ 1` recovering plain SGL. The prox of `t·λ·Ω` is exact
//! and separable per group: soft-threshold each coordinate at `tλαvᵢ`, then
//! group-shrink by `(1 − tλ(1−α)w_g√p_g/‖u_g‖₂)₊` (Simon et al. 2013).

pub mod adaptive;

pub use adaptive::AdaptiveWeights;

use crate::groups::Groups;
use crate::norms::soft_threshold;

/// A sparse-group penalty bound to a grouping structure.
#[derive(Clone, Debug)]
pub struct Penalty {
    pub alpha: f64,
    /// Per-variable ℓ1 weights `vᵢ` (all 1 for SGL).
    pub v: Vec<f64>,
    /// Per-group ℓ2 weights `w_g` (all 1 for SGL).
    pub w: Vec<f64>,
    pub groups: Groups,
}

impl Penalty {
    /// Plain SGL with mixing parameter `alpha`.
    pub fn sgl(groups: Groups, alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        let p = groups.p();
        let m = groups.m();
        Penalty { alpha, v: vec![1.0; p], w: vec![1.0; m], groups }
    }

    /// Adaptive SGL with explicit weights.
    pub fn asgl(groups: Groups, alpha: f64, v: Vec<f64>, w: Vec<f64>) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        assert_eq!(v.len(), groups.p());
        assert_eq!(w.len(), groups.m());
        Penalty { alpha, v, w, groups }
    }

    /// Is this the adaptive variant (non-unit weights)?
    pub fn is_adaptive(&self) -> bool {
        self.v.iter().any(|&x| x != 1.0) || self.w.iter().any(|&x| x != 1.0)
    }

    /// Penalty value `Ω(β)` (without λ).
    pub fn value(&self, beta: &[f64]) -> f64 {
        crate::norms::asgl_norm(beta, &self.groups, self.alpha, &self.v, &self.w)
    }

    /// Exact prox: `argmin_b ½‖b − z‖² + t·λ·Ω(b)`, written into `out`.
    pub fn prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        debug_assert_eq!(z.len(), self.groups.p());
        debug_assert_eq!(out.len(), z.len());
        let a = self.alpha;
        for (g, r) in self.groups.iter() {
            let p_g = (self.groups.size(g) as f64).sqrt();
            let gthresh = t_lambda * (1.0 - a) * self.w[g] * p_g;
            let range = r.clone();
            // Stage 1: soft threshold.
            let mut norm_sq = 0.0;
            for i in range.clone() {
                let u = soft_threshold(z[i], t_lambda * a * self.v[i]);
                out[i] = u;
                norm_sq += u * u;
            }
            // Stage 2: group shrinkage.
            let nrm = norm_sq.sqrt();
            if nrm <= gthresh {
                for i in range {
                    out[i] = 0.0;
                }
            } else {
                let scale = 1.0 - gthresh / nrm;
                for i in range {
                    out[i] *= scale;
                }
            }
        }
    }

    /// Allocating prox wrapper.
    pub fn prox(&self, z: &[f64], t_lambda: f64) -> Vec<f64> {
        let mut out = vec![0.0; z.len()];
        self.prox_into(z, t_lambda, &mut out);
        out
    }

    /// Prox of only the ℓ1 part (`t·λ·α Σ vᵢ|·|`) — one of the two simple
    /// operators that ATOS splits the penalty into.
    pub fn prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        for i in 0..z.len() {
            out[i] = soft_threshold(z[i], t_lambda * self.alpha * self.v[i]);
        }
    }

    /// Prox of only the group-ℓ2 part (`t·λ·(1−α) Σ w_g√p_g‖·‖₂`).
    pub fn prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        for (g, r) in self.groups.iter() {
            let p_g = (self.groups.size(g) as f64).sqrt();
            let gthresh = t_lambda * (1.0 - self.alpha) * self.w[g] * p_g;
            let zb = &z[r.clone()];
            let nrm = zb.iter().map(|v| v * v).sum::<f64>().sqrt();
            if nrm <= gthresh {
                for i in r {
                    out[i] = 0.0;
                }
            } else {
                let scale = 1.0 - gthresh / nrm;
                for i in r {
                    out[i] = z[i] * scale;
                }
            }
        }
    }

    /// Exact prox of group `g`'s block alone: `z`/`out` are the block
    /// slices (length `p_g`). Identical math to the group-loop body of
    /// [`Penalty::prox_into`] — the per-block contract the BCD solver
    /// cycles over.
    pub fn prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        let r = self.groups.range(g);
        debug_assert_eq!(z.len(), r.len());
        debug_assert_eq!(out.len(), r.len());
        let p_g = (self.groups.size(g) as f64).sqrt();
        let gthresh = t_lambda * (1.0 - self.alpha) * self.w[g] * p_g;
        let mut norm_sq = 0.0;
        for ((o, &zk), &vk) in out.iter_mut().zip(z).zip(&self.v[r]) {
            let u = soft_threshold(zk, t_lambda * self.alpha * vk);
            *o = u;
            norm_sq += u * u;
        }
        let nrm = norm_sq.sqrt();
        if nrm <= gthresh {
            out.fill(0.0);
        } else {
            let scale = 1.0 - gthresh / nrm;
            out.iter_mut().for_each(|o| *o *= scale);
        }
    }

    /// Restrict the penalty to a sorted variable subset (the optimization
    /// set), keeping each variable's weight and its *original* group weight
    /// and √p_g (the penalty does not change because screening removed
    /// variables — group thresholds must stay those of the full problem).
    pub fn restrict(&self, vars: &[usize]) -> RestrictedPenalty {
        let (rgroups, orig) = self.groups.restrict(vars);
        let v: Vec<f64> = vars.iter().map(|&i| self.v[i]).collect();
        let w: Vec<f64> = orig.iter().map(|&g| self.w[g]).collect();
        let sqrt_pg: Vec<f64> = orig.iter().map(|&g| (self.groups.size(g) as f64).sqrt()).collect();
        RestrictedPenalty { alpha: self.alpha, v, w, sqrt_pg, groups: rgroups }
    }
}

/// A penalty restricted to the optimization set: group ℓ2 thresholds use
/// the ORIGINAL `√p_g` (the norm of the discarded coordinates is zero, so
/// the objective restricted to the candidate set keeps the original group
/// constants — this is what makes screening solve the same problem).
#[derive(Clone, Debug)]
pub struct RestrictedPenalty {
    pub alpha: f64,
    pub v: Vec<f64>,
    pub w: Vec<f64>,
    /// Original √p_g per restricted group.
    pub sqrt_pg: Vec<f64>,
    pub groups: Groups,
}

impl RestrictedPenalty {
    /// Penalty value on the reduced coordinates.
    pub fn value(&self, beta: &[f64]) -> f64 {
        let a = self.alpha;
        let l1: f64 = beta.iter().zip(&self.v).map(|(b, vi)| vi * b.abs()).sum();
        let mut gl = 0.0;
        for (g, r) in self.groups.iter() {
            let nrm = beta[r].iter().map(|x| x * x).sum::<f64>().sqrt();
            gl += self.w[g] * self.sqrt_pg[g] * nrm;
        }
        a * l1 + (1.0 - a) * gl
    }

    /// Exact prox on the reduced coordinates.
    pub fn prox_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        let a = self.alpha;
        for (g, r) in self.groups.iter() {
            let gthresh = t_lambda * (1.0 - a) * self.w[g] * self.sqrt_pg[g];
            let mut norm_sq = 0.0;
            for i in r.clone() {
                let u = soft_threshold(z[i], t_lambda * a * self.v[i]);
                out[i] = u;
                norm_sq += u * u;
            }
            let nrm = norm_sq.sqrt();
            if nrm <= gthresh {
                for i in r {
                    out[i] = 0.0;
                }
            } else {
                let scale = 1.0 - gthresh / nrm;
                for i in r {
                    out[i] *= scale;
                }
            }
        }
    }

    pub fn prox_l1_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        for i in 0..z.len() {
            out[i] = soft_threshold(z[i], t_lambda * self.alpha * self.v[i]);
        }
    }

    pub fn prox_group_into(&self, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        for (g, r) in self.groups.iter() {
            let gthresh = t_lambda * (1.0 - self.alpha) * self.w[g] * self.sqrt_pg[g];
            let nrm = z[r.clone()].iter().map(|v| v * v).sum::<f64>().sqrt();
            if nrm <= gthresh {
                for i in r {
                    out[i] = 0.0;
                }
            } else {
                let scale = 1.0 - gthresh / nrm;
                for i in r {
                    out[i] = z[i] * scale;
                }
            }
        }
    }

    /// Exact prox of restricted group `g`'s block alone (`z`/`out` are the
    /// block slices) — the reduced-problem counterpart of
    /// [`Penalty::prox_block_into`], with the group threshold built from
    /// the *original* `√p_g`.
    pub fn prox_block_into(&self, g: usize, z: &[f64], t_lambda: f64, out: &mut [f64]) {
        let r = self.groups.range(g);
        debug_assert_eq!(z.len(), r.len());
        debug_assert_eq!(out.len(), r.len());
        let gthresh = t_lambda * (1.0 - self.alpha) * self.w[g] * self.sqrt_pg[g];
        let mut norm_sq = 0.0;
        for ((o, &zk), &vk) in out.iter_mut().zip(z).zip(&self.v[r]) {
            let u = soft_threshold(zk, t_lambda * self.alpha * vk);
            *o = u;
            norm_sq += u * u;
        }
        let nrm = norm_sq.sqrt();
        if nrm <= gthresh {
            out.fill(0.0);
        } else {
            let scale = 1.0 - gthresh / nrm;
            out.iter_mut().for_each(|o| *o *= scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn penalty() -> Penalty {
        Penalty::sgl(Groups::from_sizes(&[3, 2, 4]), 0.95)
    }

    /// Check the prox optimality condition by sampling: the prox point must
    /// attain a lower value of `½‖b−z‖² + tλΩ(b)` than perturbations.
    #[test]
    fn prox_minimizes_objective() {
        let pen = penalty();
        let mut rng = Rng::new(1);
        let z: Vec<f64> = rng.gauss_vec(9);
        let tl = 0.4;
        let b = pen.prox(&z, tl);
        let obj = |bb: &[f64]| {
            0.5 * bb.iter().zip(&z).map(|(a, c)| (a - c) * (a - c)).sum::<f64>()
                + tl * pen.value(bb)
        };
        let base = obj(&b);
        for _ in 0..300 {
            let pert: Vec<f64> = b
                .iter()
                .map(|v| v + 0.05 * rng.gauss())
                .collect();
            assert!(obj(&pert) >= base - 1e-9, "prox not a minimizer");
        }
    }

    #[test]
    fn prox_alpha1_is_soft_threshold() {
        let pen = Penalty::sgl(Groups::from_sizes(&[2, 2]), 1.0);
        let z = [2.0, -0.5, 1.5, 0.2];
        let b = pen.prox(&z, 1.0);
        assert_eq!(b, vec![1.0, 0.0, 0.5, 0.0]);
    }

    #[test]
    fn prox_alpha0_is_group_shrink() {
        let pen = Penalty::sgl(Groups::from_sizes(&[2]), 0.0);
        let z = [3.0, 4.0]; // norm 5, √p_g = √2
        let tl = 1.0;
        let thresh = (2.0f64).sqrt();
        let scale = 1.0 - thresh / 5.0;
        let b = pen.prox(&z, tl);
        assert!((b[0] - 3.0 * scale).abs() < 1e-12);
        assert!((b[1] - 4.0 * scale).abs() < 1e-12);
    }

    #[test]
    fn prox_kills_small_groups_entirely() {
        let pen = Penalty::sgl(Groups::from_sizes(&[3]), 0.5);
        let z = [0.1, -0.05, 0.08];
        let b = pen.prox(&z, 1.0);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prox_is_nonexpansive() {
        let pen = penalty();
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let z1: Vec<f64> = rng.gauss_vec(9);
            let z2: Vec<f64> = rng.gauss_vec(9);
            let p1 = pen.prox(&z1, 0.3);
            let p2 = pen.prox(&z2, 0.3);
            let dp = crate::linalg::l2_distance(&p1, &p2);
            let dz = crate::linalg::l2_distance(&z1, &z2);
            assert!(dp <= dz + 1e-12, "prox expanded: {dp} > {dz}");
        }
    }

    #[test]
    fn restricted_prox_matches_full_prox_on_zero_complement() {
        // If z is zero outside the kept set, the full prox restricted to the
        // kept set equals the restricted prox of the kept z.
        let pen = penalty();
        let keep = vec![0usize, 2, 4, 5, 8];
        let mut rng = Rng::new(3);
        let mut z = vec![0.0; 9];
        for &i in &keep {
            z[i] = rng.gauss();
        }
        let full = pen.prox(&z, 0.25);
        let rpen = pen.restrict(&keep);
        let zr: Vec<f64> = keep.iter().map(|&i| z[i]).collect();
        let mut out = vec![0.0; keep.len()];
        rpen.prox_into(&zr, 0.25, &mut out);
        for (k, &i) in keep.iter().enumerate() {
            assert!((full[i] - out[k]).abs() < 1e-12, "mismatch at {i}");
        }
    }

    #[test]
    fn adaptive_prox_uses_weights() {
        let groups = Groups::from_sizes(&[2]);
        let pen = Penalty::asgl(groups, 1.0, vec![1.0, 10.0], vec![1.0]);
        let z = [2.0, 2.0];
        let b = pen.prox(&z, 0.5);
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert_eq!(b[1], 0.0); // threshold 5 kills it
    }

    #[test]
    fn block_prox_matches_full_prox_groupwise() {
        // The exact prox is separable per group, so proxing each block
        // alone must reproduce the full prox exactly — for plain SGL, for
        // adaptive weights, and for a screening-restricted penalty.
        let pen = Penalty::asgl(
            Groups::from_sizes(&[3, 2, 4]),
            0.9,
            vec![1.0, 2.0, 0.5, 1.5, 1.0, 0.2, 3.0, 1.0, 0.8],
            vec![1.0, 0.7, 1.4],
        );
        let mut rng = Rng::new(5);
        let z: Vec<f64> = rng.gauss_vec(9);
        let tl = 0.3;
        let full = pen.prox(&z, tl);
        let mut blockwise = vec![0.0; 9];
        for (g, r) in pen.groups.iter() {
            let (zs, outs) = (&z[r.clone()], &mut blockwise[r]);
            pen.prox_block_into(g, zs, tl, outs);
        }
        assert_eq!(blockwise, full, "blockwise prox diverged from full prox");

        let keep = vec![0usize, 2, 3, 5, 6, 8];
        let rpen = pen.restrict(&keep);
        let zr: Vec<f64> = keep.iter().map(|&i| z[i]).collect();
        let mut whole = vec![0.0; keep.len()];
        rpen.prox_into(&zr, tl, &mut whole);
        let mut blocks = vec![0.0; keep.len()];
        for (g, r) in rpen.groups.iter() {
            let (zs, outs) = (&zr[r.clone()], &mut blocks[r]);
            rpen.prox_block_into(g, zs, tl, outs);
        }
        assert_eq!(blocks, whole, "restricted blockwise prox diverged");
    }

    #[test]
    fn split_proxes_compose_to_full_prox() {
        // For l1-then-group composition (valid for this penalty family):
        // prox_full(z) == prox_group(prox_l1(z)).
        let pen = penalty();
        let mut rng = Rng::new(4);
        let z: Vec<f64> = rng.gauss_vec(9);
        let tl = 0.37;
        let mut u = vec![0.0; 9];
        pen.prox_l1_into(&z, tl, &mut u);
        let mut composed = vec![0.0; 9];
        pen.prox_group_into(&u, tl, &mut composed);
        let full = pen.prox(&z, tl);
        for (a, b) in composed.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
