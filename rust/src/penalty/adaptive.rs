//! Adaptive weights for aSGL (Appendix B.3).
//!
//! Following Mendez-Civieta et al. (2021), the weights are derived from the
//! first principal component of `X`:
//!
//! ```text
//!     vᵢ = 1 / |q₁ᵢ|^γ₁ ,      w_g = 1 / ‖q₁^(g)‖₂^γ₂ ,
//! ```
//!
//! where `q₁` is the first PCA *loading* vector (the leading right singular
//! vector of the column-centered design). We compute it by power iteration
//! on `XᵀX` — no LAPACK is available offline, and the leading eigenvector
//! is all that is needed. Weights are capped to avoid infinities on exactly
//! zero loadings.

use crate::groups::Groups;
use crate::linalg::{norm2, DesignRef};

/// Cap applied to both weight families; matches the common practice of
/// guarding adaptive lasso weights against zero pilot coefficients.
pub const WEIGHT_CAP: f64 = 1e6;

/// The adaptive weight pair (v, w) of aSGL.
#[derive(Clone, Debug)]
pub struct AdaptiveWeights {
    pub v: Vec<f64>,
    pub w: Vec<f64>,
    pub gamma1: f64,
    pub gamma2: f64,
}

impl AdaptiveWeights {
    /// Compute weights from the design via its first PCA loading.
    ///
    /// `X` is centered internally (PCA convention) but not modified.
    /// Generic over the kernel view, so sparse designs derive their
    /// weights without densifying.
    pub fn from_design<'a>(
        x: impl Into<DesignRef<'a>>,
        groups: &Groups,
        gamma1: f64,
        gamma2: f64,
    ) -> Self {
        let q1 = first_pc_loading(x, 100, 0xADA97);
        let v: Vec<f64> = q1
            .iter()
            .map(|&q| (1.0 / q.abs().max(1e-12).powf(gamma1)).min(WEIGHT_CAP))
            .collect();
        let w: Vec<f64> = (0..groups.m())
            .map(|g| {
                let nrm = norm2(groups.slice(&q1, g));
                (1.0 / nrm.max(1e-12).powf(gamma2)).min(WEIGHT_CAP)
            })
            .collect();
        AdaptiveWeights { v, w, gamma1, gamma2 }
    }

    /// Unit weights (reduces aSGL to SGL); useful in tests/ablations.
    pub fn unit(p: usize, m: usize) -> Self {
        AdaptiveWeights { v: vec![1.0; p], w: vec![1.0; m], gamma1: 0.0, gamma2: 0.0 }
    }
}

/// Leading right singular vector of the column-centered design, by power
/// iteration on `X_cᵀX_c`. Deterministic (seeded start), normalized, with a
/// sign convention (largest-magnitude entry positive) so results are
/// reproducible across runs.
pub fn first_pc_loading<'a>(
    x: impl Into<DesignRef<'a>>,
    iters: usize,
    seed: u64,
) -> Vec<f64> {
    let x = x.into();
    let n = x.nrows();
    let p = x.ncols();
    let col_means: Vec<f64> = x.col_means();
    let mut rng = crate::rng::Rng::new(seed);
    let mut v: Vec<f64> = rng.gauss_vec(p);
    let nv = norm2(&v).max(1e-300);
    v.iter_mut().for_each(|a| *a /= nv);

    let mut xb = vec![0.0; n];
    for _ in 0..iters {
        // xb = X_c v = X v − (meanᵀv)·1
        x.matvec_into(&v, &mut xb);
        let shift: f64 = col_means.iter().zip(&v).map(|(m, vi)| m * vi).sum();
        xb.iter_mut().for_each(|a| *a -= shift);
        // w = X_cᵀ xb = Xᵀ xb − mean·Σxb
        let sum_xb: f64 = xb.iter().sum();
        let mut w = x.t_matvec(&xb);
        for j in 0..p {
            w[j] -= col_means[j] * sum_xb;
        }
        let nw = norm2(&w);
        if nw <= 1e-300 {
            break;
        }
        w.iter_mut().for_each(|a| *a /= nw);
        v = w;
    }
    // Sign convention.
    let (mut best_i, mut best_a) = (0, 0.0f64);
    for (i, &a) in v.iter().enumerate() {
        if a.abs() > best_a {
            best_a = a.abs();
            best_i = i;
        }
    }
    if v[best_i] < 0.0 {
        v.iter_mut().for_each(|a| *a = -*a);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    #[test]
    fn pc_loading_recovers_dominant_direction() {
        // X rows = t·u + small noise for a fixed unit u → loading ≈ u.
        let mut rng = Rng::new(11);
        let p = 6;
        let u = {
            let mut u: Vec<f64> = rng.gauss_vec(p);
            let n = norm2(&u);
            u.iter_mut().for_each(|a| *a /= n);
            u
        };
        let x = Matrix::from_fn(300, p, |i, j| {
            let _ = i;
            0.0 * j as f64
        });
        let mut x = x;
        for i in 0..300 {
            let t = rng.normal(0.0, 3.0);
            for j in 0..p {
                x.set(i, j, t * u[j] + rng.normal(0.0, 0.05));
            }
        }
        let q = first_pc_loading(&x, 200, 1);
        let cos: f64 = q.iter().zip(&u).map(|(a, b)| a * b).sum::<f64>().abs();
        assert!(cos > 0.99, "cosine {cos}");
    }

    #[test]
    fn loading_is_unit_norm() {
        let mut rng = Rng::new(12);
        let x = Matrix::from_fn(40, 9, |_, _| rng.gauss());
        let q = first_pc_loading(&x, 100, 2);
        assert!((norm2(&q) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weights_positive_and_capped() {
        let mut rng = Rng::new(13);
        let x = Matrix::from_fn(30, 12, |_, _| rng.gauss());
        let g = Groups::from_sizes(&[4, 4, 4]);
        let aw = AdaptiveWeights::from_design(&x, &g, 0.1, 0.1);
        assert_eq!(aw.v.len(), 12);
        assert_eq!(aw.w.len(), 3);
        assert!(aw.v.iter().all(|&v| v > 0.0 && v <= WEIGHT_CAP));
        assert!(aw.w.iter().all(|&w| w > 0.0 && w <= WEIGHT_CAP));
    }

    #[test]
    fn gamma_zero_gives_unit_weights() {
        let mut rng = Rng::new(14);
        let x = Matrix::from_fn(30, 8, |_, _| rng.gauss());
        let g = Groups::from_sizes(&[4, 4]);
        let aw = AdaptiveWeights::from_design(&x, &g, 0.0, 0.0);
        assert!(aw.v.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        assert!(aw.w.iter().all(|&w| (w - 1.0).abs() < 1e-12));
    }

    #[test]
    fn larger_gamma_spreads_weights() {
        let mut rng = Rng::new(15);
        let x = Matrix::from_fn(50, 10, |_, _| rng.gauss());
        let g = Groups::from_sizes(&[5, 5]);
        let a_small = AdaptiveWeights::from_design(&x, &g, 0.1, 0.1);
        let a_big = AdaptiveWeights::from_design(&x, &g, 2.0, 2.0);
        let spread = |v: &[f64]| {
            let mx = v.iter().cloned().fold(f64::MIN, f64::max);
            let mn = v.iter().cloned().fold(f64::MAX, f64::min);
            mx / mn
        };
        assert!(spread(&a_big.v) > spread(&a_small.v));
    }
}
