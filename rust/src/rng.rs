//! Pseudo-random number generation substrate.
//!
//! No `rand` crate is available in this offline environment, so we implement
//! the pieces the paper's experiments need: a counter-based 64-bit PCG
//! (`PCG-XSH-RR` variant, O'Neill 2014), uniform and Gaussian sampling
//! (polar Box–Muller with caching), permutations for k-fold CV, and
//! convenience constructors for the synthetic designs of §3.1.

/// A 64-bit-state PCG-XSH-RR 32 generator, extended to 64-bit output by
/// pairing draws. Deterministic, seedable, and fast enough that sampling is
/// never the experiment bottleneck.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Gaussian from the last polar Box–Muller round trip.
    gauss_cache: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (the stream id is derived from the seed as well).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (seed.wrapping_mul(0x9E3779B97F4A7C15) << 1) | 1,
            gauss_cache: None,
        };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream; used to give each simulation
    /// repeat / CV fold its own generator.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` by rejection (unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via polar Box–Muller (caches the paired draw).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_cache.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_cache = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Vector of i.i.d. standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`; used by the CV fold splitter.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(13);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
