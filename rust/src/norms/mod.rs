//! Norms used by the sparse-group lasso and its screening rules.
//!
//! * [`epsilon`] — the ε-norm of Burdakov (1988): the implicit norm whose
//!   *dual* is `(1−ε)‖·‖₁ + ε‖·‖₂`. The DFR group screening rule evaluates
//!   the ε-norm of per-group gradients (Eq. 5/7 of the paper).
//! * SGL / aSGL norms and the SGL dual norm (Eqs. 2–4, 18–19).
//! * Soft thresholding, used by the proximal operators and KKT checks.

pub mod epsilon;

pub use epsilon::epsilon_norm;

/// Soft-thresholding operator `S(a, b) = sign(a)·(|a| − b)₊`.
#[inline]
pub fn soft_threshold(a: f64, b: f64) -> f64 {
    if a > b {
        a - b
    } else if a < -b {
        a + b
    } else {
        0.0
    }
}

/// Vectorized soft threshold with per-element thresholds.
pub fn soft_threshold_vec(x: &[f64], thresh: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), thresh.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = soft_threshold(x[i], thresh[i]);
    }
}

/// The *dual* of the ε-norm: `‖x‖*_ε = (1−ε)‖x‖₁ + ε‖x‖₂`.
///
/// This is the form in which the SGL norm decomposes per group (Eq. 3 via
/// Eq. 24): `‖β‖_sgl = Σ_g τ_g ‖β^(g)‖*_{ε_g}`.
pub fn dual_epsilon_norm(x: &[f64], eps: f64) -> f64 {
    let l1: f64 = x.iter().map(|v| v.abs()).sum();
    let l2: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    (1.0 - eps) * l1 + eps * l2
}

/// SGL group constant `τ_g = α + (1−α)√p_g`.
#[inline]
pub fn tau_g(alpha: f64, p_g: usize) -> f64 {
    alpha + (1.0 - alpha) * (p_g as f64).sqrt()
}

/// SGL group ε `ε_g = (τ_g − α)/τ_g = (1−α)√p_g / τ_g`.
#[inline]
pub fn eps_g(alpha: f64, p_g: usize) -> f64 {
    let tau = tau_g(alpha, p_g);
    (tau - alpha) / tau
}

/// The SGL norm `α‖β‖₁ + (1−α)Σ_g √p_g ‖β^(g)‖₂` (Eq. 2).
pub fn sgl_norm(beta: &[f64], groups: &crate::groups::Groups, alpha: f64) -> f64 {
    let l1: f64 = beta.iter().map(|v| v.abs()).sum();
    let mut gl = 0.0;
    for (g, r) in groups.iter() {
        let b = &beta[r];
        let n2 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        gl += (groups.size(g) as f64).sqrt() * n2;
    }
    alpha * l1 + (1.0 - alpha) * gl
}

/// The aSGL norm `αΣᵢ vᵢ|βᵢ| + (1−α)Σ_g w_g √p_g ‖β^(g)‖₂` (Eq. 18).
pub fn asgl_norm(
    beta: &[f64],
    groups: &crate::groups::Groups,
    alpha: f64,
    v: &[f64],
    w: &[f64],
) -> f64 {
    assert_eq!(v.len(), beta.len());
    assert_eq!(w.len(), groups.m());
    let l1: f64 = beta.iter().zip(v).map(|(b, vi)| vi * b.abs()).sum();
    let mut gl = 0.0;
    for (g, r) in groups.iter() {
        let b = &beta[r];
        let n2 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        gl += w[g] * (groups.size(g) as f64).sqrt() * n2;
    }
    alpha * l1 + (1.0 - alpha) * gl
}

/// The SGL *dual* norm of a full-length vector (Eq. 4):
/// `‖ξ‖*_sgl = max_g τ_g⁻¹ ‖ξ^(g)‖_{ε_g}`.
///
/// Used for the path start `λ₁ = ‖∇f(0)‖*_sgl` and by GAP safe's dual
/// scaling.
pub fn dual_sgl_norm(xi: &[f64], groups: &crate::groups::Groups, alpha: f64) -> f64 {
    let mut best: f64 = 0.0;
    for (g, r) in groups.iter() {
        let p_g = groups.size(g);
        let tau = tau_g(alpha, p_g);
        let eps = eps_g(alpha, p_g);
        let v = epsilon_norm(&xi[r], eps);
        best = best.max(v / tau);
    }
    best
}

/// aSGL group constant `γ_g` (Eq. 19) evaluated at a coefficient block.
///
/// `γ_g = α‖v^(g)‖₁ − α·(Σ_{i≠j} v_j|β_i|)/‖β^(g)‖₁ + (1−α)w_g√p_g`.
///
/// Using `Σ_{i,j≠i} v_j|β_i| = Σ_i |β_i|(V − v_i)` with `V = Σ_j v_j`, the
/// middle term is `V − (Σ v_i|β_i|)/‖β‖₁`. For an inactive block the
/// β → 0 limit (Appendix B.1.1) gives `α·(p_g−1)/p_g·V`.
pub fn gamma_g(beta_g: &[f64], v_g: &[f64], w_g: f64, alpha: f64) -> f64 {
    let p_g = beta_g.len();
    assert_eq!(v_g.len(), p_g);
    let vsum: f64 = v_g.iter().sum();
    let l1: f64 = beta_g.iter().map(|b| b.abs()).sum();
    let group_term = (1.0 - alpha) * w_g * (p_g as f64).sqrt();
    if l1 <= 0.0 || p_g == 1 {
        // L'Hôpital limit: middle term → α(p_g−1)/p_g · Σv.
        let mid = vsum * (p_g as f64 - 1.0) / p_g as f64;
        return alpha * vsum - alpha * mid + group_term;
    }
    let weighted: f64 = beta_g.iter().zip(v_g).map(|(b, vi)| vi * b.abs()).sum();
    let mid = vsum - weighted / l1;
    alpha * vsum - alpha * mid + group_term
}

/// aSGL group ε: `ε'_g = (1−α)w_g√p_g / γ_g`, clamped into `[0, 1]`.
pub fn eps_g_adaptive(gamma: f64, w_g: f64, alpha: f64, p_g: usize) -> f64 {
    if gamma <= 0.0 {
        return 1.0;
    }
    ((1.0 - alpha) * w_g * (p_g as f64).sqrt() / gamma).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 0.0), 1.0);
    }

    #[test]
    fn sgl_norm_interpolates_lasso_group_lasso() {
        let g = Groups::from_sizes(&[2, 2]);
        let beta = [3.0, -4.0, 0.0, 1.0];
        // α = 1 → pure ℓ1.
        assert!((sgl_norm(&beta, &g, 1.0) - 8.0).abs() < 1e-12);
        // α = 0 → Σ √p_g ‖β_g‖₂ = √2·5 + √2·1.
        let expect = (2f64).sqrt() * 5.0 + (2f64).sqrt() * 1.0;
        assert!((sgl_norm(&beta, &g, 0.0) - expect).abs() < 1e-12);
    }

    #[test]
    fn asgl_reduces_to_sgl_with_unit_weights() {
        let g = Groups::from_sizes(&[3, 2]);
        let beta = [1.0, -2.0, 0.5, 0.0, 3.0];
        let v = vec![1.0; 5];
        let w = vec![1.0; 2];
        let a = asgl_norm(&beta, &g, 0.7, &v, &w);
        let s = sgl_norm(&beta, &g, 0.7);
        assert!((a - s).abs() < 1e-12);
    }

    #[test]
    fn gamma_reduces_to_tau_with_unit_weights() {
        // Appendix B.1.1: v ≡ 1, w ≡ 1 ⇒ γ_g = τ_g for any β.
        let alpha = 0.95;
        for beta_g in [vec![1.0, -2.0, 0.3], vec![0.0, 0.0, 0.0]] {
            let v_g = vec![1.0; 3];
            let gam = gamma_g(&beta_g, &v_g, 1.0, alpha);
            let tau = tau_g(alpha, 3);
            assert!((gam - tau).abs() < 1e-12, "gamma {gam} tau {tau}");
        }
    }

    #[test]
    fn eps_adaptive_reduces_to_eps() {
        let alpha = 0.6;
        let p_g = 5;
        let gam = gamma_g(&[0.0; 5], &[1.0; 5], 1.0, alpha);
        let e = eps_g_adaptive(gam, 1.0, alpha, p_g);
        assert!((e - eps_g(alpha, p_g)).abs() < 1e-12);
    }

    #[test]
    fn dual_sgl_norm_is_dual_of_sgl_norm() {
        // Empirically check ‖ξ‖* ≥ ξᵀx / ‖x‖_sgl for random x, with equality
        // approached by maximizing over many random candidates.
        let mut rng = crate::rng::Rng::new(8);
        let g = Groups::from_sizes(&[3, 4, 2]);
        let xi: Vec<f64> = rng.gauss_vec(9);
        let dual = dual_sgl_norm(&xi, &g, 0.95);
        let mut best = 0.0f64;
        for _ in 0..2000 {
            let x: Vec<f64> = rng.gauss_vec(9);
            let num: f64 = xi.iter().zip(&x).map(|(a, b)| a * b).sum();
            let den = sgl_norm(&x, &g, 0.95);
            best = best.max(num.abs() / den);
        }
        assert!(dual >= best - 1e-9, "dual {dual} < sampled sup {best}");
        assert!(best > 0.6 * dual, "sampled sup too far below dual: {best} vs {dual}");
    }
}
