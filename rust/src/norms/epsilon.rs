//! The ε-norm of Burdakov (1988).
//!
//! `‖x‖_ε` is the unique non-negative solution `q` of
//!
//! ```text
//!     Σᵢ (|xᵢ| − (1−ε)q)₊²  =  (εq)² ,          ε ∈ (0, 1],
//! ```
//!
//! with the limits `ε → 0 ⇒ ‖x‖∞` and `ε = 1 ⇒ ‖x‖₂`. Its dual is the
//! interpolation `(1−ε)‖·‖₁ + ε‖·‖₂`, which is exactly how the SGL norm
//! decomposes per group — hence the DFR group rule evaluates ε-norms of
//! group gradients.
//!
//! The solver is exact: sort `|x|` descending, locate the support size `k`
//! (the entries with `|xᵢ| > (1−ε)q`), and solve the per-interval quadratic
//! `(k a² − ε²)q² − 2a S₁q + S₂ = 0` with `a = 1−ε` and prefix sums
//! `S₁, S₂`. A bisection fallback guards against floating-point edge cases;
//! property tests cross-validate the two.

/// Left-hand side minus right-hand side of the defining equation:
/// `F(q) = Σ (|xᵢ|−(1−ε)q)₊² − (εq)²`. Strictly decreasing in `q ≥ 0`
/// (for `x ≠ 0`), from `‖x‖₂² > 0` down to `−∞`.
fn f_eps(abs_sorted: &[f64], eps: f64, q: f64) -> f64 {
    let a = 1.0 - eps;
    let mut s = 0.0;
    for &d in abs_sorted {
        let t = d - a * q;
        if t <= 0.0 {
            break; // sorted descending: all further terms are clipped
        }
        s += t * t;
    }
    s - (eps * q) * (eps * q)
}

/// Exact ε-norm. `eps` outside `[0,1]` is clamped. `O(p log p)`.
pub fn epsilon_norm(x: &[f64], eps: f64) -> f64 {
    let eps = eps.clamp(0.0, 1.0);
    if x.is_empty() {
        return 0.0;
    }
    let mut d: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    // total_cmp: a NaN magnitude sorts low instead of panicking the sort;
    // the NaN then propagates through the norm arithmetic as NaN, which
    // the solver guardrails classify as divergence.
    d.sort_unstable_by(|a, b| b.total_cmp(a));
    if d[0] == 0.0 {
        return 0.0;
    }
    if eps == 0.0 {
        return d[0]; // ℓ∞ limit
    }
    if eps == 1.0 {
        return d.iter().map(|v| v * v).sum::<f64>().sqrt(); // ℓ₂
    }
    let a = 1.0 - eps;
    // Prefix sums over sorted magnitudes.
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for k in 1..=d.len() {
        let dk = d[k - 1];
        s1 += dk;
        s2 += dk * dk;
        // Solve (k a² − ε²) q² − 2 a S₁ q + S₂ = 0 on the interval where the
        // support is exactly the top-k: a·q ∈ [d_{k+1}, d_k) (d_{p+1} = 0).
        let lo_bound = if k == d.len() { 0.0 } else { d[k] }; // a·q ≥ this
        let aa = (k as f64) * a * a - eps * eps;
        let roots = if aa.abs() < 1e-14 * (k as f64) {
            // Degenerate to linear: −2aS₁q + S₂ = 0.
            vec![s2 / (2.0 * a * s1)]
        } else {
            let disc = a * a * s1 * s1 - aa * s2;
            if disc < 0.0 {
                continue;
            }
            let sq = disc.sqrt();
            vec![(a * s1 + sq) / aa, (a * s1 - sq) / aa]
        };
        for q in roots {
            if !(q.is_finite() && q > 0.0) {
                continue;
            }
            let aq = a * q;
            let tol = 1e-10 * (1.0 + d[0]);
            if aq < dk + tol && aq >= lo_bound - tol {
                // Polish with one bisection-safe Newton step via the global
                // F to absorb the interval tolerance.
                return polish(&d, eps, q);
            }
        }
    }
    // Fallback: bisection on the strictly decreasing F. Bracket:
    // F(‖x‖∞/(1)) .. F(‖x‖₂/ε) spans the root.
    bisect(&d, eps)
}

fn polish(d: &[f64], eps: f64, q0: f64) -> f64 {
    // A couple of Newton steps on F; F' = −2a Σ(dᵢ−aq)₊ − 2ε² q.
    let a = 1.0 - eps;
    let mut q = q0;
    for _ in 0..3 {
        let f = f_eps(d, eps, q);
        let mut grad = -2.0 * eps * eps * q;
        for &di in d {
            let t = di - a * q;
            if t <= 0.0 {
                break;
            }
            grad -= 2.0 * a * t;
        }
        if grad == 0.0 {
            break;
        }
        let q_new = q - f / grad;
        if !q_new.is_finite() || q_new <= 0.0 {
            break;
        }
        if (q_new - q).abs() <= 1e-15 * q.abs() {
            q = q_new;
            break;
        }
        q = q_new;
    }
    q
}

fn bisect(d: &[f64], eps: f64) -> f64 {
    let l2: f64 = d.iter().map(|v| v * v).sum::<f64>().sqrt();
    let mut lo = 0.0;
    let mut hi = l2 / eps; // F(hi) ≤ ‖x‖₂² − ε²·hi² = 0 ⇒ root ≤ hi
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if f_eps(d, eps, mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= 1e-15 * hi {
            break;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn check_defining_equation(x: &[f64], eps: f64, q: f64) {
        let a = 1.0 - eps;
        let lhs: f64 = x.iter().map(|v| (v.abs() - a * q).max(0.0).powi(2)).sum();
        let rhs = (eps * q).powi(2);
        let scale = lhs.max(rhs).max(1e-12);
        assert!(
            ((lhs - rhs) / scale).abs() < 1e-8,
            "defining equation violated: lhs={lhs} rhs={rhs} q={q} eps={eps}"
        );
    }

    #[test]
    fn limits_linf_and_l2() {
        let x = [3.0, -1.0, 2.0];
        assert_eq!(epsilon_norm(&x, 0.0), 3.0);
        let l2 = (9.0f64 + 1.0 + 4.0).sqrt();
        assert!((epsilon_norm(&x, 1.0) - l2).abs() < 1e-12);
    }

    #[test]
    fn zero_vector_is_zero() {
        assert_eq!(epsilon_norm(&[0.0, 0.0], 0.5), 0.0);
        assert_eq!(epsilon_norm(&[], 0.5), 0.0);
    }

    #[test]
    fn singleton_any_eps_is_abs() {
        // p=1: (|x|−(1−ε)q)₊² = ε²q² ⇒ |x|−(1−ε)q = εq ⇒ q = |x|.
        for eps in [0.05, 0.3, 0.77, 0.999] {
            let q = epsilon_norm(&[-2.5], eps);
            assert!((q - 2.5).abs() < 1e-9, "eps={eps} q={q}");
        }
    }

    #[test]
    fn satisfies_defining_equation_random() {
        let mut rng = Rng::new(21);
        for trial in 0..200 {
            let p = 1 + rng.below(40);
            let x: Vec<f64> = (0..p).map(|_| rng.normal(0.0, 2.0)).collect();
            let eps = rng.uniform_range(0.01, 0.99);
            let q = epsilon_norm(&x, eps);
            if x.iter().all(|v| *v == 0.0) {
                assert_eq!(q, 0.0);
                continue;
            }
            assert!(q > 0.0, "trial {trial}");
            check_defining_equation(&x, eps, q);
        }
    }

    #[test]
    fn matches_bisection_fallback() {
        let mut rng = Rng::new(33);
        for _ in 0..100 {
            let p = 1 + rng.below(25);
            let x: Vec<f64> = (0..p).map(|_| rng.normal(0.0, 1.0)).collect();
            let eps = rng.uniform_range(0.02, 0.98);
            let mut d: Vec<f64> = x.iter().map(|v| v.abs()).collect();
            d.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            let exact = epsilon_norm(&x, eps);
            let bis = super::bisect(&d, eps);
            assert!(
                (exact - bis).abs() < 1e-7 * (1.0 + bis),
                "exact {exact} vs bisect {bis} (eps {eps})"
            );
        }
    }

    #[test]
    fn monotone_between_linf_and_l2() {
        // ‖x‖∞ ≤ ‖x‖_ε ≤ ‖x‖₂ and increasing in ε.
        let mut rng = Rng::new(5);
        let x: Vec<f64> = rng.gauss_vec(12);
        let linf = x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let l2 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut prev = linf;
        for i in 1..20 {
            let eps = i as f64 / 20.0;
            let q = epsilon_norm(&x, eps);
            assert!(q >= prev - 1e-9, "not monotone at eps={eps}");
            assert!(q >= linf - 1e-9 && q <= l2 + 1e-9);
            prev = q;
        }
    }

    #[test]
    fn homogeneous_and_triangle_inequality() {
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let p = 2 + rng.below(10);
            let x: Vec<f64> = rng.gauss_vec(p);
            let y: Vec<f64> = rng.gauss_vec(p);
            let eps = rng.uniform_range(0.05, 0.95);
            let c = rng.uniform_range(0.1, 5.0);
            let nx = epsilon_norm(&x, eps);
            let cx: Vec<f64> = x.iter().map(|v| c * v).collect();
            assert!((epsilon_norm(&cx, eps) - c * nx).abs() < 1e-7 * (1.0 + c * nx));
            let xy: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
            let ny = epsilon_norm(&y, eps);
            assert!(epsilon_norm(&xy, eps) <= nx + ny + 1e-7);
        }
    }

    #[test]
    fn duality_with_l1_l2_interpolation() {
        // ⟨x, z⟩ ≤ ‖x‖_ε · ((1−ε)‖z‖₁ + ε‖z‖₂) for all z (dual pair).
        let mut rng = Rng::new(7);
        let x: Vec<f64> = rng.gauss_vec(8);
        let eps = 0.35;
        let nx = epsilon_norm(&x, eps);
        for _ in 0..500 {
            let z: Vec<f64> = rng.gauss_vec(8);
            let ip: f64 = x.iter().zip(&z).map(|(a, b)| a * b).sum();
            let dz = crate::norms::dual_epsilon_norm(&z, eps);
            assert!(ip.abs() <= nx * dz + 1e-9);
        }
    }
}
