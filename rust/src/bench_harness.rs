//! Benchmark harness (no `criterion` offline).
//!
//! Paper-style experiment benches need *paired repeated* measurements
//! (screen vs no-screen on the same data draw) with mean ± stderr rows, not
//! criterion's statistical micro-timing, so the harness provides:
//!
//! * [`time_once`] / [`time_stat`] — wall-clock timing with warmup,
//! * [`BenchTable`] — accumulates rows keyed by (method, setting) and
//!   renders the paper-style table plus a CSV **and a machine-readable
//!   `BENCH_<name>.json`** (metric/setting/method with mean, stderr,
//!   median, count) under `target/bench_results/`, so the perf trajectory
//!   across PRs is diffable.

use crate::metrics::Accumulator;
use std::collections::BTreeMap;
use std::time::Instant;

/// Time a closure once, returning (seconds, result).
pub fn time_once<R>(f: impl FnOnce() -> R) -> (f64, R) {
    let t = Instant::now();
    let r = f();
    (t.elapsed().as_secs_f64(), r)
}

/// Time a closure `reps` times after `warmup` runs; returns an accumulator
/// of the per-run seconds.
pub fn time_stat(warmup: usize, reps: usize, mut f: impl FnMut()) -> Accumulator {
    for _ in 0..warmup {
        f();
    }
    let mut acc = Accumulator::new();
    for _ in 0..reps {
        let t = Instant::now();
        f();
        acc.push(t.elapsed().as_secs_f64());
    }
    acc
}

/// A (metric × method × setting) results table.
#[derive(Default)]
pub struct BenchTable {
    title: String,
    /// (setting, method) → accumulator, per metric name.
    metrics: BTreeMap<String, BTreeMap<(String, String), Accumulator>>,
    settings_order: Vec<String>,
    methods_order: Vec<String>,
}

impl BenchTable {
    pub fn new(title: &str) -> Self {
        BenchTable { title: title.to_string(), ..Default::default() }
    }

    /// Record one observation.
    pub fn push(&mut self, metric: &str, setting: &str, method: &str, value: f64) {
        if !self.settings_order.iter().any(|s| s == setting) {
            self.settings_order.push(setting.to_string());
        }
        if !self.methods_order.iter().any(|m| m == method) {
            self.methods_order.push(method.to_string());
        }
        self.metrics
            .entry(metric.to_string())
            .or_default()
            .entry((setting.to_string(), method.to_string()))
            .or_default()
            .push(value);
    }

    /// Render all metrics as markdown-ish tables (what the bench binaries
    /// print — rows match the paper's tables/figure series).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        for (metric, cells) in &self.metrics {
            out.push_str(&format!("\n### {metric}\n\n"));
            out.push_str("| setting |");
            for m in &self.methods_order {
                out.push_str(&format!(" {m} |"));
            }
            out.push('\n');
            out.push_str("|---|");
            for _ in &self.methods_order {
                out.push_str("---|");
            }
            out.push('\n');
            for s in &self.settings_order {
                out.push_str(&format!("| {s} |"));
                for m in &self.methods_order {
                    match cells.get(&(s.clone(), m.clone())) {
                        Some(acc) => out.push_str(&format!(
                            " {} ± {} |",
                            fmt_sig(acc.mean()),
                            fmt_sig(acc.stderr())
                        )),
                        None => out.push_str(" – |"),
                    }
                }
                out.push('\n');
            }
        }
        out
    }

    /// Write a tidy CSV (metric,setting,method,mean,stderr,count).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = String::from("metric,setting,method,mean,stderr,count\n");
        for (metric, cells) in &self.metrics {
            for ((setting, method), acc) in cells {
                s.push_str(&format!(
                    "{metric},{setting},{method},{},{},{}\n",
                    acc.mean(),
                    acc.stderr(),
                    acc.count()
                ));
            }
        }
        std::fs::write(path, s)
    }

    /// Write a machine-readable JSON dump: one row object per
    /// (metric, setting, method) cell with mean, stderr, median and count.
    /// Built on [`crate::report::Json`] — the crate's one JSON emitter
    /// (non-finite values render as null).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::report::Json;
        let rows: Vec<Json> = self
            .metrics
            .iter()
            .flat_map(|(metric, cells)| {
                cells.iter().map(move |((setting, method), acc)| {
                    Json::obj(vec![
                        ("metric", Json::Str(metric.clone())),
                        ("setting", Json::Str(setting.clone())),
                        ("method", Json::Str(method.clone())),
                        ("mean", Json::Num(acc.mean())),
                        ("stderr", Json::Num(acc.stderr())),
                        ("median", Json::Num(acc.median())),
                        ("count", Json::Num(acc.count() as f64)),
                    ])
                })
            })
            .collect();
        let doc = Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("rows", Json::Arr(rows)),
        ]);
        crate::report::write_file(path, &(doc.render() + "\n"))
    }

    /// Print to stdout and persist the CSV plus the `BENCH_<name>.json`
    /// dump under `target/bench_results/`.
    pub fn finish(&self, csv_name: &str) {
        println!("{}", self.render());
        let path = format!("target/bench_results/{csv_name}.csv");
        if let Err(e) = self.write_csv(&path) {
            eprintln!("warning: could not write {path}: {e}");
        } else {
            println!("[csv] {path}");
        }
        let jpath = format!("target/bench_results/BENCH_{csv_name}.json");
        if let Err(e) = self.write_json(&jpath) {
            eprintln!("warning: could not write {jpath}: {e}");
        } else {
            println!("[json] {jpath}");
        }
    }
}

/// Format with 4 significant digits, switching to scientific notation for
/// very small/large magnitudes (µs-scale timings would render as 0.0000
/// in fixed point).
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() < 1e-3 || x.abs() >= 1e5 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

/// Parse simple `--flag value` style bench arguments (benches run with
/// `harness = false` and receive raw argv).
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    pub fn from_env() -> Self {
        BenchArgs { args: std::env::args().collect() }
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == flag)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> usize {
        self.get(flag).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> f64 {
        self.get(flag).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }
}

/// Quick-mode scaling: `cargo bench` runs every table/figure; setting
/// `DFR_BENCH_FULL=1` switches from smoke-scale to paper-scale workloads.
pub fn full_scale() -> bool {
    std::env::var("DFR_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_cells() {
        let mut t = BenchTable::new("demo");
        t.push("improvement factor", "p=100", "DFR-SGL", 5.0);
        t.push("improvement factor", "p=100", "DFR-SGL", 7.0);
        t.push("improvement factor", "p=100", "sparsegl", 2.0);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("DFR-SGL"));
        assert!(s.contains("6.0000")); // mean of 5 and 7
    }

    #[test]
    fn csv_round_trip() {
        let mut t = BenchTable::new("demo");
        t.push("m", "s", "x", 1.0);
        let path = "target/bench_results/_test_demo.csv";
        t.write_csv(path).unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.starts_with("metric,setting,method"));
        assert!(content.contains("m,s,x,1"));
    }

    #[test]
    fn json_dump_has_all_cells_and_median() {
        let mut t = BenchTable::new("demo \"quoted\"");
        t.push("seconds", "200x1000", "DFR-SGL", 1.0);
        t.push("seconds", "200x1000", "DFR-SGL", 3.0);
        t.push("seconds", "200x1000", "DFR-SGL", 100.0);
        let path = "target/bench_results/BENCH__test_demo.json";
        t.write_json(path).unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.contains("\"metric\":\"seconds\""));
        assert!(content.contains("\"setting\":\"200x1000\""));
        assert!(content.contains("\"method\":\"DFR-SGL\""));
        assert!(content.contains("\"median\":3"));
        assert!(content.contains("\"count\":3"));
        assert!(content.contains("demo \\\"quoted\\\""));
    }

    #[test]
    fn time_stat_counts_reps() {
        let acc = time_stat(1, 5, || {
            std::hint::black_box(0u64);
        });
        assert_eq!(acc.count(), 5);
    }
}
