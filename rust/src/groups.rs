//! Grouping structure for grouped-penalty models.
//!
//! The paper's variables sit in disjoint groups `G_1, …, G_m` of sizes
//! `p_1, …, p_m`. We store groups contiguously (variable `i` belongs to
//! group `gid[i]`), which matches how the synthetic generator and all six
//! real-data surrogates lay out features, and gives O(1) slicing of
//! per-group coefficient blocks.

/// Disjoint contiguous grouping of `p` variables into `m` groups.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Groups {
    /// Start offset of each group; `starts[m] == p` sentinel included.
    starts: Vec<usize>,
    /// Group id of each variable.
    gid: Vec<usize>,
}

impl Groups {
    /// Build from group sizes.
    pub fn from_sizes(sizes: &[usize]) -> Self {
        assert!(!sizes.is_empty(), "at least one group required");
        assert!(sizes.iter().all(|&s| s > 0), "empty groups are not allowed");
        let mut starts = Vec::with_capacity(sizes.len() + 1);
        let mut gid = Vec::new();
        let mut off = 0;
        for (g, &s) in sizes.iter().enumerate() {
            starts.push(off);
            gid.extend(std::iter::repeat(g).take(s));
            off += s;
        }
        starts.push(off);
        Groups { starts, gid }
    }

    /// `p` singleton groups (the lasso limit).
    pub fn singletons(p: usize) -> Self {
        Groups::from_sizes(&vec![1; p])
    }

    /// Even groups of the given size (padding the last if `p % size != 0`).
    pub fn even(p: usize, size: usize) -> Self {
        assert!(size > 0 && p > 0);
        let full = p / size;
        let rem = p % size;
        let mut sizes = vec![size; full];
        if rem > 0 {
            sizes.push(rem);
        }
        Groups::from_sizes(&sizes)
    }

    /// Number of groups.
    #[inline]
    pub fn m(&self) -> usize {
        self.starts.len() - 1
    }

    /// Total number of variables.
    #[inline]
    pub fn p(&self) -> usize {
        self.gid.len()
    }

    /// Size `p_g` of group `g`.
    #[inline]
    pub fn size(&self, g: usize) -> usize {
        self.starts[g + 1] - self.starts[g]
    }

    /// Index range of group `g`.
    #[inline]
    pub fn range(&self, g: usize) -> std::ops::Range<usize> {
        self.starts[g]..self.starts[g + 1]
    }

    /// Start offset of every group plus the `p` sentinel — the group-block
    /// tiling `[offsets[g], offsets[g+1])` that block-coordinate solvers
    /// and the reduced-design cache agree on.
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.starts
    }

    /// Group id of variable `i`.
    #[inline]
    pub fn group_of(&self, i: usize) -> usize {
        self.gid[i]
    }

    /// Slice the per-variable vector `x` to group `g`'s block.
    #[inline]
    pub fn slice<'a>(&self, x: &'a [f64], g: usize) -> &'a [f64] {
        &x[self.range(g)]
    }

    /// Mutable block of group `g`.
    #[inline]
    pub fn slice_mut<'a>(&self, x: &'a mut [f64], g: usize) -> &'a mut [f64] {
        &mut x[self.range(g)]
    }

    /// Iterator over `(g, range)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (usize, std::ops::Range<usize>)> + '_ {
        (0..self.m()).map(move |g| (g, self.range(g)))
    }

    /// `√p_g` for every group — the SGL group weights.
    pub fn sqrt_sizes(&self) -> Vec<f64> {
        (0..self.m()).map(|g| (self.size(g) as f64).sqrt()).collect()
    }

    /// Group sizes.
    pub fn sizes(&self) -> Vec<usize> {
        (0..self.m()).map(|g| self.size(g)).collect()
    }

    /// Restrict the grouping to a sorted subset of variables, renumbering
    /// groups that survive. Returns the reduced grouping plus, for each
    /// reduced group, its original group id. Used to carry the penalty
    /// structure onto the screening-reduced design.
    pub fn restrict(&self, vars: &[usize]) -> (Groups, Vec<usize>) {
        assert!(vars.windows(2).all(|w| w[0] < w[1]), "vars must be sorted unique");
        let mut sizes: Vec<usize> = Vec::new();
        let mut orig: Vec<usize> = Vec::new();
        for &v in vars {
            let g = self.gid[v];
            match sizes.last_mut() {
                Some(last) if orig.last() == Some(&g) => *last += 1,
                _ => {
                    orig.push(g);
                    sizes.push(1);
                }
            }
        }
        if sizes.is_empty() {
            // Degenerate but legal: empty optimization set. Represent as a
            // single empty-free placeholder group of size 1 never used.
            return (Groups::from_sizes(&[1]), vec![0]);
        }
        (Groups::from_sizes(&sizes), orig)
    }

    /// Generate uneven group sizes in `[lo, hi]` that sum to exactly `p`
    /// (the paper's "m uneven groups of sizes in [3, 100]"). Sizes are drawn
    /// uniformly and the last group is clamped to make the total exact.
    pub fn random_sizes(p: usize, lo: usize, hi: usize, rng: &mut crate::rng::Rng) -> Vec<usize> {
        assert!(lo >= 1 && hi >= lo && p >= lo);
        let mut sizes = Vec::new();
        let mut total = 0;
        while total < p {
            let remaining = p - total;
            if remaining <= hi {
                // Close out, splitting if the remainder is below `lo`.
                match sizes.last_mut() {
                    // Merge the remainder into the previous group.
                    Some(last) if remaining < lo => *last += remaining,
                    _ => sizes.push(remaining),
                }
                total = p;
            } else {
                let s = lo + rng.below(hi - lo + 1);
                sizes.push(s);
                total += s;
            }
        }
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sizes_layout() {
        let g = Groups::from_sizes(&[2, 3, 1]);
        assert_eq!(g.m(), 3);
        assert_eq!(g.p(), 6);
        assert_eq!(g.range(1), 2..5);
        assert_eq!(g.group_of(4), 1);
        assert_eq!(g.size(2), 1);
    }

    #[test]
    fn even_handles_remainder() {
        let g = Groups::even(10, 4);
        assert_eq!(g.sizes(), vec![4, 4, 2]);
    }

    #[test]
    fn sqrt_sizes_match() {
        let g = Groups::from_sizes(&[4, 9]);
        assert_eq!(g.sqrt_sizes(), vec![2.0, 3.0]);
    }

    #[test]
    fn restrict_renumbers_and_tracks_origin() {
        let g = Groups::from_sizes(&[3, 2, 4]); // vars 0-2 | 3-4 | 5-8
        let (r, orig) = g.restrict(&[1, 2, 5, 8]);
        assert_eq!(r.sizes(), vec![2, 2]);
        assert_eq!(orig, vec![0, 2]);
    }

    #[test]
    fn restrict_empty_is_safe() {
        let g = Groups::from_sizes(&[3]);
        let (r, _) = g.restrict(&[]);
        assert_eq!(r.m(), 1);
    }

    #[test]
    fn random_sizes_sum_to_p_and_bounded() {
        let mut rng = crate::rng::Rng::new(42);
        for _ in 0..20 {
            let sizes = Groups::random_sizes(1000, 3, 100, &mut rng);
            assert_eq!(sizes.iter().sum::<usize>(), 1000);
            // All but possibly merged-last are within [3, 100+3).
            for &s in &sizes {
                assert!(s >= 3 && s <= 103, "size {s}");
            }
        }
    }

    #[test]
    fn singleton_groups() {
        let g = Groups::singletons(4);
        assert_eq!(g.m(), 4);
        assert!((0..4).all(|i| g.size(i) == 1));
    }
}
