//! Command-line argument parsing substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, and positional arguments, with typed
//! accessors and a generated usage string. Used by the `dfr` launcher and
//! shared by the examples.

use std::collections::BTreeMap;

/// Parsed argv.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub program: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declared option for usage rendering.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

impl Args {
    /// Parse a raw argv (including program name).
    pub fn parse(argv: impl IntoIterator<Item = String>, specs: &[OptSpec]) -> Result<Args, String> {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        let takes_value: BTreeMap<&str, bool> =
            specs.iter().map(|s| (s.name, s.takes_value)).collect();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                match takes_value.get(name) {
                    Some(true) => {
                        let v = it
                            .next()
                            .ok_or_else(|| format!("--{name} expects a value"))?;
                        args.options.insert(name.to_string(), v);
                    }
                    Some(false) => args.flags.push(name.to_string()),
                    None => return Err(format!("unknown option --{name}")),
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env(specs: &[OptSpec]) -> Result<Args, String> {
        Args::parse(std::env::args(), specs)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected number, got `{v}`")),
        }
    }

    /// Typed accessor for seed-style options (avoids the lossy
    /// `usize_or(..) as u64` dance at call sites).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got `{v}`")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Render a usage block for `--help`.
pub fn usage(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUSAGE:\n    {program} [OPTIONS]\n\nOPTIONS:\n");
    for spec in specs {
        let val = if spec.takes_value { " <value>" } else { "" };
        let def = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("    --{}{val}\n        {}{def}\n", spec.name, spec.help));
    }
    s
}

/// Parse a comma-separated list of numbers (`"0.5,0.9,0.95"`), as used by
/// the `dfr cv --alphas` grid flag. Empty entries are skipped; NaN and ±∞
/// (which `f64::parse` accepts) are rejected here so they never reach a
/// solver.
pub fn parse_f64_list(s: &str) -> Result<Vec<f64>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            let v: f64 =
                t.parse().map_err(|_| format!("expected number, got `{t}`"))?;
            if !v.is_finite() {
                return Err(format!("expected finite number, got `{t}`"));
            }
            Ok(v)
        })
        .collect()
}

/// Parse a comma-separated γ grid for `dfr cv --gammas`. Each entry is
/// `none` (plain SGL), a single number `g` (meaning `γ₁ = γ₂ = g`), or a
/// pair `g1:g2`. γ values must be finite and non-negative (adaptive
/// weights `1/|β|^γ` make no sense otherwise).
pub fn parse_gamma_list(s: &str) -> Result<Vec<Option<(f64, f64)>>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            if t.eq_ignore_ascii_case("none") {
                return Ok(None);
            }
            let parse = |v: &str| {
                let g: f64 =
                    v.trim().parse().map_err(|_| format!("bad γ entry `{t}`"))?;
                if !g.is_finite() || g < 0.0 {
                    return Err(format!("γ entry `{t}` must be finite and ≥ 0"));
                }
                Ok(g)
            };
            match t.split_once(':') {
                Some((a, b)) => Ok(Some((parse(a)?, parse(b)?))),
                None => {
                    let g = parse(t)?;
                    Ok(Some((g, g)))
                }
            }
        })
        .collect()
}

/// Parse a screening-rule name as used across the CLI / benches.
pub fn parse_rule(name: &str) -> Result<crate::screen::RuleKind, String> {
    use crate::screen::RuleKind::*;
    Ok(match name.to_ascii_lowercase().as_str() {
        "none" | "no-screen" | "noscreen" => NoScreen,
        "dfr" | "dfr-sgl" => DfrSgl,
        "dfr-asgl" | "asgl" => DfrAsgl,
        "sparsegl" => Sparsegl,
        "gap" | "gap-seq" | "gap-safe" => GapSafeSeq,
        "gap-dyn" => GapSafeDyn,
        "tlfre" => Tlfre,
        other => return Err(format!("unknown rule `{other}`")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "p", help: "dims", default: Some("1000"), takes_value: true },
            OptSpec { name: "verbose", help: "talk", default: None, takes_value: false },
        ]
    }

    fn argv(items: &[&str]) -> Vec<String> {
        std::iter::once("prog").chain(items.iter().copied()).map(String::from).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(argv(&["fit", "--p", "200", "--verbose"]), &specs()).unwrap();
        assert_eq!(a.positional, vec!["fit"]);
        assert_eq!(a.usize_or("p", 0).unwrap(), 200);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Args::parse(argv(&["--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv(&["--p"]), &specs()).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv(&[]), &specs()).unwrap();
        assert_eq!(a.usize_or("p", 1000).unwrap(), 1000);
        assert_eq!(a.f64_or("missing", 0.5).unwrap(), 0.5);
        assert_eq!(a.u64_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn u64_parses_and_rejects() {
        let a = Args::parse(argv(&["--p", "42"]), &specs()).unwrap();
        assert_eq!(a.u64_or("p", 0).unwrap(), 42);
        let b = Args::parse(argv(&["--p", "nope"]), &specs()).unwrap();
        assert!(b.u64_or("p", 0).is_err());
    }

    #[test]
    fn rule_names_parse() {
        assert_eq!(parse_rule("dfr").unwrap(), crate::screen::RuleKind::DfrSgl);
        assert_eq!(parse_rule("DFR-aSGL").unwrap(), crate::screen::RuleKind::DfrAsgl);
        assert_eq!(parse_rule("tlfre").unwrap(), crate::screen::RuleKind::Tlfre);
        assert_eq!(parse_rule("TLFre").unwrap(), crate::screen::RuleKind::Tlfre);
        assert!(parse_rule("wat").is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = usage("dfr", "about", &specs());
        assert!(u.contains("--p"));
        assert!(u.contains("default: 1000"));
    }

    #[test]
    fn f64_lists_parse() {
        assert_eq!(parse_f64_list("0.5,0.9, 0.95").unwrap(), vec![0.5, 0.9, 0.95]);
        assert_eq!(parse_f64_list("1").unwrap(), vec![1.0]);
        assert_eq!(parse_f64_list("0.5,,0.9,").unwrap(), vec![0.5, 0.9]);
        assert!(parse_f64_list("0.5,x").is_err());
    }

    #[test]
    fn f64_lists_reject_non_finite() {
        // `f64::parse` happily accepts these spellings; the CLI must not.
        assert!(parse_f64_list("nan").is_err());
        assert!(parse_f64_list("0.5,inf").is_err());
        assert!(parse_f64_list("-inf,0.5").is_err());
    }

    #[test]
    fn gamma_lists_parse() {
        assert_eq!(
            parse_gamma_list("none,0.1,0.2:0.5").unwrap(),
            vec![None, Some((0.1, 0.1)), Some((0.2, 0.5))]
        );
        assert_eq!(parse_gamma_list("NONE").unwrap(), vec![None]);
        assert!(parse_gamma_list("0.1:wat").is_err());
        assert!(parse_gamma_list("huh").is_err());
    }

    #[test]
    fn gamma_lists_reject_invalid_values() {
        assert!(parse_gamma_list("-0.1").is_err());
        assert!(parse_gamma_list("nan").is_err());
        assert!(parse_gamma_list("0.1:inf").is_err());
    }
}
