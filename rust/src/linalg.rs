//! Dense linear-algebra substrate.
//!
//! No BLAS binding is available offline, so the crate carries its own
//! column-major dense matrix with the handful of kernels the pathwise SGL
//! stack needs: `Xᵀr` (gradient), `Xβ` (predictions), column gathers (for
//! screening-reduced designs), Gram products and standardization. The
//! gradient matvec is the L3 hot path when the XLA engine is not in use, so
//! it is written to auto-vectorize (contiguous column dot products with
//! 4-way unrolled accumulators) and can fan out over a thread scope.

use crate::parallel;

/// Column-major dense matrix of `f64`.
///
/// Column-major is the natural layout for pathwise screening: the gradient
/// `Xᵀr` is one contiguous dot product per column, and gathering the
/// optimization set into a reduced design is a set of `memcpy`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    p: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix with `n` rows and `p` columns.
    pub fn zeros(n: usize, p: usize) -> Self {
        Matrix { n, p, data: vec![0.0; n * p] }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(n: usize, p: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                m.data[j * n + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data (length must be `n * p`).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major data length mismatch");
        Matrix { n, p, data }
    }

    /// Build from a list of columns, each of length `n`.
    pub fn from_columns(n: usize, cols: &[Vec<f64>]) -> Self {
        let p = cols.len();
        let mut data = Vec::with_capacity(n * p);
        for c in cols {
            assert_eq!(c.len(), n);
            data.extend_from_slice(c);
        }
        Matrix { n, p, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Raw column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.p);
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = X β`, reusing the output buffer (hot-loop form).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                axpy(b, self.col(j), out);
            }
        }
    }

    /// `g = Xᵀ r` (length p). Single-threaded.
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = Xᵀ r`, reusing the output buffer.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = dot(self.col(j), r);
        }
    }

    /// `Xᵀ r` fanned out across a thread scope — the no-XLA gradient hot
    /// path for large `p`.
    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_par_into(r, threads, &mut out);
        out
    }

    /// `out = Xᵀ r` fanned out across a thread scope, reusing the output
    /// buffer (the allocation-free hot-loop form).
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        // Scoped-thread spawn costs ~50–100 µs per worker and the matvec
        // is memory-bandwidth bound, so threading only breaks even once
        // the matrix itself is far larger than L2 (measured in
        // benches/perf_hotpath.rs — see EXPERIMENTS.md §Perf).
        if threads <= 1 || self.n * self.p < 8_000_000 {
            self.t_matvec_into(r, out);
            return;
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = dot(self.col(start + k), r);
            }
        });
    }

    /// Gather the given columns into a new (n × idx.len()) matrix — used to
    /// build the screening-reduced design for the inner solver. Pathwise
    /// callers should prefer [`ReducedDesign`], which reuses its backing
    /// buffer and diffs consecutive index sets.
    pub fn gather_columns(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(self.n * idx.len());
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        Matrix { n: self.n, p: idx.len(), data }
    }

    /// Drop all but the first `k` columns in place (capacity is retained,
    /// so subsequent [`Matrix::push_col`] calls do not reallocate).
    pub fn truncate_cols(&mut self, k: usize) {
        assert!(k <= self.p, "truncate_cols past the end");
        self.data.truncate(self.n * k);
        self.p = k;
    }

    /// Append one column (length must be `n`).
    pub fn push_col(&mut self, col: &[f64]) {
        assert_eq!(col.len(), self.n);
        self.data.extend_from_slice(col);
        self.p += 1;
    }

    /// Reserve backing storage for `extra` additional columns.
    pub fn reserve_cols(&mut self, extra: usize) {
        self.data.reserve(self.n * extra);
    }

    /// ℓ₂ norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| norm2(self.col(j))).collect()
    }

    /// Spectral-norm upper bound via `max_j ‖X e_j‖₂ · √p` is far too loose;
    /// instead run a few power iterations on `XᵀX` to estimate `‖X‖₂²`,
    /// which upper-bounds the gradient Lipschitz constant of the squared
    /// loss (divided by n).
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        let mut v: Vec<f64> = {
            let mut rng = crate::rng::Rng::new(seed);
            (0..self.p).map(|_| rng.gauss()).collect()
        };
        let nv = norm2(&v).max(1e-300);
        v.iter_mut().for_each(|x| *x /= nv);
        let mut lam;
        let mut xb = vec![0.0; self.n];
        for _ in 0..iters.max(1) {
            self.matvec_into(&v, &mut xb);
            let w = self.t_matvec(&xb);
            lam = norm2(&w);
            if lam <= 0.0 {
                return 0.0;
            }
            v = w.iter().map(|x| x / lam).collect();
        }
        // One extra Rayleigh quotient for a tighter estimate.
        self.matvec_into(&v, &mut xb);
        dot(&xb, &xb) / dot(&v, &v)
    }

    /// Center each column to mean zero and scale to unit ℓ₂ norm (the
    /// paper's "ℓ₂ standardization"). Returns per-column (mean, norm) so
    /// coefficients can be mapped back to the original scale. Constant
    /// columns get norm 1 (they stay zero after centering).
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n;
        (0..self.p)
            .map(|j| {
                let col = self.col_mut(j);
                let mean = col.iter().sum::<f64>() / n as f64;
                col.iter_mut().for_each(|x| *x -= mean);
                let nrm = norm2(col);
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                col.iter_mut().for_each(|x| *x /= scale);
                (mean, scale)
            })
            .collect()
    }

    /// Horizontal concatenation (same row count).
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { n: self.n, p: self.p + other.p, data }
    }

    /// Select a subset of rows (used by the CV fold splitter).
    pub fn gather_rows(&self, rows: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(rows.len(), self.p);
        for j in 0..self.p {
            let src = self.col(j);
            let dst = m.col_mut(j);
            for (k, &i) in rows.iter().enumerate() {
                dst[k] = src[i];
            }
        }
        m
    }
}

/// Incremental cache of a screening-reduced design `X[:, idx]`.
///
/// The pathwise coordinator re-gathers the optimization set every λ step
/// and every KKT re-entry round; consecutive sets overlap heavily (the
/// active set persists, KKT rounds only add variables). This cache keeps
/// one grow-only backing buffer across the whole path and, on each update,
/// keeps the longest common prefix of the sorted index lists in place —
/// identical sets cost nothing, append-only growth copies only the new
/// columns, and even a full rebuild reuses the allocation.
///
/// The source matrix is identified by pointer + length + a strided content
/// fingerprint, so reusing one cache across datasets (CV folds, bench
/// repeats) detects a swapped design even when the allocator hands the new
/// matrix the old one's address. Contract: source matrices are immutable
/// between updates (true everywhere in this crate — designs never change
/// after construction); an *in-place* mutation of the same allocation can
/// dodge the 64 sampled positions, so callers mutating a design must call
/// [`ReducedDesign::invalidate`] themselves.
#[derive(Clone, Debug)]
pub struct ReducedDesign {
    idx: Vec<usize>,
    mat: Matrix,
    key: Option<(usize, usize, u64)>,
    /// Updates answered with zero copying (identical index set).
    pub hits: usize,
    /// Columns kept in place across updates (common sorted prefix).
    pub kept_cols: usize,
    /// Columns memcpy'd from the source matrix.
    pub copied_cols: usize,
}

impl ReducedDesign {
    pub fn new() -> Self {
        ReducedDesign {
            idx: Vec::new(),
            mat: Matrix::zeros(0, 0),
            key: None,
            hits: 0,
            kept_cols: 0,
            copied_cols: 0,
        }
    }

    /// Point the cache at `x[:, idx]` (sorted indices), reusing any columns
    /// already in place, and return the reduced matrix.
    pub fn update(&mut self, x: &Matrix, idx: &[usize]) -> &Matrix {
        let key = (
            x.as_slice().as_ptr() as usize,
            x.as_slice().len(),
            fingerprint(x.as_slice()),
        );
        if self.key != Some(key) {
            self.key = Some(key);
            self.idx.clear();
            if self.mat.nrows() == x.nrows() {
                self.mat.truncate_cols(0);
            } else {
                self.mat = Matrix::zeros(x.nrows(), 0);
            }
        }
        if self.idx == idx {
            self.hits += 1;
            return &self.mat;
        }
        let keep = self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
        self.mat.truncate_cols(keep);
        self.idx.truncate(keep);
        self.mat.reserve_cols(idx.len() - keep);
        for &j in &idx[keep..] {
            self.mat.push_col(x.col(j));
        }
        self.idx.extend_from_slice(&idx[keep..]);
        self.kept_cols += keep;
        self.copied_cols += idx.len() - keep;
        &self.mat
    }

    /// The cached reduced matrix (columns of the last `update`).
    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }

    /// The column indices currently cached.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Force the next update to rebuild from scratch (buffer retained).
    pub fn invalidate(&mut self) {
        self.idx.clear();
        self.key = None;
        self.mat.truncate_cols(0);
    }
}

impl Default for ReducedDesign {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-style fingerprint over up to 64 strided samples — cheap identity
/// check for "is this the same array as last time". Single source of truth
/// for both the [`ReducedDesign`] cache and the runtime's device-buffer
/// cache key.
pub(crate) fn fingerprint(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let n = data.len();
    let stride = (n / 64).max(1);
    let mut i = 0;
    while i < n {
        h ^= data[i].to_bits();
        h = h.wrapping_mul(0x100000001b3);
        i += stride;
    }
    h
}

/// Dot product with 4 independent accumulators (lets LLVM vectorize without
/// needing `-ffast-math`-style reassociation permission).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ℓ₁ norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// ‖a − b‖₂ — used for the paper's "ℓ₂ distance to no screen" metric.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Elementwise subtraction `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
pub fn scale(x: &mut [f64], s: f64) {
    x.iter_mut().for_each(|v| *v *= s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Matrix {
        // [[1, 4], [2, 5], [3, 6]]
        Matrix::from_columns(3, &[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn t_matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.t_matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn parallel_t_matvec_matches_serial() {
        let mut rng = crate::rng::Rng::new(1);
        let m = Matrix::from_fn(37, 501, |_, _| rng.gauss());
        let r = rng.gauss_vec(37);
        let a = m.t_matvec(&r);
        let b = m.t_matvec_par(&r, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_columns_picks_right_columns() {
        let m = small();
        let g = m.gather_columns(&[1]);
        assert_eq!(g.ncols(), 1);
        assert_eq!(g.col(0), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn parallel_t_matvec_into_matches_allocating_form() {
        let mut rng = crate::rng::Rng::new(5);
        let m = Matrix::from_fn(23, 301, |_, _| rng.gauss());
        let r = rng.gauss_vec(23);
        let a = m.t_matvec_par(&r, 3);
        let mut b = vec![1.0; 301]; // non-zero garbage: must be overwritten
        m.t_matvec_par_into(&r, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn truncate_and_push_cols_roundtrip() {
        let mut m = small();
        m.truncate_cols(1);
        assert_eq!(m.ncols(), 1);
        assert_eq!(m.col(0), &[1.0, 2.0, 3.0]);
        m.push_col(&[7.0, 8.0, 9.0]);
        assert_eq!(m.ncols(), 2);
        assert_eq!(m.col(1), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn reduced_design_matches_fresh_gather() {
        let mut rng = crate::rng::Rng::new(6);
        let x = Matrix::from_fn(11, 14, |_, _| rng.gauss());
        let mut rd = ReducedDesign::new();
        for idx in [
            vec![1usize, 3, 5],
            vec![1, 3, 6, 7],    // shares the [1, 3] prefix
            vec![1, 3, 6, 7],    // identical → cache hit
            vec![0, 3, 6],       // no shared prefix → rebuild
            vec![0, 3, 6, 9, 12], // append-only growth
        ] {
            let got = rd.update(&x, &idx).clone();
            assert_eq!(got, x.gather_columns(&idx), "idx {idx:?}");
            assert_eq!(rd.indices(), idx.as_slice());
        }
        assert_eq!(rd.hits, 1);
        assert!(rd.kept_cols >= 2, "prefix reuse never happened");
    }

    #[test]
    fn reduced_design_detects_matrix_change() {
        let mut rng = crate::rng::Rng::new(7);
        let a = Matrix::from_fn(9, 6, |_, _| rng.gauss());
        let b = Matrix::from_fn(9, 6, |_, _| rng.gauss());
        let mut rd = ReducedDesign::new();
        rd.update(&a, &[0, 2, 4]);
        let got = rd.update(&b, &[0, 2, 4]).clone();
        assert_eq!(got, b.gather_columns(&[0, 2, 4]), "stale columns served");
    }

    #[test]
    fn gather_rows_picks_right_rows() {
        let m = small();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.get(0, 0), 3.0);
        assert_eq!(g.get(1, 1), 4.0);
    }

    #[test]
    fn standardize_gives_zero_mean_unit_norm() {
        let mut rng = crate::rng::Rng::new(2);
        let mut m = Matrix::from_fn(50, 10, |_, _| rng.normal(3.0, 2.0));
        m.standardize_l2();
        for j in 0..10 {
            let c = m.col(j);
            let mean: f64 = c.iter().sum::<f64>() / 50.0;
            assert!(mean.abs() < 1e-12);
            assert!((norm2(c) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn op_norm_est_close_to_true_on_diagonal_case() {
        // X = diag-ish: columns orthogonal with norms 1, 2, 3 → ‖X‖₂² = 9.
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 1.0);
        m.set(1, 1, 2.0);
        m.set(2, 2, 3.0);
        let est = m.op_norm_sq_est(50, 7);
        assert!((est - 9.0).abs() < 1e-6, "est {est}");
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
        assert_eq!(dot(&a, &a), 91.0);
    }

    #[test]
    fn l2_distance_zero_iff_equal() {
        let a = [1.0, 2.0];
        assert_eq!(l2_distance(&a, &a), 0.0);
        assert!((l2_distance(&a, &[1.0, 4.0]) - 2.0).abs() < 1e-15);
    }
}
