//! Dense linear-algebra substrate.
//!
//! No BLAS binding is available offline, so the crate carries its own
//! column-major dense matrix with the handful of kernels the pathwise SGL
//! stack needs: `Xᵀr` (gradient), `Xβ` (predictions), column gathers (for
//! screening-reduced designs), Gram products and standardization. The
//! gradient matvec is the L3 hot path when the XLA engine is not in use, so
//! it is written to auto-vectorize (contiguous column dot products with
//! 4-way unrolled accumulators) and can fan out over a thread scope.

use crate::parallel;

/// Column-major dense matrix of `f64`.
///
/// Column-major is the natural layout for pathwise screening: the gradient
/// `Xᵀr` is one contiguous dot product per column, and gathering the
/// optimization set into a reduced design is a set of `memcpy`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    p: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix with `n` rows and `p` columns.
    pub fn zeros(n: usize, p: usize) -> Self {
        Matrix { n, p, data: vec![0.0; n * p] }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(n: usize, p: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                m.data[j * n + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data (length must be `n * p`).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major data length mismatch");
        Matrix { n, p, data }
    }

    /// Build from a list of columns, each of length `n`.
    pub fn from_columns(n: usize, cols: &[Vec<f64>]) -> Self {
        let p = cols.len();
        let mut data = Vec::with_capacity(n * p);
        for c in cols {
            assert_eq!(c.len(), n);
            data.extend_from_slice(c);
        }
        Matrix { n, p, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Raw column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.p);
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = X β`, reusing the output buffer (hot-loop form).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                axpy(b, self.col(j), out);
            }
        }
    }

    /// `g = Xᵀ r` (length p). Single-threaded.
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = Xᵀ r`, reusing the output buffer.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = dot(self.col(j), r);
        }
    }

    /// `Xᵀ r` fanned out across a thread scope — the no-XLA gradient hot
    /// path for large `p`.
    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut out = vec![0.0; self.p];
        // Scoped-thread spawn costs ~50–100 µs per worker and the matvec
        // is memory-bandwidth bound, so threading only breaks even once
        // the matrix itself is far larger than L2 (measured in
        // benches/perf_hotpath.rs — see EXPERIMENTS.md §Perf).
        if threads <= 1 || self.n * self.p < 8_000_000 {
            self.t_matvec_into(r, &mut out);
            return out;
        }
        parallel::for_each_chunk(&mut out, threads, |start, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = dot(self.col(start + k), r);
            }
        });
        out
    }

    /// Gather the given columns into a new (n × idx.len()) matrix — used to
    /// build the screening-reduced design for the inner solver.
    pub fn gather_columns(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(self.n * idx.len());
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        Matrix { n: self.n, p: idx.len(), data }
    }

    /// ℓ₂ norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| norm2(self.col(j))).collect()
    }

    /// Spectral-norm upper bound via `max_j ‖X e_j‖₂ · √p` is far too loose;
    /// instead run a few power iterations on `XᵀX` to estimate `‖X‖₂²`,
    /// which upper-bounds the gradient Lipschitz constant of the squared
    /// loss (divided by n).
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        let mut v: Vec<f64> = {
            let mut rng = crate::rng::Rng::new(seed);
            (0..self.p).map(|_| rng.gauss()).collect()
        };
        let nv = norm2(&v).max(1e-300);
        v.iter_mut().for_each(|x| *x /= nv);
        let mut lam;
        let mut xb = vec![0.0; self.n];
        for _ in 0..iters.max(1) {
            self.matvec_into(&v, &mut xb);
            let w = self.t_matvec(&xb);
            lam = norm2(&w);
            if lam <= 0.0 {
                return 0.0;
            }
            v = w.iter().map(|x| x / lam).collect();
        }
        // One extra Rayleigh quotient for a tighter estimate.
        self.matvec_into(&v, &mut xb);
        dot(&xb, &xb) / dot(&v, &v)
    }

    /// Center each column to mean zero and scale to unit ℓ₂ norm (the
    /// paper's "ℓ₂ standardization"). Returns per-column (mean, norm) so
    /// coefficients can be mapped back to the original scale. Constant
    /// columns get norm 1 (they stay zero after centering).
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n;
        (0..self.p)
            .map(|j| {
                let col = self.col_mut(j);
                let mean = col.iter().sum::<f64>() / n as f64;
                col.iter_mut().for_each(|x| *x -= mean);
                let nrm = norm2(col);
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                col.iter_mut().for_each(|x| *x /= scale);
                (mean, scale)
            })
            .collect()
    }

    /// Horizontal concatenation (same row count).
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { n: self.n, p: self.p + other.p, data }
    }

    /// Select a subset of rows (used by the CV fold splitter).
    pub fn gather_rows(&self, rows: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(rows.len(), self.p);
        for j in 0..self.p {
            let src = self.col(j);
            let dst = m.col_mut(j);
            for (k, &i) in rows.iter().enumerate() {
                dst[k] = src[i];
            }
        }
        m
    }
}

/// Dot product with 4 independent accumulators (lets LLVM vectorize without
/// needing `-ffast-math`-style reassociation permission).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ℓ₁ norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// ‖a − b‖₂ — used for the paper's "ℓ₂ distance to no screen" metric.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Elementwise subtraction `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
pub fn scale(x: &mut [f64], s: f64) {
    x.iter_mut().for_each(|v| *v *= s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Matrix {
        // [[1, 4], [2, 5], [3, 6]]
        Matrix::from_columns(3, &[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn t_matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.t_matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn parallel_t_matvec_matches_serial() {
        let mut rng = crate::rng::Rng::new(1);
        let m = Matrix::from_fn(37, 501, |_, _| rng.gauss());
        let r = rng.gauss_vec(37);
        let a = m.t_matvec(&r);
        let b = m.t_matvec_par(&r, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_columns_picks_right_columns() {
        let m = small();
        let g = m.gather_columns(&[1]);
        assert_eq!(g.ncols(), 1);
        assert_eq!(g.col(0), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn gather_rows_picks_right_rows() {
        let m = small();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.get(0, 0), 3.0);
        assert_eq!(g.get(1, 1), 4.0);
    }

    #[test]
    fn standardize_gives_zero_mean_unit_norm() {
        let mut rng = crate::rng::Rng::new(2);
        let mut m = Matrix::from_fn(50, 10, |_, _| rng.normal(3.0, 2.0));
        m.standardize_l2();
        for j in 0..10 {
            let c = m.col(j);
            let mean: f64 = c.iter().sum::<f64>() / 50.0;
            assert!(mean.abs() < 1e-12);
            assert!((norm2(c) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn op_norm_est_close_to_true_on_diagonal_case() {
        // X = diag-ish: columns orthogonal with norms 1, 2, 3 → ‖X‖₂² = 9.
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 1.0);
        m.set(1, 1, 2.0);
        m.set(2, 2, 3.0);
        let est = m.op_norm_sq_est(50, 7);
        assert!((est - 9.0).abs() < 1e-6, "est {est}");
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
        assert_eq!(dot(&a, &a), 91.0);
    }

    #[test]
    fn l2_distance_zero_iff_equal() {
        let a = [1.0, 2.0];
        assert_eq!(l2_distance(&a, &a), 0.0);
        assert!((l2_distance(&a, &[1.0, 4.0]) - 2.0).abs() < 1e-15);
    }
}
