//! Dense linear-algebra substrate.
//!
//! No BLAS binding is available offline, so the crate carries its own
//! column-major dense matrix with the handful of kernels the pathwise SGL
//! stack needs: `Xᵀr` (gradient), `Xβ` (predictions), column gathers (for
//! screening-reduced designs), Gram products and standardization. The
//! gradient matvec is the L3 hot path when the XLA engine is not in use, so
//! it is written to auto-vectorize (contiguous column dot products with
//! 4-way unrolled accumulators) and can fan out over a thread scope.

use crate::parallel;

/// Column-major dense matrix of `f64`.
///
/// Column-major is the natural layout for pathwise screening: the gradient
/// `Xᵀr` is one contiguous dot product per column, and gathering the
/// optimization set into a reduced design is a set of `memcpy`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    p: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix with `n` rows and `p` columns.
    pub fn zeros(n: usize, p: usize) -> Self {
        Matrix { n, p, data: vec![0.0; n * p] }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(n: usize, p: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(n, p);
        for j in 0..p {
            for i in 0..n {
                m.data[j * n + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data (length must be `n * p`).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major data length mismatch");
        Matrix { n, p, data }
    }

    /// Build from a list of columns, each of length `n`.
    pub fn from_columns(n: usize, cols: &[Vec<f64>]) -> Self {
        let p = cols.len();
        let mut data = Vec::with_capacity(n * p);
        for c in cols {
            assert_eq!(c.len(), n);
            data.extend_from_slice(c);
        }
        Matrix { n, p, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Raw column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.p);
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = X β`, reusing the output buffer (hot-loop form).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                axpy(b, self.col(j), out);
            }
        }
    }

    /// `g = Xᵀ r` (length p). Single-threaded.
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = Xᵀ r`, reusing the output buffer.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = dot(self.col(j), r);
        }
    }

    /// `Xᵀ r` fanned out across a thread scope — the no-XLA gradient hot
    /// path for large `p`.
    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_par_into(r, threads, &mut out);
        out
    }

    /// `out = Xᵀ r` fanned out across a thread scope, reusing the output
    /// buffer (the allocation-free hot-loop form).
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        // Scoped-thread spawn costs ~50–100 µs per worker and the matvec
        // is memory-bandwidth bound, so threading only breaks even once
        // the matrix itself is far larger than L2 (measured in
        // benches/perf_hotpath.rs — see EXPERIMENTS.md §Perf).
        if threads <= 1 || self.n * self.p < 8_000_000 {
            self.t_matvec_into(r, out);
            return;
        }
        parallel::for_each_chunk(out, threads, |start, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = dot(self.col(start + k), r);
            }
        });
    }

    /// `out += Σ_k coeffs[k] · X[:, cols.start + k]` — the group-block
    /// matvec `X_g β_g` accumulated into a carried fitted-values buffer
    /// (the BCD residual-carried block update). Zero coefficients are
    /// skipped, so updating an inactive block costs nothing.
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(coeffs.len(), cols.len());
        debug_assert_eq!(out.len(), self.n);
        for (k, &c) in coeffs.iter().enumerate() {
            if c != 0.0 {
                axpy(c, self.col(cols.start + k), out);
            }
        }
    }

    /// `out[k] = X[:, cols.start + k]ᵀ r` — the group-block transpose
    /// matvec `X_gᵀ r`, written into the block slice of a gradient buffer.
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), cols.len());
        debug_assert_eq!(r.len(), self.n);
        for (k, o) in out.iter_mut().enumerate() {
            *o = dot(self.col(cols.start + k), r);
        }
    }

    /// Squared ℓ₂ norm of every column, written into `out` (length p) —
    /// the per-column cache behind the BCD block-Lipschitz seeds.
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.p);
        for (j, o) in out.iter_mut().enumerate() {
            let c = self.col(j);
            *o = dot(c, c);
        }
    }

    /// Gather the given columns into a new (n × idx.len()) matrix — used to
    /// build the screening-reduced design for the inner solver. Pathwise
    /// callers should prefer [`ReducedDesign`], which reuses its backing
    /// buffer and diffs consecutive index sets.
    pub fn gather_columns(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(self.n * idx.len());
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        Matrix { n: self.n, p: idx.len(), data }
    }

    /// Drop all but the first `k` columns in place (capacity is retained,
    /// so subsequent [`Matrix::push_col`] calls do not reallocate).
    pub fn truncate_cols(&mut self, k: usize) {
        assert!(k <= self.p, "truncate_cols past the end");
        self.data.truncate(self.n * k);
        self.p = k;
    }

    /// Append one column (length must be `n`).
    pub fn push_col(&mut self, col: &[f64]) {
        assert_eq!(col.len(), self.n);
        self.data.extend_from_slice(col);
        self.p += 1;
    }

    /// Reserve backing storage for `extra` additional columns.
    pub fn reserve_cols(&mut self, extra: usize) {
        self.data.reserve(self.n * extra);
    }

    /// ℓ₂ norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| norm2(self.col(j))).collect()
    }

    /// Spectral-norm upper bound via `max_j ‖X e_j‖₂ · √p` is far too loose;
    /// instead run a few power iterations on `XᵀX` to estimate `‖X‖₂²`,
    /// which upper-bounds the gradient Lipschitz constant of the squared
    /// loss (divided by n). One shared implementation serves every kernel
    /// variant ([`DesignRef::op_norm_sq_est`]), so the dense and sparse
    /// Lipschitz estimates can never drift apart algorithmically.
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        DesignRef::Dense(self).op_norm_sq_est(iters, seed)
    }

    /// Center each column to mean zero and scale to unit ℓ₂ norm (the
    /// paper's "ℓ₂ standardization"). Returns per-column (mean, norm) so
    /// coefficients can be mapped back to the original scale. Constant
    /// columns get norm 1 (they stay zero after centering).
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n;
        (0..self.p)
            .map(|j| {
                let col = self.col_mut(j);
                let mean = col.iter().sum::<f64>() / n as f64;
                col.iter_mut().for_each(|x| *x -= mean);
                let nrm = norm2(col);
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                col.iter_mut().for_each(|x| *x /= scale);
                (mean, scale)
            })
            .collect()
    }

    /// Horizontal concatenation (same row count).
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { n: self.n, p: self.p + other.p, data }
    }

    /// Select a subset of rows (used by the CV fold splitter).
    pub fn gather_rows(&self, rows: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(rows.len(), self.p);
        for j in 0..self.p {
            let src = self.col(j);
            let dst = m.col_mut(j);
            for (k, &i) in rows.iter().enumerate() {
                dst[k] = src[i];
            }
        }
        m
    }
}

/// Compressed-sparse-column matrix of `f64` — the input format for sparse
/// designs (genotype dosage matrices, one-hot expansions) accepted by the
/// model API's `Design::Csc` variant.
///
/// Storage is the classic CSC triplet: `col_ptr` (length `p + 1`) delimits
/// each column's slice of `row_idx`/`values`. Row indices are strictly
/// increasing within a column. The pathwise solver stack runs on the dense
/// [`Matrix`] (ℓ₂ standardization destroys sparsity anyway — centering
/// fills every zero), so the sparse type's job is (a) sparse-aware
/// `matvec`/`t_matvec`/`col_norms` for prediction and screening-style
/// passes over *raw* designs, and (b) one-pass standardization straight
/// into a dense standardized matrix, computing the per-column (mean, norm)
/// from the nonzeros alone — no intermediate dense unstandardized copy.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n: usize,
    p: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from raw CSC parts. Validates shape invariants (monotone
    /// `col_ptr`, in-range strictly-increasing row indices per column).
    pub fn new(
        n: usize,
        p: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(col_ptr.len(), p + 1, "col_ptr must have p + 1 entries");
        assert_eq!(col_ptr[0], 0, "col_ptr must start at 0");
        assert_eq!(col_ptr[p], row_idx.len(), "col_ptr end ≠ nnz");
        assert_eq!(row_idx.len(), values.len(), "row_idx / values length mismatch");
        for j in 0..p {
            assert!(col_ptr[j] <= col_ptr[j + 1], "col_ptr must be monotone");
            let rows = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            assert!(
                rows.windows(2).all(|w| w[0] < w[1]),
                "row indices must be strictly increasing within column {j}"
            );
            if let Some(&last) = rows.last() {
                assert!(last < n, "row index {last} out of range in column {j}");
            }
        }
        CscMatrix { n, p, col_ptr, row_idx, values }
    }

    /// Compress a dense matrix, keeping entries with `|x| > drop_tol`
    /// (use `0.0` to keep every nonzero exactly). NaN entries are always
    /// kept, so a poisoned input poisons the sparse fit the same way it
    /// poisons a dense one instead of silently becoming an implicit zero.
    pub fn from_dense(x: &Matrix, drop_tol: f64) -> Self {
        let (n, p) = (x.nrows(), x.ncols());
        let mut col_ptr = Vec::with_capacity(p + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..p {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v.abs() > drop_tol || v.is_nan() {
                    row_idx.push(i);
                    values.push(v);
                }
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n, p, col_ptr, row_idx, values }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Number of explicitly stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `nnz / (n · p)` — the fill fraction.
    pub fn density(&self) -> f64 {
        if self.n == 0 || self.p == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n * self.p) as f64
    }

    /// Column `j`'s stored `(row, value)` pairs.
    #[inline]
    pub fn col_entries(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let r = self.col_ptr[j]..self.col_ptr[j + 1];
        self.row_idx[r.clone()].iter().copied().zip(self.values[r].iter().copied())
    }

    /// `out = X β` touching only stored entries (O(nnz)).
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                for (i, v) in self.col_entries(j) {
                    out[i] += b * v;
                }
            }
        }
    }

    /// `y = X β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = Xᵀ r` touching only stored entries (O(nnz)).
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for (j, o) in out.iter_mut().enumerate() {
            let mut s = 0.0;
            for (i, v) in self.col_entries(j) {
                s += v * r[i];
            }
            *o = s;
        }
    }

    /// `g = Xᵀ r` (length p).
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// ℓ₂ norm of each column from the stored entries alone.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.p)
            .map(|j| self.col_entries(j).map(|(_, v)| v * v).sum::<f64>().sqrt())
            .collect()
    }

    /// Mean of each column (implicit zeros included).
    pub fn col_means(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| self.col_entries(j).map(|(_, v)| v).sum::<f64>() / n)
            .collect()
    }

    /// Per-column `(mean, scale)` of the ℓ₂ standardization (zero mean,
    /// unit ℓ₂ norm), computed sparse-aware in two passes over the stored
    /// entries: mean first, then the centered norm as
    /// `√(Σ_nz (v − mean)² + (n − nnz_j)·mean²)`. The shifted second pass
    /// avoids the catastrophic cancellation of the textbook
    /// `Σv² − n·mean²` form (large mean, tiny spread), so the stats track
    /// the dense two-pass [`Matrix::standardize_l2`] (near-constant
    /// columns get scale 1).
    pub fn standardize_stats(&self) -> Vec<(f64, f64)> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let mut sum = 0.0;
                let mut nnz_j = 0usize;
                for (_, v) in self.col_entries(j) {
                    sum += v;
                    nnz_j += 1;
                }
                let mean = sum / n;
                let mut centered_sumsq = (n - nnz_j as f64) * mean * mean;
                for (_, v) in self.col_entries(j) {
                    let d = v - mean;
                    centered_sumsq += d * d;
                }
                let nrm = centered_sumsq.sqrt();
                let scale = if nrm > 1e-12 { nrm } else { 1.0 };
                (mean, scale)
            })
            .collect()
    }

    /// Materialize the ℓ₂-standardized design as a dense [`Matrix`] in one
    /// pass (fill each column with `−mean/scale`, overwrite the stored
    /// entries with `(v − mean)/scale`), returning the per-column
    /// `(mean, scale)` used — the sparse entry point into the dense
    /// pathwise stack.
    pub fn to_standardized_dense(&self) -> (Matrix, Vec<(f64, f64)>) {
        note_dense_materialization();
        let stats = self.standardize_stats();
        let mut m = Matrix::zeros(self.n, self.p);
        for (j, &(mean, scale)) in stats.iter().enumerate() {
            let dst = m.col_mut(j);
            dst.fill(-mean / scale);
            for (i, v) in self.col_entries(j) {
                dst[i] = (v - mean) / scale;
            }
        }
        (m, stats)
    }

    /// Densify without standardizing (tests / small problems).
    pub fn to_dense(&self) -> Matrix {
        note_dense_materialization();
        let mut m = Matrix::zeros(self.n, self.p);
        for j in 0..self.p {
            let dst = m.col_mut(j);
            for (i, v) in self.col_entries(j) {
                dst[i] = v;
            }
        }
        m
    }

    /// Full content hash over values, row indices, and column pointers —
    /// the sparse leg of the model API's prepared-design cache key. Every
    /// stored entry participates, so any change to the matrix changes the
    /// hash (up to 64-bit collision odds).
    pub fn fingerprint(&self) -> u64 {
        let mut h = content_hash(&self.values);
        h ^= content_hash_usizes(&self.row_idx).wrapping_mul(0x9e3779b97f4a7c15);
        h ^= content_hash_usizes(&self.col_ptr).rotate_left(17);
        h
    }
}

thread_local! {
    /// Per-thread count of sparse→dense materializations (see
    /// [`dense_materializations`]).
    static DENSE_MATERIALIZATIONS: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// Number of times *this thread* has materialized a sparse design as a
/// dense matrix ([`CscMatrix::to_dense`], [`CscMatrix::to_standardized_dense`],
/// [`CenteredSparse::to_dense`]). The sparse solve path's acceptance
/// witness: a fit through the centered-implicit kernels must leave this
/// counter untouched (`rust/tests/sparse_equivalence.rs`). Thread-local so
/// concurrently running tests cannot alias each other's counts.
pub fn dense_materializations() -> u64 {
    DENSE_MATERIALIZATIONS.with(|c| c.get())
}

fn note_dense_materialization() {
    DENSE_MATERIALIZATIONS.with(|c| c.set(c.get() + 1));
}

/// ℓ₂-standardized sparse design held in centered-implicit form: the raw
/// CSC nonzeros plus per-column `(offset, scale)` such that the matrix the
/// kernels *evaluate* is
///
/// ```text
///     X̃[:, j] = (X[:, j] − offset_j · 1) / scale_j ,
/// ```
///
/// which is **never materialized dense** — centering would fill every
/// implicit zero with `−offset_j / scale_j`, destroying sparsity, so the
/// kernels carry the rank-one correction instead (the trick production SGL
/// solvers like `sparsegl` use):
///
/// * `X̃β  = X(β ⊘ s) − (Σ_j β_j μ_j / s_j) · 1` — one sparse matvec plus a
///   scalar shift, O(nnz + n);
/// * `X̃ᵀr = (Xᵀr − μ · Σᵢ rᵢ) ⊘ s` — one sparse transpose-matvec plus a
///   rank-one correction, O(nnz + n).
///
/// Built from a [`CscMatrix`] via [`CenteredSparse::from_csc`] (offsets =
/// column means, scales = centered column ℓ₂ norms, computed from the
/// nonzeros alone), this is the drop-in sparse counterpart of a dense
/// standardized [`Matrix`] everywhere the solve path only needs the
/// [`DesignRef`] kernel contract.
#[derive(Clone, Debug, PartialEq)]
pub struct CenteredSparse {
    n: usize,
    p: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
    /// Per-column centering offset μ_j (the raw column mean at build time).
    offsets: Vec<f64>,
    /// Per-column divisor s_j (the centered column norm at build time).
    scales: Vec<f64>,
}

impl CenteredSparse {
    /// Empty design with `n` rows and no columns (grow-only buffer seed
    /// for the reduced-design cache).
    pub fn empty(n: usize) -> Self {
        CenteredSparse {
            n,
            p: 0,
            col_ptr: vec![0],
            row_idx: Vec::new(),
            values: Vec::new(),
            offsets: Vec::new(),
            scales: Vec::new(),
        }
    }

    /// Standardized view of a raw CSC design: offsets/scales are the
    /// per-column `(mean, centered ℓ₂ norm)` from
    /// [`CscMatrix::standardize_stats`], so the implied matrix equals
    /// [`CscMatrix::to_standardized_dense`]'s output without the `n × p`
    /// allocation.
    pub fn from_csc(csc: &CscMatrix) -> Self {
        let stats = csc.standardize_stats();
        let (offsets, scales) = stats.into_iter().unzip();
        CenteredSparse {
            n: csc.n,
            p: csc.p,
            col_ptr: csc.col_ptr.clone(),
            row_idx: csc.row_idx.clone(),
            values: csc.values.clone(),
            offsets,
            scales,
        }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Number of stored raw nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fill fraction of the *raw* nonzeros (the implied standardized
    /// matrix is dense by construction; this measures the kernel cost).
    pub fn density(&self) -> f64 {
        if self.n == 0 || self.p == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n * self.p) as f64
    }

    /// Per-column `(offset, scale)` — the standardization centers callers
    /// use to map coefficients back to the raw scale.
    pub fn centers(&self) -> Vec<(f64, f64)> {
        self.offsets.iter().copied().zip(self.scales.iter().copied()).collect()
    }

    /// `out = X̃ β` touching only stored entries plus one rank-one shift.
    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        let mut shift = 0.0;
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                let bs = b / self.scales[j];
                shift += bs * self.offsets[j];
                for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                    out[self.row_idx[k]] += bs * self.values[k];
                }
            }
        }
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    /// `y = X̃ β` (length n).
    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        self.matvec_into(beta, &mut out);
        out
    }

    /// `out = X̃ᵀ r`: sparse column dots corrected by `μ_j · Σᵢ rᵢ`.
    pub fn t_matvec_into(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        let sr: f64 = r.iter().sum();
        for (j, o) in out.iter_mut().enumerate() {
            let mut s = 0.0;
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                s += self.values[k] * r[self.row_idx[k]];
            }
            *o = (s - self.offsets[j] * sr) / self.scales[j];
        }
    }

    /// `g = X̃ᵀ r` (length p).
    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.p];
        self.t_matvec_into(r, &mut out);
        out
    }

    /// `out = X̃ᵀ r` fanned out across a thread scope. The sparse kernel is
    /// O(nnz), so the break-even point is on stored entries, not `n·p`.
    pub fn t_matvec_par_into(&self, r: &[f64], threads: usize, out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        if threads <= 1 || self.nnz() + self.n < 4_000_000 {
            self.t_matvec_into(r, out);
            return;
        }
        let sr: f64 = r.iter().sum();
        parallel::for_each_chunk(out, threads, |start, chunk| {
            for (k, o) in chunk.iter_mut().enumerate() {
                let j = start + k;
                let mut s = 0.0;
                for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                    s += self.values[t] * r[self.row_idx[t]];
                }
                *o = (s - self.offsets[j] * sr) / self.scales[j];
            }
        });
    }

    /// `out += Σ_k coeffs[k] · X̃[:, cols.start + k]` — the centered-
    /// implicit group-block matvec: sparse per-column axpys plus **one**
    /// rank-one centering shift over the whole block, O(nnz_block + n).
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(coeffs.len(), cols.len());
        debug_assert_eq!(out.len(), self.n);
        let mut shift = 0.0;
        for (k, &c) in coeffs.iter().enumerate() {
            if c != 0.0 {
                let j = cols.start + k;
                let bs = c / self.scales[j];
                shift += bs * self.offsets[j];
                for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                    out[self.row_idx[t]] += bs * self.values[t];
                }
            }
        }
        if shift != 0.0 {
            out.iter_mut().for_each(|v| *v -= shift);
        }
    }

    /// `out[k] = X̃[:, cols.start + k]ᵀ r` — sparse block column dots with
    /// the rank-one centering correction (`Σᵢ rᵢ` computed once per block).
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), cols.len());
        debug_assert_eq!(r.len(), self.n);
        let sr: f64 = r.iter().sum();
        for (k, o) in out.iter_mut().enumerate() {
            let j = cols.start + k;
            let mut s = 0.0;
            for t in self.col_ptr[j]..self.col_ptr[j + 1] {
                s += self.values[t] * r[self.row_idx[t]];
            }
            *o = (s - self.offsets[j] * sr) / self.scales[j];
        }
    }

    /// Squared ℓ₂ norm of every *implied standardized* column into `out`
    /// (the sparse leg of the BCD block-Lipschitz cache) — computed from
    /// the stored entries alone, like [`CenteredSparse::col_norms`] without
    /// the square root.
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.p);
        let n = self.n as f64;
        for (j, o) in out.iter_mut().enumerate() {
            let (mu, s) = (self.offsets[j], self.scales[j]);
            let mut nnz_j = 0usize;
            let mut sumsq = 0.0;
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                let d = (self.values[k] - mu) / s;
                sumsq += d * d;
                nnz_j += 1;
            }
            let z = mu / s;
            *o = sumsq + (n - nnz_j as f64) * z * z;
        }
    }

    /// ℓ₂ norm of each *implied standardized* column:
    /// `√(Σ_nz ((v − μ)/s)² + (n − nnz_j)·(μ/s)²)` — 1 by construction for
    /// non-degenerate columns.
    pub fn col_norms(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let (mu, s) = (self.offsets[j], self.scales[j]);
                let mut nnz_j = 0usize;
                let mut sumsq = 0.0;
                for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                    let d = (self.values[k] - mu) / s;
                    sumsq += d * d;
                    nnz_j += 1;
                }
                let z = mu / s;
                (sumsq + (n - nnz_j as f64) * z * z).sqrt()
            })
            .collect()
    }

    /// Mean of each implied standardized column — `(mean_raw − μ)/s`,
    /// zero by construction right after [`CenteredSparse::from_csc`].
    pub fn col_means(&self) -> Vec<f64> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let raw: f64 =
                    self.values[self.col_ptr[j]..self.col_ptr[j + 1]].iter().sum();
                (raw / n - self.offsets[j]) / self.scales[j]
            })
            .collect()
    }

    /// Power-iteration estimate of `‖X̃‖₂²` — the shared
    /// [`DesignRef::op_norm_sq_est`] run through the implicit kernels.
    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        DesignRef::Sparse(self).op_norm_sq_est(iters, seed)
    }

    /// Row subset (CV folds): gathers the *raw* nonzeros and keeps the
    /// per-column `(offset, scale)`, so the implied matrix of the result is
    /// exactly the row-gather of this design's implied matrix. Arbitrary
    /// row order (and repeats) are supported, matching
    /// [`Matrix::gather_rows`].
    pub fn gather_rows(&self, rows: &[usize]) -> CenteredSparse {
        let mut positions: Vec<Vec<usize>> = vec![Vec::new(); self.n];
        for (k, &i) in rows.iter().enumerate() {
            assert!(i < self.n, "row index {i} out of range");
            positions[i].push(k);
        }
        let mut out = CenteredSparse::empty(rows.len());
        out.offsets = self.offsets.clone();
        out.scales = self.scales.clone();
        out.p = self.p;
        let mut col: Vec<(usize, f64)> = Vec::new();
        for j in 0..self.p {
            col.clear();
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                for &new_i in &positions[self.row_idx[k]] {
                    col.push((new_i, self.values[k]));
                }
            }
            col.sort_unstable_by_key(|&(i, _)| i);
            for &(i, v) in &col {
                out.row_idx.push(i);
                out.values.push(v);
            }
            out.col_ptr.push(out.values.len());
        }
        out
    }

    /// Re-standardize the *implied* matrix in place (zero mean, unit ℓ₂
    /// norm per column) and return the per-column `(mean, scale)` of the
    /// implied columns — the sparse counterpart of
    /// [`Matrix::standardize_l2`], used by the CV fold planner on sparse
    /// training subsets.
    ///
    /// The composition stays affine, so only the offsets/scales move:
    /// with current `(μ, s)` and implied-column stats `(m', s')`,
    /// `((x − μ)/s − m')/s' = (x − mean_raw)/(s·s')` where
    /// `mean_raw = μ + s·m'` is the raw column mean over these rows.
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        let n = self.n as f64;
        (0..self.p)
            .map(|j| {
                let r = self.col_ptr[j]..self.col_ptr[j + 1];
                let nnz_j = r.len();
                let sum: f64 = self.values[r.clone()].iter().sum();
                let mean_raw = sum / n;
                // Shifted two-pass centered norm (see
                // `CscMatrix::standardize_stats` for the cancellation
                // rationale).
                let mut centered_sumsq = (n - nnz_j as f64) * mean_raw * mean_raw;
                for k in r {
                    let d = self.values[k] - mean_raw;
                    centered_sumsq += d * d;
                }
                let (mu, s) = (self.offsets[j], self.scales[j]);
                let m_prime = (mean_raw - mu) / s;
                let nrm = centered_sumsq.sqrt() / s;
                let s_prime = if nrm > 1e-12 { nrm } else { 1.0 };
                self.offsets[j] = mean_raw;
                self.scales[j] = s * s_prime;
                (m_prime, s_prime)
            })
            .collect()
    }

    /// Materialize the implied standardized matrix (tests / diagnostics
    /// only — counts as a dense materialization for the sparse-path
    /// witness counter).
    pub fn to_dense(&self) -> Matrix {
        note_dense_materialization();
        let mut m = Matrix::zeros(self.n, self.p);
        for j in 0..self.p {
            let (mu, s) = (self.offsets[j], self.scales[j]);
            let dst = m.col_mut(j);
            dst.fill(-mu / s);
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                dst[self.row_idx[k]] = (self.values[k] - mu) / s;
            }
        }
        m
    }

    /// Drop all but the first `k` columns in place (grow-only buffers, for
    /// the reduced-design cache).
    pub(crate) fn truncate_cols(&mut self, k: usize) {
        assert!(k <= self.p, "truncate_cols past the end");
        let nnz = self.col_ptr[k];
        self.col_ptr.truncate(k + 1);
        self.row_idx.truncate(nnz);
        self.values.truncate(nnz);
        self.offsets.truncate(k);
        self.scales.truncate(k);
        self.p = k;
    }

    /// Append a copy of `src`'s column `j` (raw entries + its center).
    pub(crate) fn push_col_from(&mut self, src: &CenteredSparse, j: usize) {
        debug_assert_eq!(self.n, src.n);
        let r = src.col_ptr[j]..src.col_ptr[j + 1];
        self.row_idx.extend_from_slice(&src.row_idx[r.clone()]);
        self.values.extend_from_slice(&src.values[r]);
        self.offsets.push(src.offsets[j]);
        self.scales.push(src.scales[j]);
        self.col_ptr.push(self.values.len());
        self.p += 1;
    }
}

/// Kernel-variant display/cache-key name of the dense path — the single
/// source of the string shared by [`DesignRef::kernel_name`],
/// [`DesignOps::kernel_name`], and the model API's kernel resolution.
pub const DENSE_KERNEL: &str = "dense";

/// Kernel-variant name of the centered-implicit sparse path (see
/// [`DENSE_KERNEL`]).
pub const SPARSE_KERNEL: &str = "centered-sparse";

/// Borrowed view of a design the solve path can run its kernels on — the
/// kernel contract shared by every layer of the pathwise stack (loss
/// gradients, FISTA/ATOS matvecs, GAP-safe screening, power-iteration
/// Lipschitz estimates).
///
/// Two variants: [`DesignRef::Dense`] delegates to the exact same
/// [`Matrix`] kernels as before (dense results stay bit-stable), and
/// [`DesignRef::Sparse`] serves the centered-implicit kernels of
/// [`CenteredSparse`]. `Copy`, so it threads through call stacks like the
/// `&Matrix` it replaces.
#[derive(Clone, Copy, Debug)]
pub enum DesignRef<'a> {
    Dense(&'a Matrix),
    Sparse(&'a CenteredSparse),
}

impl<'a> DesignRef<'a> {
    #[inline]
    pub fn nrows(self) -> usize {
        match self {
            DesignRef::Dense(m) => m.nrows(),
            DesignRef::Sparse(s) => s.nrows(),
        }
    }

    #[inline]
    pub fn ncols(self) -> usize {
        match self {
            DesignRef::Dense(m) => m.ncols(),
            DesignRef::Sparse(s) => s.ncols(),
        }
    }

    /// The dense matrix behind this view, if any (XLA artifact execution
    /// and column gathers into dense buffers are dense-only).
    #[inline]
    pub fn as_dense(self) -> Option<&'a Matrix> {
        match self {
            DesignRef::Dense(m) => Some(m),
            DesignRef::Sparse(_) => None,
        }
    }

    /// Kernel variant name for reports and cache keys.
    pub fn kernel_name(self) -> &'static str {
        match self {
            DesignRef::Dense(_) => DENSE_KERNEL,
            DesignRef::Sparse(_) => SPARSE_KERNEL,
        }
    }

    pub fn matvec_into(self, beta: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.matvec_into(beta, out),
            DesignRef::Sparse(s) => s.matvec_into(beta, out),
        }
    }

    pub fn matvec(self, beta: &[f64]) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.matvec(beta),
            DesignRef::Sparse(s) => s.matvec(beta),
        }
    }

    pub fn t_matvec_into(self, r: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.t_matvec_into(r, out),
            DesignRef::Sparse(s) => s.t_matvec_into(r, out),
        }
    }

    pub fn t_matvec(self, r: &[f64]) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.t_matvec(r),
            DesignRef::Sparse(s) => s.t_matvec(r),
        }
    }

    pub fn t_matvec_par(self, r: &[f64], threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.ncols()];
        self.t_matvec_par_into(r, threads, &mut out);
        out
    }

    pub fn t_matvec_par_into(self, r: &[f64], threads: usize, out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.t_matvec_par_into(r, threads, out),
            DesignRef::Sparse(s) => s.t_matvec_par_into(r, threads, out),
        }
    }

    pub fn col_norms(self) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => m.col_norms(),
            DesignRef::Sparse(s) => s.col_norms(),
        }
    }

    /// Group-block matvec: `out += Σ_k coeffs[k] · X[:, cols.start + k]`
    /// (dense axpys / centered-implicit sparse axpys + one rank-one
    /// shift). The kernel contract of the BCD solver's residual-carried
    /// block updates.
    pub fn block_axpy_into(self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.block_axpy_into(cols, coeffs, out),
            DesignRef::Sparse(s) => s.block_axpy_into(cols, coeffs, out),
        }
    }

    /// Group-block transpose matvec: `out[k] = X[:, cols.start + k]ᵀ r`.
    pub fn block_t_matvec_into(self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.block_t_matvec_into(cols, r, out),
            DesignRef::Sparse(s) => s.block_t_matvec_into(cols, r, out),
        }
    }

    /// Squared ℓ₂ norm of every column of the design the kernels evaluate
    /// (per-group block-Lipschitz seeds).
    pub fn col_sq_norms_into(self, out: &mut [f64]) {
        match self {
            DesignRef::Dense(m) => m.col_sq_norms_into(out),
            DesignRef::Sparse(s) => s.col_sq_norms_into(out),
        }
    }

    /// Column means of the design the kernels evaluate (adaptive-weight
    /// PCA centering).
    pub fn col_means(self) -> Vec<f64> {
        match self {
            DesignRef::Dense(m) => {
                let n = m.nrows() as f64;
                (0..m.ncols()).map(|j| m.col(j).iter().sum::<f64>() / n).collect()
            }
            DesignRef::Sparse(s) => s.col_means(),
        }
    }

    /// Power-iteration estimate of `‖X‖₂²` on whichever kernel variant
    /// this view holds — the single implementation behind
    /// [`Matrix::op_norm_sq_est`] and [`CenteredSparse::op_norm_sq_est`]
    /// (for the dense arm this runs the exact historical algorithm through
    /// the delegating kernels, so dense results are unchanged).
    pub fn op_norm_sq_est(self, iters: usize, seed: u64) -> f64 {
        let p = self.ncols();
        let n = self.nrows();
        let mut v: Vec<f64> = {
            let mut rng = crate::rng::Rng::new(seed);
            (0..p).map(|_| rng.gauss()).collect()
        };
        let nv = norm2(&v).max(1e-300);
        v.iter_mut().for_each(|x| *x /= nv);
        let mut lam;
        let mut xb = vec![0.0; n];
        for _ in 0..iters.max(1) {
            self.matvec_into(&v, &mut xb);
            let w = self.t_matvec(&xb);
            lam = norm2(&w);
            if lam <= 0.0 {
                return 0.0;
            }
            v = w.iter().map(|x| x / lam).collect();
        }
        // One extra Rayleigh quotient for a tighter estimate.
        self.matvec_into(&v, &mut xb);
        dot(&xb, &xb) / dot(&v, &v)
    }
}

impl<'a> From<&'a Matrix> for DesignRef<'a> {
    fn from(m: &'a Matrix) -> Self {
        DesignRef::Dense(m)
    }
}

impl<'a> From<&'a CenteredSparse> for DesignRef<'a> {
    fn from(s: &'a CenteredSparse) -> Self {
        DesignRef::Sparse(s)
    }
}

impl<'a> From<&'a DesignOps> for DesignRef<'a> {
    fn from(d: &'a DesignOps) -> Self {
        d.view()
    }
}

/// Owned design in whichever kernel representation the solve should run:
/// a dense standardized [`Matrix`] (today's exact code path) or a
/// [`CenteredSparse`] centered-implicit design (sparse end-to-end). This
/// is what a [`crate::data::Dataset`] carries; the compute layers see it
/// through the borrowed [`DesignRef`] kernel contract.
#[derive(Clone, Debug)]
pub enum DesignOps {
    Dense(Matrix),
    Sparse(CenteredSparse),
}

impl DesignOps {
    /// Borrowed kernel view.
    #[inline]
    pub fn view(&self) -> DesignRef<'_> {
        match self {
            DesignOps::Dense(m) => DesignRef::Dense(m),
            DesignOps::Sparse(s) => DesignRef::Sparse(s),
        }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.view().nrows()
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.view().ncols()
    }

    /// Kernel variant name ("dense" / "centered-sparse").
    pub fn kernel_name(&self) -> &'static str {
        self.view().kernel_name()
    }

    pub fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.view().matvec(beta)
    }

    pub fn matvec_into(&self, beta: &[f64], out: &mut [f64]) {
        self.view().matvec_into(beta, out)
    }

    pub fn t_matvec(&self, r: &[f64]) -> Vec<f64> {
        self.view().t_matvec(r)
    }

    pub fn t_matvec_par(&self, r: &[f64], threads: usize) -> Vec<f64> {
        self.view().t_matvec_par(r, threads)
    }

    pub fn col_norms(&self) -> Vec<f64> {
        self.view().col_norms()
    }

    /// Group-block matvec (see [`DesignRef::block_axpy_into`]).
    pub fn block_axpy_into(&self, cols: std::ops::Range<usize>, coeffs: &[f64], out: &mut [f64]) {
        self.view().block_axpy_into(cols, coeffs, out)
    }

    /// Group-block transpose matvec (see [`DesignRef::block_t_matvec_into`]).
    pub fn block_t_matvec_into(&self, cols: std::ops::Range<usize>, r: &[f64], out: &mut [f64]) {
        self.view().block_t_matvec_into(cols, r, out)
    }

    /// Per-column squared norms (see [`DesignRef::col_sq_norms_into`]).
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        self.view().col_sq_norms_into(out)
    }

    pub fn op_norm_sq_est(&self, iters: usize, seed: u64) -> f64 {
        self.view().op_norm_sq_est(iters, seed)
    }

    /// The dense matrix inside. Panics on a centered-sparse design — for
    /// dense-only construction/inspection paths (data generators,
    /// interaction expansion, tests); the solve path never calls it.
    pub fn dense(&self) -> &Matrix {
        match self {
            DesignOps::Dense(m) => m,
            DesignOps::Sparse(_) => {
                panic!("dense() called on a centered-sparse design")
            }
        }
    }

    /// Mutable access to the dense matrix inside (panics when sparse).
    pub fn dense_mut(&mut self) -> &mut Matrix {
        match self {
            DesignOps::Dense(m) => m,
            DesignOps::Sparse(_) => {
                panic!("dense_mut() called on a centered-sparse design")
            }
        }
    }

    /// ℓ₂-standardize in place (dense: [`Matrix::standardize_l2`]; sparse:
    /// affine recomposition of the offsets/scales), returning the
    /// per-column `(mean, scale)` on the *current* implied scale.
    pub fn standardize_l2(&mut self) -> Vec<(f64, f64)> {
        match self {
            DesignOps::Dense(m) => m.standardize_l2(),
            DesignOps::Sparse(s) => s.standardize_l2(),
        }
    }

    /// Row subset with the variant preserved (CV folds stay sparse on the
    /// sparse path).
    pub fn gather_rows(&self, rows: &[usize]) -> DesignOps {
        match self {
            DesignOps::Dense(m) => DesignOps::Dense(m.gather_rows(rows)),
            DesignOps::Sparse(s) => DesignOps::Sparse(s.gather_rows(rows)),
        }
    }
}

impl From<Matrix> for DesignOps {
    fn from(m: Matrix) -> Self {
        DesignOps::Dense(m)
    }
}

impl From<CenteredSparse> for DesignOps {
    fn from(s: CenteredSparse) -> Self {
        DesignOps::Sparse(s)
    }
}

/// Incremental cache of a screening-reduced design `X[:, idx]`.
///
/// The pathwise coordinator re-gathers the optimization set every λ step
/// and every KKT re-entry round; consecutive sets overlap heavily (the
/// active set persists, KKT rounds only add variables). This cache keeps
/// one grow-only backing buffer across the whole path and, on each update,
/// keeps the longest common prefix of the sorted index lists in place —
/// identical sets cost nothing, append-only growth copies only the new
/// columns, and even a full rebuild reuses the allocation.
///
/// The source design is identified by variant + pointer + length + a
/// strided content fingerprint, so reusing one cache across datasets (CV
/// folds, bench repeats) detects a swapped design even when the allocator
/// hands the new matrix the old one's address. Contract: source designs
/// are immutable between updates (true everywhere in this crate — designs
/// never change after construction); an *in-place* mutation of the same
/// allocation can dodge the 64 sampled positions, so callers mutating a
/// design must call [`ReducedDesign::invalidate`] themselves.
///
/// Both kernel variants are served: a dense source gathers into a dense
/// grow-only [`Matrix`] exactly as before, and a [`CenteredSparse`] source
/// gathers into a reduced *centered-sparse* design (raw column slices plus
/// their `(offset, scale)` pairs) with the same prefix-diff reuse — the
/// sparse solve path never densifies its reduced problems.
#[derive(Clone, Debug)]
pub struct ReducedDesign {
    idx: Vec<usize>,
    mat: Matrix,
    smat: CenteredSparse,
    key: Option<(bool, usize, usize, u64)>,
    /// Group-block offsets of the last [`ReducedDesign::update_grouped`]
    /// gather: start of each maximal run of columns drawn from one
    /// original group, plus the `idx.len()` sentinel.
    gstarts: Vec<usize>,
    /// Updates answered with zero copying (identical index set).
    pub hits: usize,
    /// Columns kept in place across updates (common sorted prefix).
    pub kept_cols: usize,
    /// Columns memcpy'd from the source matrix.
    pub copied_cols: usize,
}

impl ReducedDesign {
    pub fn new() -> Self {
        ReducedDesign {
            idx: Vec::new(),
            mat: Matrix::zeros(0, 0),
            smat: CenteredSparse::empty(0),
            key: None,
            gstarts: Vec::new(),
            hits: 0,
            kept_cols: 0,
            copied_cols: 0,
        }
    }

    /// Point the cache at `x[:, idx]` (sorted indices), reusing any columns
    /// already in place, and return the reduced design in the source's
    /// kernel variant.
    pub fn update<'s, 'x>(
        &'s mut self,
        src: impl Into<DesignRef<'x>>,
        idx: &[usize],
    ) -> DesignRef<'s> {
        match src.into() {
            DesignRef::Dense(x) => {
                let key = (
                    false,
                    x.as_slice().as_ptr() as usize,
                    x.as_slice().len(),
                    fingerprint(x.as_slice()),
                );
                if self.key != Some(key) {
                    self.key = Some(key);
                    self.idx.clear();
                    // Drop any columns gathered from a previous sparse
                    // source so the cross-variant accessors never serve a
                    // stale design.
                    self.smat.truncate_cols(0);
                    if self.mat.nrows() == x.nrows() {
                        self.mat.truncate_cols(0);
                    } else {
                        self.mat = Matrix::zeros(x.nrows(), 0);
                    }
                }
                if self.idx == idx {
                    self.hits += 1;
                    return DesignRef::Dense(&self.mat);
                }
                let keep =
                    self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
                self.mat.truncate_cols(keep);
                self.idx.truncate(keep);
                self.mat.reserve_cols(idx.len() - keep);
                for &j in &idx[keep..] {
                    self.mat.push_col(x.col(j));
                }
                self.idx.extend_from_slice(&idx[keep..]);
                self.kept_cols += keep;
                self.copied_cols += idx.len() - keep;
                DesignRef::Dense(&self.mat)
            }
            DesignRef::Sparse(s) => {
                let key = (
                    true,
                    s.values.as_ptr() as usize,
                    s.values.len(),
                    fingerprint(&s.values)
                        ^ fingerprint(&s.offsets).rotate_left(17)
                        ^ fingerprint(&s.scales).rotate_left(31),
                );
                if self.key != Some(key) {
                    self.key = Some(key);
                    self.idx.clear();
                    // Symmetric to the dense branch: a stale dense gather
                    // from a previous source must not survive.
                    self.mat.truncate_cols(0);
                    if self.smat.nrows() == s.nrows() {
                        self.smat.truncate_cols(0);
                    } else {
                        self.smat = CenteredSparse::empty(s.nrows());
                    }
                }
                if self.idx == idx {
                    self.hits += 1;
                    return DesignRef::Sparse(&self.smat);
                }
                let keep =
                    self.idx.iter().zip(idx.iter()).take_while(|(a, b)| a == b).count();
                self.smat.truncate_cols(keep);
                self.idx.truncate(keep);
                for &j in &idx[keep..] {
                    self.smat.push_col_from(s, j);
                }
                self.idx.extend_from_slice(&idx[keep..]);
                self.kept_cols += keep;
                self.copied_cols += idx.len() - keep;
                DesignRef::Sparse(&self.smat)
            }
        }
    }

    /// [`ReducedDesign::update`] plus group-block bookkeeping: records the
    /// offsets at which the gathered columns change original group under
    /// `groups`, so a block-coordinate solver running on the reduced
    /// design sees exactly the blocks of the restricted penalty
    /// ([`crate::groups::Groups::restrict`] renumbers the same runs).
    /// Offsets are recomputed in O(|idx|) per update; the column gather
    /// itself keeps all of [`ReducedDesign::update`]'s prefix-diff reuse.
    pub fn update_grouped<'s, 'x>(
        &'s mut self,
        src: impl Into<DesignRef<'x>>,
        idx: &[usize],
        groups: &crate::groups::Groups,
    ) -> DesignRef<'s> {
        self.gstarts.clear();
        self.gstarts.push(0);
        for (k, w) in idx.windows(2).enumerate() {
            if groups.group_of(w[0]) != groups.group_of(w[1]) {
                self.gstarts.push(k + 1);
            }
        }
        self.gstarts.push(idx.len());
        self.update(src, idx)
    }

    /// Group-block offsets recorded by the last
    /// [`ReducedDesign::update_grouped`] (block `g` spans columns
    /// `offsets[g]..offsets[g+1]` of the reduced design). Empty until the
    /// first grouped update.
    pub fn group_offsets(&self) -> &[usize] {
        &self.gstarts
    }

    /// The cached dense reduced matrix (columns of the last dense
    /// `update`; empty if the last source was sparse).
    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }

    /// The column indices currently cached.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Force the next update to rebuild from scratch (buffers retained).
    pub fn invalidate(&mut self) {
        self.idx.clear();
        self.key = None;
        self.mat.truncate_cols(0);
        self.smat.truncate_cols(0);
        self.gstarts.clear();
    }
}

impl Default for ReducedDesign {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-style fingerprint over up to 64 strided samples — cheap identity
/// check for "is this the same array as last time". Single source of truth
/// for both the [`ReducedDesign`] cache and the runtime's device-buffer
/// cache key.
pub(crate) fn fingerprint(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let n = data.len();
    let stride = (n / 64).max(1);
    let mut i = 0;
    while i < n {
        h ^= data[i].to_bits();
        h = h.wrapping_mul(0x100000001b3);
        i += stride;
    }
    h
}

/// Full-content FNV hash over every entry — the sound (collision-odds
/// only, no sampling gaps) identity key for caches that must never serve
/// stale results for genuinely different data, e.g. the model API's
/// prepared-design cache. O(len), which is still far cheaper than the
/// copy + standardization a hit skips.
pub(crate) fn content_hash(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in data {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// [`content_hash`] over a `usize` slice (CSC structure arrays).
pub(crate) fn content_hash_usizes(data: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &v in data {
        h ^= v as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Dot product with 4 independent accumulators (lets LLVM vectorize without
/// needing `-ffast-math`-style reassociation permission).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ℓ₁ norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// ‖a − b‖₂ — used for the paper's "ℓ₂ distance to no screen" metric.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// Elementwise subtraction `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Scale in place.
pub fn scale(x: &mut [f64], s: f64) {
    x.iter_mut().for_each(|v| *v *= s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Matrix {
        // [[1, 4], [2, 5], [3, 6]]
        Matrix::from_columns(3, &[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn t_matvec_matches_hand_computation() {
        let m = small();
        assert_eq!(m.t_matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn parallel_t_matvec_matches_serial() {
        let mut rng = crate::rng::Rng::new(1);
        let m = Matrix::from_fn(37, 501, |_, _| rng.gauss());
        let r = rng.gauss_vec(37);
        let a = m.t_matvec(&r);
        let b = m.t_matvec_par(&r, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_columns_picks_right_columns() {
        let m = small();
        let g = m.gather_columns(&[1]);
        assert_eq!(g.ncols(), 1);
        assert_eq!(g.col(0), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn parallel_t_matvec_into_matches_allocating_form() {
        let mut rng = crate::rng::Rng::new(5);
        let m = Matrix::from_fn(23, 301, |_, _| rng.gauss());
        let r = rng.gauss_vec(23);
        let a = m.t_matvec_par(&r, 3);
        let mut b = vec![1.0; 301]; // non-zero garbage: must be overwritten
        m.t_matvec_par_into(&r, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn truncate_and_push_cols_roundtrip() {
        let mut m = small();
        m.truncate_cols(1);
        assert_eq!(m.ncols(), 1);
        assert_eq!(m.col(0), &[1.0, 2.0, 3.0]);
        m.push_col(&[7.0, 8.0, 9.0]);
        assert_eq!(m.ncols(), 2);
        assert_eq!(m.col(1), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn reduced_design_matches_fresh_gather() {
        let mut rng = crate::rng::Rng::new(6);
        let x = Matrix::from_fn(11, 14, |_, _| rng.gauss());
        let mut rd = ReducedDesign::new();
        for idx in [
            vec![1usize, 3, 5],
            vec![1, 3, 6, 7],    // shares the [1, 3] prefix
            vec![1, 3, 6, 7],    // identical → cache hit
            vec![0, 3, 6],       // no shared prefix → rebuild
            vec![0, 3, 6, 9, 12], // append-only growth
        ] {
            let got = rd.update(&x, &idx).as_dense().unwrap().clone();
            assert_eq!(got, x.gather_columns(&idx), "idx {idx:?}");
            assert_eq!(rd.indices(), idx.as_slice());
        }
        assert_eq!(rd.hits, 1);
        assert!(rd.kept_cols >= 2, "prefix reuse never happened");
    }

    #[test]
    fn reduced_design_detects_matrix_change() {
        let mut rng = crate::rng::Rng::new(7);
        let a = Matrix::from_fn(9, 6, |_, _| rng.gauss());
        let b = Matrix::from_fn(9, 6, |_, _| rng.gauss());
        let mut rd = ReducedDesign::new();
        rd.update(&a, &[0, 2, 4]);
        let got = rd.update(&b, &[0, 2, 4]).as_dense().unwrap().clone();
        assert_eq!(got, b.gather_columns(&[0, 2, 4]), "stale columns served");
    }

    #[test]
    fn reduced_design_update_grouped_records_offsets() {
        let mut rng = crate::rng::Rng::new(8);
        let x = Matrix::from_fn(9, 10, |_, _| rng.gauss());
        let groups = crate::groups::Groups::from_sizes(&[3, 3, 4]); // 0-2 | 3-5 | 6-9
        let mut rd = ReducedDesign::new();
        // vars {1, 2} ⊂ g0, {4} ⊂ g1, {6, 9} ⊂ g2 → blocks at 0, 2, 3.
        rd.update_grouped(&x, &[1, 2, 4, 6, 9], &groups);
        assert_eq!(rd.group_offsets(), &[0, 2, 3, 5]);
        let (restricted, _) = groups.restrict(&[1, 2, 4, 6, 9]);
        assert_eq!(rd.group_offsets(), restricted.offsets());
        // Incremental growth keeps the offsets in sync with the new set.
        rd.update_grouped(&x, &[1, 2, 4, 5, 6, 9], &groups);
        assert_eq!(rd.group_offsets(), &[0, 2, 4, 6]);
    }

    #[test]
    fn block_kernels_match_whole_design_kernels() {
        let mut rng = crate::rng::Rng::new(9);
        let x = Matrix::from_fn(12, 9, |_, _| rng.gauss());
        let cols = 3..7usize;
        let coeffs = rng.gauss_vec(4);
        let r = rng.gauss_vec(12);

        // block_axpy == matvec of a vector supported on the block.
        let mut full_beta = vec![0.0; 9];
        full_beta[cols.clone()].copy_from_slice(&coeffs);
        let expect = x.matvec(&full_beta);
        let mut got = vec![0.0; 12];
        x.block_axpy_into(cols.clone(), &coeffs, &mut got);
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-14);
        }

        // block_t_matvec == the block slice of Xᵀr.
        let full = x.t_matvec(&r);
        let mut block = vec![0.0; 4];
        x.block_t_matvec_into(cols.clone(), &r, &mut block);
        for (a, b) in block.iter().zip(&full[cols]) {
            assert!((a - b).abs() < 1e-14);
        }

        // col_sq_norms == col_norms².
        let mut sq = vec![0.0; 9];
        x.col_sq_norms_into(&mut sq);
        for (a, b) in sq.iter().zip(&x.col_norms()) {
            assert!((a - b * b).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_block_kernels_match_dense_block_kernels() {
        let (dense, csc) = sparse_fixture();
        let sparse = CenteredSparse::from_csc(&csc);
        let dense_std = sparse.to_dense(); // implied standardized matrix
        let mut rng = crate::rng::Rng::new(10);
        let cols = 2..6usize;
        let coeffs = rng.gauss_vec(4);
        let r = rng.gauss_vec(dense.nrows());
        let n = dense.nrows();

        let mut a = rng.gauss_vec(n); // nonzero accumulator: += semantics
        let mut b = a.clone();
        dense_std.block_axpy_into(cols.clone(), &coeffs, &mut a);
        sparse.block_axpy_into(cols.clone(), &coeffs, &mut b);
        for (x1, x2) in a.iter().zip(&b) {
            assert!((x1 - x2).abs() < 1e-12, "block_axpy drift");
        }

        let mut da = vec![0.0; 4];
        let mut db = vec![0.0; 4];
        dense_std.block_t_matvec_into(cols.clone(), &r, &mut da);
        sparse.block_t_matvec_into(cols.clone(), &r, &mut db);
        for (x1, x2) in da.iter().zip(&db) {
            assert!((x1 - x2).abs() < 1e-12, "block_t_matvec drift");
        }

        let mut sa = vec![0.0; dense.ncols()];
        let mut sb = vec![0.0; dense.ncols()];
        dense_std.col_sq_norms_into(&mut sa);
        sparse.col_sq_norms_into(&mut sb);
        for (x1, x2) in sa.iter().zip(&sb) {
            assert!((x1 - x2).abs() < 1e-12, "col_sq_norms drift");
        }
    }

    #[test]
    fn gather_rows_picks_right_rows() {
        let m = small();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.get(0, 0), 3.0);
        assert_eq!(g.get(1, 1), 4.0);
    }

    #[test]
    fn standardize_gives_zero_mean_unit_norm() {
        let mut rng = crate::rng::Rng::new(2);
        let mut m = Matrix::from_fn(50, 10, |_, _| rng.normal(3.0, 2.0));
        m.standardize_l2();
        for j in 0..10 {
            let c = m.col(j);
            let mean: f64 = c.iter().sum::<f64>() / 50.0;
            assert!(mean.abs() < 1e-12);
            assert!((norm2(c) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn op_norm_est_close_to_true_on_diagonal_case() {
        // X = diag-ish: columns orthogonal with norms 1, 2, 3 → ‖X‖₂² = 9.
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 1.0);
        m.set(1, 1, 2.0);
        m.set(2, 2, 3.0);
        let est = m.op_norm_sq_est(50, 7);
        assert!((est - 9.0).abs() < 1e-6, "est {est}");
    }

    fn sparse_fixture() -> (Matrix, CscMatrix) {
        // Sparse-ish matrix with exact zeros, a dense column, and an
        // all-zero column.
        let mut rng = crate::rng::Rng::new(11);
        let dense = Matrix::from_fn(13, 7, |i, j| {
            if j == 3 {
                rng.gauss() // fully dense column
            } else if j == 5 {
                0.0 // empty column
            } else if (i + j) % 3 == 0 {
                rng.gauss()
            } else {
                0.0
            }
        });
        let csc = CscMatrix::from_dense(&dense, 0.0);
        (dense, csc)
    }

    #[test]
    fn csc_round_trips_through_dense() {
        let (dense, csc) = sparse_fixture();
        assert_eq!(csc.to_dense(), dense);
        assert!(csc.nnz() < 13 * 7);
        assert!((csc.density() - csc.nnz() as f64 / 91.0).abs() < 1e-15);
    }

    #[test]
    fn csc_matvec_and_t_matvec_match_dense() {
        let (dense, csc) = sparse_fixture();
        let mut rng = crate::rng::Rng::new(12);
        let beta = rng.gauss_vec(7);
        let r = rng.gauss_vec(13);
        for (a, b) in csc.matvec(&beta).iter().zip(&dense.matvec(&beta)) {
            assert!((a - b).abs() < 1e-14);
        }
        for (a, b) in csc.t_matvec(&r).iter().zip(&dense.t_matvec(&r)) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn csc_col_stats_match_dense() {
        let (dense, csc) = sparse_fixture();
        for (a, b) in csc.col_norms().iter().zip(&dense.col_norms()) {
            assert!((a - b).abs() < 1e-12);
        }
        for (j, m) in csc.col_means().iter().enumerate() {
            let want = dense.col(j).iter().sum::<f64>() / 13.0;
            assert!((m - want).abs() < 1e-12);
        }
    }

    #[test]
    fn csc_standardized_dense_matches_dense_standardization() {
        let (dense, csc) = sparse_fixture();
        let mut want = dense.clone();
        let want_stats = want.standardize_l2();
        let (got, got_stats) = csc.to_standardized_dense();
        for j in 0..7 {
            let (wm, ws) = want_stats[j];
            let (gm, gs) = got_stats[j];
            assert!((wm - gm).abs() < 1e-12, "col {j} mean");
            assert!((ws - gs).abs() < 1e-12, "col {j} scale");
            for i in 0..13 {
                assert!(
                    (want.get(i, j) - got.get(i, j)).abs() < 1e-12,
                    "entry ({i}, {j})"
                );
            }
        }
    }

    #[test]
    fn csc_fingerprint_distinguishes_content_and_structure() {
        let (_, csc) = sparse_fixture();
        let fp = csc.fingerprint();
        let mut other = csc.clone();
        // Perturb one stored value: the fingerprint must move.
        let perturbed = CscMatrix::new(
            other.nrows(),
            other.ncols(),
            other.col_ptr.clone(),
            other.row_idx.clone(),
            {
                other.values[0] += 1.0;
                other.values.clone()
            },
        );
        assert_ne!(fp, perturbed.fingerprint());
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn csc_rejects_unsorted_rows() {
        CscMatrix::new(3, 1, vec![0, 2], vec![1, 0], vec![1.0, 2.0]);
    }

    #[test]
    fn csc_from_dense_preserves_nan() {
        let mut m = Matrix::zeros(3, 2);
        m.set(1, 0, f64::NAN);
        m.set(2, 1, 5.0);
        let csc = CscMatrix::from_dense(&m, 0.0);
        assert_eq!(csc.nnz(), 2, "NaN entry must be stored, not dropped");
        assert!(csc.to_dense().get(1, 0).is_nan());
    }

    #[test]
    fn centered_sparse_kernels_match_dense_standardized() {
        let (_, csc) = sparse_fixture();
        let cs = CenteredSparse::from_csc(&csc);
        let (dense_std, stats) = csc.to_standardized_dense();
        assert_eq!(cs.centers(), stats);
        let mut rng = crate::rng::Rng::new(21);
        let beta = rng.gauss_vec(7);
        let r = rng.gauss_vec(13);
        for (a, b) in cs.matvec(&beta).iter().zip(&dense_std.matvec(&beta)) {
            assert!((a - b).abs() < 1e-12, "matvec {a} vs {b}");
        }
        for (a, b) in cs.t_matvec(&r).iter().zip(&dense_std.t_matvec(&r)) {
            assert!((a - b).abs() < 1e-12, "t_matvec {a} vs {b}");
        }
        let mut par = vec![9.0; 7];
        cs.t_matvec_par_into(&r, 3, &mut par);
        for (a, b) in par.iter().zip(&cs.t_matvec(&r)) {
            assert!((a - b).abs() < 1e-14, "par t_matvec");
        }
        for (a, b) in cs.col_norms().iter().zip(&dense_std.col_norms()) {
            assert!((a - b).abs() < 1e-12, "col norm {a} vs {b}");
        }
        for m in cs.col_means() {
            assert!(m.abs() < 1e-12, "implied mean {m}");
        }
        let (est_s, est_d) = (cs.op_norm_sq_est(60, 7), dense_std.op_norm_sq_est(60, 7));
        assert!((est_s - est_d).abs() < 1e-6 * (1.0 + est_d), "{est_s} vs {est_d}");
    }

    #[test]
    fn centered_sparse_gather_rows_matches_dense() {
        let (_, csc) = sparse_fixture();
        let cs = CenteredSparse::from_csc(&csc);
        let dense_std = cs.to_dense();
        for rows in [vec![0usize, 3, 7, 12], vec![5, 1, 1, 9]] {
            let got = cs.gather_rows(&rows).to_dense();
            let want = dense_std.gather_rows(&rows);
            for j in 0..7 {
                for i in 0..rows.len() {
                    assert!(
                        (got.get(i, j) - want.get(i, j)).abs() < 1e-12,
                        "rows {rows:?}, entry ({i}, {j})"
                    );
                }
            }
        }
    }

    #[test]
    fn centered_sparse_restandardize_matches_dense() {
        // Gather fold rows, then re-standardize: the sparse affine
        // recomposition must track the dense two-pass standardization of
        // the same implied rows (the CV fold-plan contract).
        let (_, csc) = sparse_fixture();
        let cs = CenteredSparse::from_csc(&csc);
        let rows: Vec<usize> = (0..13).filter(|i| i % 3 != 0).collect();
        let mut sub_sparse = cs.gather_rows(&rows);
        let mut sub_dense = cs.to_dense().gather_rows(&rows);
        let got_centers = sub_sparse.standardize_l2();
        let want_centers = sub_dense.standardize_l2();
        for j in 0..7 {
            let ((gm, gs), (wm, ws)) = (got_centers[j], want_centers[j]);
            assert!((gm - wm).abs() < 1e-10, "col {j} mean {gm} vs {wm}");
            assert!((gs - ws).abs() < 1e-10, "col {j} scale {gs} vs {ws}");
        }
        let got = sub_sparse.to_dense();
        for j in 0..7 {
            for i in 0..rows.len() {
                assert!(
                    (got.get(i, j) - sub_dense.get(i, j)).abs() < 1e-10,
                    "entry ({i}, {j})"
                );
            }
        }
    }

    #[test]
    fn reduced_design_serves_sparse_sources() {
        let (_, csc) = sparse_fixture();
        let cs = CenteredSparse::from_csc(&csc);
        let dense_std = cs.to_dense();
        let mut rd = ReducedDesign::new();
        for idx in [
            vec![0usize, 2, 4],
            vec![0, 2, 5, 6], // shares the [0, 2] prefix
            vec![0, 2, 5, 6], // identical → cache hit
            vec![1, 3],       // no shared prefix → rebuild
        ] {
            let got = match rd.update(&cs, &idx) {
                DesignRef::Sparse(s) => s.to_dense(),
                DesignRef::Dense(_) => panic!("sparse source produced a dense gather"),
            };
            let want = dense_std.gather_columns(&idx);
            assert_eq!(got, want, "idx {idx:?}");
            assert_eq!(rd.indices(), idx.as_slice());
        }
        assert_eq!(rd.hits, 1);
        assert!(rd.kept_cols >= 2, "sparse prefix reuse never happened");
        // Switching to a dense source invalidates and serves dense.
        let got = rd.update(&dense_std, &[1, 3]).as_dense().unwrap().clone();
        assert_eq!(got, dense_std.gather_columns(&[1, 3]));
    }

    #[test]
    fn dense_materialization_counter_ticks_on_densify_only() {
        let (_, csc) = sparse_fixture();
        let cs = CenteredSparse::from_csc(&csc);
        let before = dense_materializations();
        let mut out = vec![0.0; 13];
        cs.matvec_into(&[0.1; 7], &mut out);
        cs.t_matvec(&[0.1; 13]);
        cs.col_norms();
        assert_eq!(dense_materializations(), before, "kernels must not densify");
        let _ = cs.to_dense();
        let _ = csc.to_standardized_dense();
        assert_eq!(dense_materializations(), before + 2);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f64> = (0..7).map(|i| i as f64).collect();
        assert_eq!(dot(&a, &a), 91.0);
    }

    #[test]
    fn l2_distance_zero_iff_equal() {
        let a = [1.0, 2.0];
        assert_eq!(l2_distance(&a, &a), 0.0);
        assert!((l2_distance(&a, &[1.0, 4.0]) - 2.0).abs() < 1e-15);
    }
}
