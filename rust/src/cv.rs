//! K-fold cross-validation for SGL / aSGL (Appendix D.7).
//!
//! The paper's motivation for DFR includes making *joint* tuning of
//! `(λ, α)` — and `(γ₁, γ₂)` for aSGL — computationally feasible. The
//! driver fits the full λ path per fold (warm-started, screened), scores
//! held-out deviance, and supports a grid over α / γ with fold-level
//! thread parallelism.

use crate::data::{Dataset, Response};
use crate::loss::sigmoid;
use crate::metrics::Accumulator;
use crate::path::{PathConfig, PathRunner};
use crate::rng::Rng;
use crate::screen::RuleKind;

/// One grid cell result.
#[derive(Clone, Debug)]
pub struct CvCell {
    pub alpha: f64,
    pub gamma: Option<(f64, f64)>,
    /// Mean held-out loss per path point (length = path_len).
    pub cv_loss: Vec<f64>,
    pub lambdas: Vec<f64>,
    /// Index of the best λ.
    pub best_idx: usize,
    pub seconds: f64,
}

/// Cross-validation configuration.
#[derive(Clone, Debug)]
pub struct CvConfig {
    pub folds: usize,
    pub path: PathConfig,
    pub rule: RuleKind,
    pub seed: u64,
    pub threads: usize,
}

impl Default for CvConfig {
    fn default() -> Self {
        CvConfig {
            folds: 10,
            path: PathConfig::default(),
            rule: RuleKind::DfrSgl,
            seed: 7,
            threads: crate::parallel::default_threads(),
        }
    }
}

/// Split `n` observations into `k` folds (shuffled, near-equal).
pub fn fold_assignments(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    let perm = rng.permutation(n);
    let mut fold = vec![0usize; n];
    for (pos, &i) in perm.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Held-out prediction loss of a coefficient vector.
fn holdout_loss(ds: &Dataset, beta: &[f64]) -> f64 {
    let xb = ds.x.matvec(beta);
    let n = ds.y.len() as f64;
    match ds.response {
        Response::Linear => {
            xb.iter().zip(&ds.y).map(|(p, y)| (y - p) * (y - p)).sum::<f64>() / n
        }
        Response::Logistic => {
            // mean deviance
            xb.iter()
                .zip(&ds.y)
                .map(|(&eta, &y)| {
                    let p = sigmoid(eta).clamp(1e-12, 1.0 - 1e-12);
                    -(y * p.ln() + (1.0 - y) * (1.0 - p).ln())
                })
                .sum::<f64>()
                / n
        }
    }
}

/// Run k-fold CV at one (α, γ) setting. λ path is fixed from the full-data
/// fit so folds are comparable.
pub fn cross_validate(ds: &Dataset, cfg: &CvConfig) -> anyhow::Result<CvCell> {
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let folds = fold_assignments(ds.n(), cfg.folds, &mut rng);

    // Reference λ path from the full data.
    let full_fit = PathRunner::new(ds, cfg.path.clone()).rule(cfg.rule).run()?;
    let lambdas = full_fit.lambdas.clone();
    let l = lambdas.len();

    let fold_losses: Vec<Vec<f64>> = crate::parallel::par_map(cfg.folds, cfg.threads, |f| {
        let train_rows: Vec<usize> =
            (0..ds.n()).filter(|&i| folds[i] != f).collect();
        let test_rows: Vec<usize> = (0..ds.n()).filter(|&i| folds[i] == f).collect();
        let mut train = ds.subset_rows(&train_rows);
        train.standardize();
        let test = ds.subset_rows(&test_rows);
        let fit = PathRunner::new(&train, cfg.path.clone())
            .rule(cfg.rule)
            .fixed_path(lambdas.clone())
            .run()
            .expect("fold fit failed");
        fit.betas.iter().map(|b| holdout_loss(&test, b)).collect()
    });

    let mut cv_loss = vec![0.0; l];
    for fl in &fold_losses {
        for (i, v) in fl.iter().enumerate() {
            cv_loss[i] += v / cfg.folds as f64;
        }
    }
    let best_idx = cv_loss
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);

    Ok(CvCell {
        alpha: cfg.path.alpha,
        gamma: cfg.path.adaptive,
        cv_loss,
        lambdas,
        best_idx,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Grid search over α (and γ for aSGL): returns every cell plus the winner.
pub fn grid_search(
    ds: &Dataset,
    base: &CvConfig,
    alphas: &[f64],
    gammas: &[Option<(f64, f64)>],
) -> anyhow::Result<(Vec<CvCell>, usize)> {
    let mut cells = Vec::new();
    for &alpha in alphas {
        for &gamma in gammas {
            let mut cfg = base.clone();
            cfg.path.alpha = alpha;
            cfg.path.adaptive = gamma;
            cells.push(cross_validate(ds, &cfg)?);
        }
    }
    let best = cells
        .iter()
        .enumerate()
        .min_by(|a, b| {
            a.1.cv_loss[a.1.best_idx].partial_cmp(&b.1.cv_loss[b.1.best_idx]).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok((cells, best))
}

/// Paired CV timing: screened vs no-screen, as in Table A36.
pub fn cv_improvement_factor(ds: &Dataset, cfg: &CvConfig) -> anyhow::Result<(f64, f64, f64)> {
    let mut acc_if = Accumulator::new();
    let screened = cross_validate(ds, cfg)?;
    let mut no_cfg = cfg.clone();
    no_cfg.rule = RuleKind::NoScreen;
    let unscreened = cross_validate(ds, &no_cfg)?;
    acc_if.push(unscreened.seconds / screened.seconds.max(1e-12));
    Ok((acc_if.mean(), screened.seconds, unscreened.seconds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn data() -> Dataset {
        SyntheticConfig {
            n: 60,
            p: 40,
            groups: crate::data::synthetic::GroupSpec::Even(8),
            ..SyntheticConfig::default()
        }
        .generate(3)
        .dataset
    }

    #[test]
    fn folds_are_balanced_and_cover() {
        let mut rng = Rng::new(1);
        let f = fold_assignments(103, 10, &mut rng);
        assert_eq!(f.len(), 103);
        for k in 0..10 {
            let c = f.iter().filter(|&&x| x == k).count();
            assert!((10..=11).contains(&c), "fold {k} has {c}");
        }
    }

    #[test]
    fn cv_picks_interior_lambda_on_signal_data() {
        let ds = data();
        let cfg = CvConfig {
            folds: 4,
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            threads: 2,
            ..CvConfig::default()
        };
        let cell = cross_validate(&ds, &cfg).unwrap();
        assert_eq!(cell.cv_loss.len(), 10);
        // With real signal the best λ should not be the null model.
        assert!(cell.best_idx > 0, "best_idx {}", cell.best_idx);
        assert!(cell.cv_loss.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn grid_search_returns_all_cells() {
        let ds = data();
        let cfg = CvConfig {
            folds: 3,
            path: PathConfig { path_len: 6, ..PathConfig::default() },
            threads: 2,
            ..CvConfig::default()
        };
        let (cells, best) =
            grid_search(&ds, &cfg, &[0.5, 0.95], &[None, Some((0.1, 0.1))]).unwrap();
        assert_eq!(cells.len(), 4);
        assert!(best < 4);
    }
}
