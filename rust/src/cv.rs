//! Workspace-pooled K-fold cross-validation for SGL / aSGL (Appendix D.7).
//!
//! The paper's motivation for DFR includes making *joint* tuning of
//! `(λ, α)` — and `(γ₁, γ₂)` for aSGL — computationally feasible. This
//! module is the CV engine behind that claim, organized around three ideas:
//!
//! 1. **Shared fold plans.** Fold splits and the standardized per-fold
//!    training datasets depend only on `(dataset, folds, seed)`, so a
//!    [`FoldPlan`] is computed once and shared read-only by every `(α, γ)`
//!    grid cell, instead of being rebuilt per cell. Adaptive weights, which
//!    depend only on the fold design and `γ`, are likewise computed once
//!    per `(γ, fold)` pair and shared across all α values.
//! 2. **Workspace pooling.** All path fits — the per-cell full-data
//!    reference fits that pin each cell's λ grid, and every fold fit — run
//!    through a [`crate::parallel::WorkspacePool`] of persistent
//!    [`PathWorkspace`]s, one per worker thread, reused across folds, grid
//!    cells, and repeated [`CvEngine`] invocations. After warm-up the CV
//!    hot loop allocates no per-fold path workspaces; the
//!    [`crate::linalg::ReducedDesign`] gather cache inside each workspace
//!    fingerprints its source matrix, so carrying one workspace across
//!    different folds is safe.
//! 3. **Grid-flattened scheduling.** The fold fits of *all* cells are
//!    flattened into `(cell × fold)` task units and pulled from one shared
//!    queue, so parallelism scales with the whole grid rather than capping
//!    at the fold count, while warm-starting along each cell's λ path is
//!    preserved (it lives inside the per-task path fit).
//!
//! [`grid_search_reference`] keeps the per-cell fresh-allocation semantics
//! (re-split, re-standardize, fresh workspaces, per-fit adaptive weights)
//! as the correctness/pricing baseline; `rust/tests/cv_equivalence.rs`
//! proves the pooled engine matches it to ℓ₂ ≤ 1e-10.

use crate::data::{Dataset, Response};
use crate::loss::sigmoid;
use crate::metrics::Accumulator;
use crate::parallel::WorkspacePool;
use crate::path::{PathConfig, PathRunner, PathWorkspace};
use crate::penalty::AdaptiveWeights;
use crate::rng::Rng;
use crate::screen::RuleKind;
use crate::solver::SolveStatus;

/// One `(α, γ)` grid cell result.
#[derive(Clone, Debug)]
pub struct CvCell {
    /// SGL mixing parameter of this cell.
    pub alpha: f64,
    /// Adaptive-weight exponents `(γ₁, γ₂)` of this cell; `None` = plain SGL.
    pub gamma: Option<(f64, f64)>,
    /// Mean held-out loss per path point (length = path length).
    pub cv_loss: Vec<f64>,
    /// Standard error of the fold losses per path point (sample standard
    /// deviation across folds divided by √folds; zero for a single fold).
    pub cv_se: Vec<f64>,
    /// The cell's λ grid, fixed from its full-data reference fit so folds
    /// are comparable.
    pub lambdas: Vec<f64>,
    /// Index of the CV-optimal λ.
    pub best_idx: usize,
    /// One-standard-error rule: index of the largest λ (sparsest model)
    /// whose CV loss is within one standard error of the minimum.
    pub best_1se_idx: usize,
    /// Mean screened candidate-set size `C_v / p` across fold fits — the
    /// per-cell screening-reduction statistic.
    pub mean_candidate_proportion: f64,
    /// Mean optimization-set size `O_v / p` across fold fits.
    pub mean_input_proportion: f64,
    /// Fit seconds attributed to this cell. For a single-cell
    /// [`cross_validate`] this is the wall-clock time of the whole CV; for
    /// grid cells (whose fold fits interleave with other cells on the
    /// shared task queue) it is the summed fit time of the cell's
    /// reference fit plus its fold fits.
    pub seconds: f64,
    /// Worst solve status across the cell's reference fit and every fold
    /// fit at every path point ([`SolveStatus::Converged`] when all clean).
    pub status: SolveStatus,
}

/// Cross-validation configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct CvConfig {
    /// Number of folds (k).
    pub folds: usize,
    /// Pathwise fit settings shared by the reference and fold fits. The
    /// `alpha` / `adaptive` fields are the grid-cell coordinates; grid
    /// searches override them per cell. `path.solver.kind` picks the
    /// inner solver (FISTA / ATOS / BCD) every fold and grid cell
    /// dispatches through the [`crate::solver::Solver`] trait.
    pub path: PathConfig,
    /// Screening rule applied to every fit.
    pub rule: RuleKind,
    /// Seed for the fold split.
    pub seed: u64,
    /// Worker threads used by the convenience functions
    /// ([`cross_validate`], [`grid_search`], [`cv_improvement_factor`])
    /// when they construct their transient [`CvEngine`]. A caller-held
    /// engine uses its own thread count instead.
    pub threads: usize,
}

impl Default for CvConfig {
    fn default() -> Self {
        CvConfig {
            folds: 10,
            path: PathConfig::default(),
            rule: RuleKind::DfrSgl,
            seed: 7,
            threads: crate::parallel::default_threads(),
        }
    }
}

/// Split `n` observations into `k` folds (shuffled, near-equal).
pub fn fold_assignments(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    let perm = rng.permutation(n);
    let mut fold = vec![0usize; n];
    for (pos, &i) in perm.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// One fold's data: standardized training subset, raw held-out subset,
/// and the standardization parameters linking the two scales.
///
/// Scoring contract: fold fits run on the *re-standardized* training
/// subset, so their coefficients live on the fold-train scale. Before a
/// held-out row (kept on the **parent dataset's** scale) is scored,
/// [`CvFold::holdout_loss`] maps the coefficients back through this
/// fold's `(mean, scale)` pairs and intercept — exactly the
/// unstandardization `model_api` applies to final fits — so CV losses are
/// genuine parent-scale prediction errors even when the parent is not
/// itself standardized.
#[derive(Clone, Debug)]
pub struct CvFold {
    /// Training rows (all observations outside the fold), standardized.
    pub train: Dataset,
    /// Held-out rows, on the scale of the parent dataset.
    pub test: Dataset,
    /// Per-column `(mean, scale)` of the training-subset standardization.
    pub centers: Vec<(f64, f64)>,
    /// Mean of the raw (parent-scale) training response — the intercept
    /// base for linear models (0 for logistic, whose response is never
    /// centered).
    pub train_y_mean: f64,
}

impl CvFold {
    /// Held-out loss of fold-train-standardized coefficients, scored on
    /// the parent scale: `β_raw_j = β_j / s_j`, intercept
    /// `ȳ_train − Σ β_j m_j / s_j` (linear) or `−Σ β_j m_j / s_j`
    /// (logistic), then mean squared error / mean deviance over the raw
    /// test rows.
    pub fn holdout_loss(&self, beta_std: &[f64]) -> f64 {
        let ds = &self.test;
        assert_eq!(beta_std.len(), self.centers.len());
        let mut shift = 0.0;
        let beta_raw: Vec<f64> = beta_std
            .iter()
            .zip(&self.centers)
            .map(|(&b, &(m, s))| {
                shift += b * m / s;
                b / s
            })
            .collect();
        let intercept = match ds.response {
            Response::Linear => self.train_y_mean - shift,
            Response::Logistic => -shift,
        };
        let mut eta = ds.x.matvec(&beta_raw);
        eta.iter_mut().for_each(|e| *e += intercept);
        let n = ds.y.len() as f64;
        match ds.response {
            Response::Linear => {
                eta.iter().zip(&ds.y).map(|(p, y)| (y - p) * (y - p)).sum::<f64>() / n
            }
            Response::Logistic => {
                // mean deviance
                eta.iter()
                    .zip(&ds.y)
                    .map(|(&e, &y)| {
                        let p = sigmoid(e).clamp(1e-12, 1.0 - 1e-12);
                        -(y * p.ln() + (1.0 - y) * (1.0 - p).ln())
                    })
                    .sum::<f64>()
                    / n
            }
        }
    }
}

/// The dataset-level part of a CV run: fold assignments plus the
/// standardized per-fold training sets, computed **once** per
/// `(dataset, folds, seed)` and shared read-only across every grid cell.
#[derive(Clone, Debug)]
pub struct FoldPlan {
    /// `assignments[i]` = fold index of observation `i`.
    pub assignments: Vec<usize>,
    /// Per-fold train/test datasets.
    pub folds: Vec<CvFold>,
}

impl FoldPlan {
    /// Split and standardize. Matches the per-cell splits of
    /// [`grid_search_reference`] exactly (same RNG stream, same
    /// standardization), which is what makes shared plans a pure
    /// de-duplication rather than a behavior change.
    ///
    /// Kernel variants survive the split: a centered-sparse parent design
    /// produces centered-sparse fold training/test sets (row-gathered raw
    /// nonzeros, re-standardized by affine recomposition) — the sparse
    /// solve path never densifies inside CV.
    pub fn new(ds: &Dataset, folds: usize, seed: u64) -> anyhow::Result<FoldPlan> {
        anyhow::ensure!(folds >= 2, "need at least 2 folds, got {folds}");
        anyhow::ensure!(
            folds <= ds.n(),
            "more folds ({folds}) than observations ({})",
            ds.n()
        );
        let mut rng = Rng::new(seed);
        let assignments = fold_assignments(ds.n(), folds, &mut rng);
        let folds = (0..folds)
            .map(|f| {
                let train_rows: Vec<usize> =
                    (0..ds.n()).filter(|&i| assignments[i] != f).collect();
                let test_rows: Vec<usize> =
                    (0..ds.n()).filter(|&i| assignments[i] == f).collect();
                let mut train = ds.subset_rows(&train_rows);
                // Standardize inline (rather than Dataset::standardize) so
                // the (mean, scale) pairs and raw-y mean survive for the
                // raw-scale held-out scoring in CvFold::holdout_loss.
                let train_y_mean = if train.response == Response::Linear {
                    let m = train.y.iter().sum::<f64>() / train.y.len() as f64;
                    train.y.iter_mut().for_each(|v| *v -= m);
                    m
                } else {
                    0.0
                };
                let centers = train.x.standardize_l2();
                let test = ds.subset_rows(&test_rows);
                CvFold { train, test, centers, train_y_mean }
            })
            .collect();
        Ok(FoldPlan { assignments, folds })
    }
}

/// One `(α, γ)` coordinate of a CV grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GridPoint {
    /// SGL mixing parameter.
    pub alpha: f64,
    /// Adaptive exponents; `None` = plain SGL (unless the rule forces aSGL).
    pub gamma: Option<(f64, f64)>,
}

/// Per-fold fit outcome carried from the flattened scheduler to the
/// per-cell reduction.
struct FoldFit {
    /// Held-out loss at each path point.
    losses: Vec<f64>,
    /// Mean `C_v / p` over the fit's path points.
    c_prop: f64,
    /// Mean `O_v / p` over the fit's path points.
    o_prop: f64,
    /// Fit wall-clock seconds.
    seconds: f64,
    /// Worst per-point solve status of the fit.
    status: SolveStatus,
}

/// Fold-order reduction of one cell; shared by the pooled engine and the
/// reference implementation so their outputs are bit-comparable.
fn reduce_cell(
    point: GridPoint,
    lambdas: Vec<f64>,
    fold_fits: &[FoldFit],
    seconds: f64,
    ref_status: SolveStatus,
) -> CvCell {
    let k = fold_fits.len();
    let l = lambdas.len();
    let mut cv_loss = vec![0.0; l];
    for ff in fold_fits {
        for (i, v) in ff.losses.iter().enumerate() {
            cv_loss[i] += v / k as f64;
        }
    }
    let mut cv_se = vec![0.0; l];
    if k > 1 {
        for (i, se) in cv_se.iter_mut().enumerate() {
            let var = fold_fits
                .iter()
                .map(|ff| {
                    let d = ff.losses[i] - cv_loss[i];
                    d * d
                })
                .sum::<f64>()
                / (k - 1) as f64;
            *se = (var / k as f64).sqrt();
        }
    }
    let best_idx = cv_loss
        .iter()
        .enumerate()
        // total_cmp: a NaN fold loss sorts high instead of panicking.
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    // λ grid is sorted descending, so the first index within one SE of the
    // minimum is the sparsest acceptable model.
    let threshold = cv_loss.get(best_idx).copied().unwrap_or(0.0)
        + cv_se.get(best_idx).copied().unwrap_or(0.0);
    let best_1se_idx = cv_loss
        .iter()
        .position(|&v| v <= threshold)
        .unwrap_or(best_idx);
    let mean = |f: &dyn Fn(&FoldFit) -> f64| {
        if k == 0 {
            0.0
        } else {
            fold_fits.iter().map(|ff| f(ff)).sum::<f64>() / k as f64
        }
    };
    CvCell {
        alpha: point.alpha,
        gamma: point.gamma,
        cv_loss,
        cv_se,
        lambdas,
        best_idx,
        best_1se_idx,
        mean_candidate_proportion: mean(&|ff| ff.c_prop),
        mean_input_proportion: mean(&|ff| ff.o_prop),
        seconds,
        status: fold_fits.iter().fold(ref_status, |s, ff| s.worst(ff.status)),
    }
}

/// Index of the winning cell: minimal CV loss at each cell's own best λ.
fn winner(cells: &[CvCell]) -> usize {
    cells
        .iter()
        .enumerate()
        .min_by(|a, b| {
            // total_cmp: a NaN cell loss sorts high instead of panicking.
            a.1.cv_loss[a.1.best_idx].total_cmp(&b.1.cv_loss[b.1.best_idx])
        })
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// The workspace-pooled CV engine.
///
/// Owns a [`WorkspacePool`] of [`PathWorkspace`]s (one slot per worker
/// thread) that persists across every method call, so repeated
/// cross-validations — a bench loop, a model-selection sweep, a grid
/// search per dataset — pay for workspace allocation exactly once. The
/// engine is cheap to construct but the pool only amortizes if you hold
/// on to it; the free functions in this module build a transient engine
/// per call (pooled within the call, not across calls).
pub struct CvEngine {
    threads: usize,
    pool: WorkspacePool<PathWorkspace>,
}

impl CvEngine {
    /// Engine with `threads` workers and as many pooled workspaces.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        CvEngine { threads, pool: WorkspacePool::new(threads) }
    }

    /// Engine sized by [`crate::parallel::default_threads`].
    pub fn with_default_threads() -> Self {
        Self::new(crate::parallel::default_threads())
    }

    /// Worker-thread count (= pool slots).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of path workspaces ever allocated by this engine. Stays at
    /// [`CvEngine::threads`] no matter how many folds/cells/invocations
    /// run — the bench acceptance signal for "no per-fold allocation".
    pub fn pool_slots(&self) -> usize {
        self.pool.slots()
    }

    /// Total workspace checkouts served (reference fits + fold fits).
    pub fn pool_checkouts(&self) -> usize {
        self.pool.checkouts()
    }

    /// Run k-fold CV at one `(α, γ)` setting (taken from `cfg.path`). The
    /// λ path is fixed from the full-data fit so folds are comparable.
    pub fn cross_validate(&self, ds: &Dataset, cfg: &CvConfig) -> anyhow::Result<CvCell> {
        let t0 = std::time::Instant::now();
        let plan = FoldPlan::new(ds, cfg.folds, cfg.seed)?;
        let point = GridPoint { alpha: cfg.path.alpha, gamma: cfg.path.adaptive };
        let mut cells = self.run_grid(ds, &plan, cfg, &[point])?;
        let mut cell = match cells.pop() {
            Some(c) => c,
            None => anyhow::bail!("single-point grid produced no cell"),
        };
        cell.seconds = t0.elapsed().as_secs_f64();
        Ok(cell)
    }

    /// Grid search over α (and γ for aSGL): returns every cell plus the
    /// index of the winner. Cells are ordered α-major (`alphas[0]` with
    /// every γ first), matching [`grid_search_reference`].
    pub fn grid_search(
        &self,
        ds: &Dataset,
        base: &CvConfig,
        alphas: &[f64],
        gammas: &[Option<(f64, f64)>],
    ) -> anyhow::Result<(Vec<CvCell>, usize)> {
        anyhow::ensure!(!alphas.is_empty(), "empty α grid");
        anyhow::ensure!(!gammas.is_empty(), "empty γ grid");
        let grid: Vec<GridPoint> = alphas
            .iter()
            .flat_map(|&alpha| gammas.iter().map(move |&gamma| GridPoint { alpha, gamma }))
            .collect();
        let plan = FoldPlan::new(ds, base.folds, base.seed)?;
        let cells = self.run_grid(ds, &plan, base, &grid)?;
        let best = winner(&cells);
        Ok((cells, best))
    }

    /// The scheduler: per-cell reference fits, then all `(cell × fold)`
    /// fits flattened onto one task queue, every fit drawing a pooled
    /// workspace.
    fn run_grid(
        &self,
        ds: &Dataset,
        plan: &FoldPlan,
        base: &CvConfig,
        grid: &[GridPoint],
    ) -> anyhow::Result<Vec<CvCell>> {
        let k = plan.folds.len();

        // Adaptive weights depend only on (design, γ): compute each
        // distinct γ once for the full data and once per fold, shared by
        // every α cell, instead of once per (cell × fold) fit. The γ a
        // cell actually fits with comes from PathConfig::resolve_adaptive
        // — the same decision build_penalty makes.
        let mut gammas: Vec<(f64, f64)> = Vec::new();
        for gp in grid {
            if let Some(g) = PathConfig::resolve_adaptive(gp.gamma, base.rule) {
                if !gammas.iter().any(|&x| x == g) {
                    gammas.push(g);
                }
            }
        }
        // Flattened (γ × {full, fold₀..fold_{k−1}}) batch so the PCA power
        // iterations behind the weights run on the worker pool too, not
        // serially ahead of it.
        let per = k + 1;
        let weight_batch = crate::parallel::par_map(gammas.len() * per, self.threads, |t| {
            let (g1, g2) = gammas[t / per];
            match t % per {
                0 => AdaptiveWeights::from_design(&ds.x, &ds.groups, g1, g2),
                j => {
                    let f = &plan.folds[j - 1];
                    AdaptiveWeights::from_design(&f.train.x, &f.train.groups, g1, g2)
                }
            }
        });
        let mut batch_iter = weight_batch.into_iter();
        let mut shared_weights: Vec<(AdaptiveWeights, Vec<AdaptiveWeights>)> =
            Vec::with_capacity(gammas.len());
        for _ in 0..gammas.len() {
            let full = match batch_iter.next() {
                Some(w) => w,
                None => anyhow::bail!("weight batch underrun"),
            };
            let mut per_fold = Vec::with_capacity(k);
            for _ in 0..k {
                match batch_iter.next() {
                    Some(w) => per_fold.push(w),
                    None => anyhow::bail!("weight batch underrun"),
                }
            }
            shared_weights.push((full, per_fold));
        }
        // The position lookup cannot miss (every resolved γ was pushed
        // above); `and_then` degrades an impossible miss to per-fit weight
        // recomputation instead of a panic.
        let gamma_slot = |gp: &GridPoint| {
            PathConfig::resolve_adaptive(gp.gamma, base.rule)
                .and_then(|g| gammas.iter().position(|&x| x == g))
        };

        // Stage 1 — each cell's reference λ path from the full data.
        let refs = crate::parallel::par_map(grid.len(), self.threads, |c| {
            let gp = &grid[c];
            let mut cfg = base.path.clone();
            cfg.alpha = gp.alpha;
            cfg.adaptive = gp.gamma;
            let mut runner = PathRunner::new(ds, cfg).rule(base.rule);
            if let Some(gi) = gamma_slot(gp) {
                runner = runner.weights(shared_weights[gi].0.clone());
            }
            let mut ws = self.pool.checkout();
            let fit = runner
                .run_with_workspace(&mut ws)
                .map_err(|e| anyhow::anyhow!("cell {c} reference path fit failed: {e}"))?;
            Ok::<(Vec<f64>, f64, SolveStatus), anyhow::Error>((
                fit.lambdas,
                fit.metrics.total_seconds,
                fit.metrics.worst_status(),
            ))
        });
        let mut lambdas: Vec<Vec<f64>> = Vec::with_capacity(grid.len());
        let mut ref_seconds: Vec<f64> = Vec::with_capacity(grid.len());
        let mut ref_status: Vec<SolveStatus> = Vec::with_capacity(grid.len());
        for r in refs {
            let (l, s, st) = r?;
            lambdas.push(l);
            ref_seconds.push(s);
            ref_status.push(st);
        }

        // Stage 2 — flattened (cell × fold) fits on one shared queue.
        let fold_results = crate::parallel::par_map(grid.len() * k, self.threads, |t| {
            let (c, f) = (t / k, t % k);
            let gp = &grid[c];
            let fold = &plan.folds[f];
            let mut cfg = base.path.clone();
            cfg.alpha = gp.alpha;
            cfg.adaptive = gp.gamma;
            let mut runner = PathRunner::new(&fold.train, cfg)
                .rule(base.rule)
                .fixed_path(lambdas[c].clone());
            if let Some(gi) = gamma_slot(gp) {
                runner = runner.weights(shared_weights[gi].1[f].clone());
            }
            let mut ws = self.pool.checkout();
            let fit = runner
                .run_with_workspace(&mut ws)
                .map_err(|e| anyhow::anyhow!("cell {c} fold {f} fit failed: {e}"))?;
            let m = &fit.metrics;
            Ok::<FoldFit, anyhow::Error>(FoldFit {
                losses: fit.betas.iter().map(|b| fold.holdout_loss(b)).collect(),
                c_prop: m.candidate_proportion(),
                o_prop: m.input_proportion(),
                seconds: m.total_seconds,
                status: m.worst_status(),
            })
        });
        let mut fold_fits: Vec<FoldFit> = Vec::with_capacity(grid.len() * k);
        for r in fold_results {
            fold_fits.push(r?);
        }

        // Stage 3 — per-cell reduction, fold order preserved.
        let cells = grid
            .iter()
            .enumerate()
            .map(|(c, &gp)| {
                let ffs = &fold_fits[c * k..(c + 1) * k];
                let seconds =
                    ref_seconds[c] + ffs.iter().map(|ff| ff.seconds).sum::<f64>();
                reduce_cell(gp, std::mem::take(&mut lambdas[c]), ffs, seconds, ref_status[c])
            })
            .collect();
        Ok(cells)
    }
}

/// Run k-fold CV at one `(α, γ)` setting with a transient [`CvEngine`]
/// (`cfg.threads` workers). Hold a [`CvEngine`] instead to amortize its
/// workspace pool across repeated calls.
pub fn cross_validate(ds: &Dataset, cfg: &CvConfig) -> anyhow::Result<CvCell> {
    CvEngine::new(cfg.threads).cross_validate(ds, cfg)
}

/// Grid search over α (and γ for aSGL) with a transient [`CvEngine`]:
/// returns every cell plus the winner index.
pub fn grid_search(
    ds: &Dataset,
    base: &CvConfig,
    alphas: &[f64],
    gammas: &[Option<(f64, f64)>],
) -> anyhow::Result<(Vec<CvCell>, usize)> {
    CvEngine::new(base.threads).grid_search(ds, base, alphas, gammas)
}

/// Per-cell fresh-allocation reference for the pooled grid search: every
/// cell re-splits the folds, re-standardizes its training data, recomputes
/// adaptive weights per fit, and every fit allocates private workspaces.
/// Slower by construction; exists so benches can price the pooled engine
/// and `rust/tests/cv_equivalence.rs` can prove it changes nothing.
pub fn grid_search_reference(
    ds: &Dataset,
    base: &CvConfig,
    alphas: &[f64],
    gammas: &[Option<(f64, f64)>],
) -> anyhow::Result<(Vec<CvCell>, usize)> {
    anyhow::ensure!(!alphas.is_empty(), "empty α grid");
    anyhow::ensure!(!gammas.is_empty(), "empty γ grid");
    let mut cells = Vec::new();
    for &alpha in alphas {
        for &gamma in gammas {
            let mut cfg = base.clone();
            cfg.path.alpha = alpha;
            cfg.path.adaptive = gamma;
            let t0 = std::time::Instant::now();
            // Per-cell split and standardization (the redundancy the
            // shared FoldPlan removes — byte-identical results).
            let plan = FoldPlan::new(ds, cfg.folds, cfg.seed)?;
            let full_fit =
                PathRunner::new(ds, cfg.path.clone()).rule(cfg.rule).run()?;
            let lambdas = full_fit.lambdas.clone();
            let results = crate::parallel::par_map(plan.folds.len(), cfg.threads, |f| {
                let fold = &plan.folds[f];
                let fit = PathRunner::new(&fold.train, cfg.path.clone())
                    .rule(cfg.rule)
                    .fixed_path(lambdas.clone())
                    .run()
                    .map_err(|e| anyhow::anyhow!("fold {f} fit failed: {e}"))?;
                let m = &fit.metrics;
                Ok::<FoldFit, anyhow::Error>(FoldFit {
                    losses: fit.betas.iter().map(|b| fold.holdout_loss(b)).collect(),
                    c_prop: m.candidate_proportion(),
                    o_prop: m.input_proportion(),
                    seconds: m.total_seconds,
                    status: m.worst_status(),
                })
            });
            let mut fold_fits = Vec::with_capacity(plan.folds.len());
            for r in results {
                fold_fits.push(r?);
            }
            let point = GridPoint { alpha, gamma };
            cells.push(reduce_cell(
                point,
                full_fit.lambdas,
                &fold_fits,
                t0.elapsed().as_secs_f64(),
                full_fit.metrics.worst_status(),
            ));
        }
    }
    let best = winner(&cells);
    Ok((cells, best))
}

/// Paired CV timing: screened vs no-screen, as in Table A36. Both timed
/// runs share one engine and see a warm workspace pool — the untimed
/// warm-up runs at *no-screen* sizes, growing every buffer to its
/// maximum (screened problems are strictly smaller) — so the comparison
/// prices screening, not allocation order.
pub fn cv_improvement_factor(ds: &Dataset, cfg: &CvConfig) -> anyhow::Result<(f64, f64, f64)> {
    let engine = CvEngine::new(cfg.threads);
    let mut no_cfg = cfg.clone();
    no_cfg.rule = RuleKind::NoScreen;
    engine.cross_validate(ds, &no_cfg)?;
    let mut acc_if = Accumulator::new();
    let screened = engine.cross_validate(ds, cfg)?;
    let unscreened = engine.cross_validate(ds, &no_cfg)?;
    acc_if.push(unscreened.seconds / screened.seconds.max(1e-12));
    Ok((acc_if.mean(), screened.seconds, unscreened.seconds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn data() -> Dataset {
        SyntheticConfig {
            n: 60,
            p: 40,
            groups: crate::data::synthetic::GroupSpec::Even(8),
            ..SyntheticConfig::default()
        }
        .generate(3)
        .dataset
    }

    #[test]
    fn folds_are_balanced_and_cover() {
        let mut rng = Rng::new(1);
        let f = fold_assignments(103, 10, &mut rng);
        assert_eq!(f.len(), 103);
        for k in 0..10 {
            let c = f.iter().filter(|&&x| x == k).count();
            assert!((10..=11).contains(&c), "fold {k} has {c}");
        }
    }

    #[test]
    fn fold_plan_partitions_and_standardizes() {
        let ds = data();
        let plan = FoldPlan::new(&ds, 4, 5).unwrap();
        assert_eq!(plan.folds.len(), 4);
        let total_test: usize = plan.folds.iter().map(|f| f.test.n()).sum();
        assert_eq!(total_test, ds.n());
        for fold in &plan.folds {
            assert_eq!(fold.train.n() + fold.test.n(), ds.n());
            // Training data is standardized: unit column norms.
            let norms = fold.train.x.col_norms();
            for nv in norms {
                assert!((nv - 1.0).abs() < 1e-8, "column norm {nv}");
            }
        }
    }

    #[test]
    fn holdout_loss_matches_per_row_unstandardization() {
        // Deliberately unstandardized parent: the fold scoring must map
        // coefficients back through the fold's (mean, scale) pairs.
        let mut rng = Rng::new(17);
        let x = crate::linalg::Matrix::from_fn(24, 5, |_, j| {
            3.0 * (j as f64 + 1.0) + 2.0 * rng.gauss()
        });
        let y: Vec<f64> = (0..24).map(|_| 5.0 + rng.gauss()).collect();
        let ds = Dataset {
            x: x.into(),
            y,
            groups: crate::groups::Groups::from_sizes(&[5]),
            response: Response::Linear,
            name: "raw".into(),
        };
        let plan = FoldPlan::new(&ds, 3, 9).unwrap();
        let fold = &plan.folds[0];
        let beta_std = [0.4, -0.2, 0.0, 1.1, -0.7];
        // Independent per-row computation of the raw-scale loss.
        let mut shift = 0.0;
        let mut beta_raw = [0.0; 5];
        for j in 0..5 {
            let (m, s) = fold.centers[j];
            beta_raw[j] = beta_std[j] / s;
            shift += beta_std[j] * m / s;
        }
        let intercept = fold.train_y_mean - shift;
        let mut want = 0.0;
        for i in 0..fold.test.n() {
            let eta: f64 = intercept
                + (0..5).map(|j| fold.test.x.dense().get(i, j) * beta_raw[j]).sum::<f64>();
            want += (fold.test.y[i] - eta) * (fold.test.y[i] - eta);
        }
        want /= fold.test.n() as f64;
        let got = fold.holdout_loss(&beta_std);
        // Matvec vs per-row summation order: tiny float slack allowed.
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn fold_plan_rejects_degenerate_splits() {
        let ds = data();
        assert!(FoldPlan::new(&ds, 1, 5).is_err());
        assert!(FoldPlan::new(&ds, ds.n() + 1, 5).is_err());
    }

    #[test]
    fn cv_picks_interior_lambda_on_signal_data() {
        let ds = data();
        let cfg = CvConfig {
            folds: 4,
            path: PathConfig { path_len: 10, ..PathConfig::default() },
            threads: 2,
            ..CvConfig::default()
        };
        let cell = cross_validate(&ds, &cfg).unwrap();
        assert_eq!(cell.cv_loss.len(), 10);
        assert_eq!(cell.cv_se.len(), 10);
        // With real signal the best λ should not be the null model.
        assert!(cell.best_idx > 0, "best_idx {}", cell.best_idx);
        assert!(cell.cv_loss.iter().all(|v| v.is_finite()));
        assert!(cell.cv_se.iter().all(|v| v.is_finite() && *v >= 0.0));
        // 1-SE never selects a denser (smaller-λ) model than the optimum.
        assert!(cell.best_1se_idx <= cell.best_idx);
        // Screening stats populated: the optimization set is non-trivial.
        assert!(cell.mean_input_proportion > 0.0);
        assert!(cell.mean_input_proportion <= 1.0 + 1e-12);
    }

    #[test]
    fn grid_search_returns_all_cells() {
        let ds = data();
        let cfg = CvConfig {
            folds: 3,
            path: PathConfig { path_len: 6, ..PathConfig::default() },
            threads: 2,
            ..CvConfig::default()
        };
        let (cells, best) =
            grid_search(&ds, &cfg, &[0.5, 0.95], &[None, Some((0.1, 0.1))]).unwrap();
        assert_eq!(cells.len(), 4);
        assert!(best < 4);
        // α-major cell order, mirroring grid_search_reference.
        assert_eq!(cells[0].alpha, 0.5);
        assert_eq!(cells[1].alpha, 0.5);
        assert_eq!(cells[1].gamma, Some((0.1, 0.1)));
        assert_eq!(cells[2].alpha, 0.95);
    }

    #[test]
    fn engine_pool_never_grows_across_invocations() {
        let ds = data();
        let cfg = CvConfig {
            folds: 3,
            path: PathConfig { path_len: 5, ..PathConfig::default() },
            threads: 2,
            ..CvConfig::default()
        };
        let engine = CvEngine::new(2);
        let first = engine.cross_validate(&ds, &cfg).unwrap();
        let second = engine.cross_validate(&ds, &cfg).unwrap();
        // Deterministic: repeated invocations on a warm pool are identical.
        assert_eq!(first.best_idx, second.best_idx);
        for (a, b) in first.cv_loss.iter().zip(&second.cv_loss) {
            assert_eq!(a, b, "warm-pool CV drifted");
        }
        assert_eq!(engine.pool_slots(), 2, "pool must not allocate per invocation");
        // 2 invocations × (1 reference fit + 3 fold fits) = 8 checkouts.
        assert_eq!(engine.pool_checkouts(), 8);
    }

    #[test]
    fn empty_grids_error_instead_of_panicking() {
        let ds = data();
        let cfg = CvConfig { folds: 3, threads: 1, ..CvConfig::default() };
        assert!(grid_search(&ds, &cfg, &[], &[None]).is_err());
        assert!(grid_search(&ds, &cfg, &[0.5], &[]).is_err());
    }
}
