//! Datasets: synthetic generators matching the paper's simulation designs
//! (§3.1, §D.2, Table A1), interaction expansion (Table 1), and surrogate
//! generators for the six real datasets of §4 / Table A37.

pub mod interactions;
pub mod real;
pub mod synthetic;

pub use interactions::InteractionOrder;
pub use synthetic::{GeneratedData, SyntheticConfig};

use crate::groups::Groups;
use crate::linalg::DesignOps;

/// Response family of a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    /// Continuous response — squared-error loss `(1/2n)‖y − Xβ‖²`.
    Linear,
    /// Binary response in {0, 1} — mean logistic deviance.
    Logistic,
}

/// A regression problem: standardized design, response, grouping.
///
/// The design is a [`DesignOps`] — dense for everything the in-crate
/// generators produce, centered-implicit sparse when a CSC input enters
/// through the model API's sparse solve path. Every layer above consumes
/// it through the [`crate::linalg::DesignRef`] kernel contract.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: DesignOps,
    pub y: Vec<f64>,
    pub groups: Groups,
    pub response: Response,
    /// Name used in reports.
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.nrows()
    }

    pub fn p(&self) -> usize {
        self.x.ncols()
    }

    pub fn m(&self) -> usize {
        self.groups.m()
    }

    /// ℓ₂-standardize the design in place (zero mean, unit column norm) and,
    /// for linear responses, center `y` (equivalent to an unpenalized
    /// intercept). Matches the paper's Table A1 algorithm settings.
    pub fn standardize(&mut self) {
        self.x.standardize_l2();
        if self.response == Response::Linear {
            let mean = self.y.iter().sum::<f64>() / self.y.len() as f64;
            self.y.iter_mut().for_each(|v| *v -= mean);
        }
    }

    /// Restrict to a subset of observations (CV folds).
    pub fn subset_rows(&self, rows: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather_rows(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
            groups: self.groups.clone(),
            response: self.response,
            name: self.name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_centers_linear_response() {
        let mut d = SyntheticConfig { n: 40, p: 12, ..SyntheticConfig::default() }
            .generate(3)
            .dataset;
        d.standardize();
        let ymean = d.y.iter().sum::<f64>() / d.y.len() as f64;
        assert!(ymean.abs() < 1e-10);
    }

    #[test]
    fn subset_rows_keeps_alignment() {
        let d = SyntheticConfig { n: 20, p: 6, ..SyntheticConfig::default() }
            .generate(4)
            .dataset;
        let s = d.subset_rows(&[3, 7, 11]);
        assert_eq!(s.n(), 3);
        assert_eq!(s.y[1], d.y[7]);
        assert_eq!(s.x.dense().get(2, 4), d.x.dense().get(11, 4));
    }
}
