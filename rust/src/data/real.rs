//! Surrogates for the six real datasets of §4 (Table A37).
//!
//! The original datasets (TCGA brca1, scheetz eye expression, the COVID
//! trust-experts survey, adenoma / celiac / tumour transcriptomes) are
//! external downloads unavailable in this offline environment. Screening
//! behaviour is governed by the *shape* of a problem — dimensionality,
//! group-size skew, response type, signal sparsity and within-group
//! correlation — so each surrogate reproduces its dataset's published
//! characteristics from Table A37 exactly (p, n, m, group-size range,
//! response family) together with a heavy-tailed group-size distribution
//! (gene-pathway sizes are famously power-law) and a sparse signal. See
//! DESIGN.md §5 for the substitution argument.

use super::synthetic::{GroupSpec, SyntheticConfig};
use super::{Dataset, Response};
use crate::rng::Rng;

/// The six datasets of the paper's real-data study.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RealDatasetKind {
    Brca1,
    Scheetz,
    TrustExperts,
    Adenoma,
    Celiac,
    Tumour,
}

impl RealDatasetKind {
    pub const ALL: [RealDatasetKind; 6] = [
        RealDatasetKind::Brca1,
        RealDatasetKind::Scheetz,
        RealDatasetKind::TrustExperts,
        RealDatasetKind::Adenoma,
        RealDatasetKind::Celiac,
        RealDatasetKind::Tumour,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RealDatasetKind::Brca1 => "brca1",
            RealDatasetKind::Scheetz => "scheetz",
            RealDatasetKind::TrustExperts => "trust-experts",
            RealDatasetKind::Adenoma => "adenoma",
            RealDatasetKind::Celiac => "celiac",
            RealDatasetKind::Tumour => "tumour",
        }
    }

    /// (p, n, m, min group size, max group size, response) from Table A37.
    pub fn shape(&self) -> (usize, usize, usize, usize, usize, Response) {
        match self {
            RealDatasetKind::Brca1 => (17322, 536, 243, 1, 6505, Response::Linear),
            RealDatasetKind::Scheetz => (18975, 120, 85, 1, 6274, Response::Linear),
            RealDatasetKind::TrustExperts => (101, 9759, 7, 4, 51, Response::Linear),
            RealDatasetKind::Adenoma => (18559, 64, 313, 1, 741, Response::Logistic),
            RealDatasetKind::Celiac => (14657, 132, 276, 1, 617, Response::Logistic),
            RealDatasetKind::Tumour => (18559, 52, 313, 1, 741, Response::Logistic),
        }
    }
}

/// Configuration for surrogate generation.
#[derive(Clone, Debug)]
pub struct SurrogateConfig {
    pub kind: RealDatasetKind,
    /// Scale factor on (p, n) to keep bench wall-clock practical while
    /// preserving the aspect ratio and group-size skew; 1.0 = full size.
    pub scale: f64,
    pub seed: u64,
}

impl SurrogateConfig {
    pub fn new(kind: RealDatasetKind) -> Self {
        SurrogateConfig { kind, scale: 1.0, seed: 1234 }
    }

    pub fn scaled(kind: RealDatasetKind, scale: f64) -> Self {
        SurrogateConfig { kind, scale, seed: 1234 }
    }

    /// Heavy-tailed group sizes: draw from a truncated Pareto-like law over
    /// `[lo, hi]` so a few pathway-style giant groups coexist with many
    /// singletons, then adjust to sum exactly to `p` with `m` groups.
    fn pathway_sizes(p: usize, m: usize, lo: usize, hi: usize, rng: &mut Rng) -> Vec<usize> {
        assert!(m >= 1 && p >= m * lo);
        let alpha = 1.2; // tail index — heavier than exponential
        let mut sizes: Vec<usize> = (0..m)
            .map(|_| {
                let u = rng.uniform().max(1e-12);
                let lo_f = lo as f64;
                let hi_f = hi as f64;
                // Inverse-CDF of truncated Pareto.
                let s = lo_f
                    * ((1.0 - u * (1.0 - (lo_f / hi_f).powf(alpha))).powf(-1.0 / alpha));
                (s.round() as usize).clamp(lo, hi)
            })
            .collect();
        // Rescale to sum to p while respecting bounds.
        loop {
            let total: usize = sizes.iter().sum();
            if total == p {
                break;
            }
            if total < p {
                // Grow a random group that has headroom.
                let deficit = p - total;
                let g = rng.below(m);
                let room = hi - sizes[g];
                let add = deficit.min(room.max(0));
                if add == 0 {
                    // All at cap (cannot happen when m*hi ≥ p).
                    sizes[g] += deficit;
                    break;
                }
                sizes[g] += add;
            } else {
                let excess = total - p;
                let g = rng.below(m);
                let room = sizes[g].saturating_sub(lo);
                let sub = excess.min(room);
                if sub == 0 {
                    continue;
                }
                sizes[g] -= sub;
            }
        }
        sizes
    }

    /// Generate the surrogate dataset (standardized).
    pub fn generate(&self) -> Dataset {
        let (p0, n0, m0, lo, hi, response) = self.kind.shape();
        let s = self.scale.clamp(0.01, 1.0);
        let p = ((p0 as f64 * s).round() as usize).max(20);
        let n = ((n0 as f64 * s).round() as usize).max(16);
        let m = ((m0 as f64 * s.sqrt()).round() as usize).clamp(2, p);
        let hi_s = ((hi as f64 * s).round() as usize).clamp(lo + 1, p);
        let mut rng = Rng::new(self.seed ^ (self.kind as u64) << 32);
        let sizes = Self::pathway_sizes(p, m, lo, hi_s.max(lo + 1), &mut rng);

        // Gene-expression-style correlation: stronger inside small pathways,
        // weaker inside giant catch-all groups.
        let rho = match self.kind {
            RealDatasetKind::TrustExperts => 0.15, // survey factors: near-orthogonal dummies
            _ => 0.35,
        };
        // Sparse signal: a handful of active pathways (matches the small
        // active sets of Table A39).
        let cfg = SyntheticConfig {
            n,
            p,
            groups: GroupSpec::Sizes(sizes),
            group_sparsity: (3.0 / m as f64).min(0.3),
            var_sparsity: 0.1,
            rho,
            signal: 1.5,
            noise_sd: 1.0,
            response,
            standardize: true,
        };
        let mut gd = cfg.generate(self.seed.wrapping_add(0x5EED));
        gd.dataset.name = self.kind.name().to_string();
        gd.dataset
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table_a37_at_full_scale() {
        for kind in RealDatasetKind::ALL {
            let (p, n, m, lo, hi, _) = kind.shape();
            assert!(p > 0 && n > 0 && m > 0 && lo <= hi);
        }
    }

    #[test]
    fn scaled_surrogate_preserves_aspect() {
        let ds = SurrogateConfig::scaled(RealDatasetKind::Celiac, 0.05).generate();
        // celiac: p=14657, n=132 → ≈ 733, ≈ 16 at 5%.
        assert!((ds.p() as f64 - 733.0).abs() < 40.0, "p = {}", ds.p());
        assert!(ds.n() >= 16);
        assert_eq!(ds.response, Response::Logistic);
        assert_eq!(ds.name, "celiac");
    }

    #[test]
    fn trust_experts_is_low_dimensional() {
        let ds = SurrogateConfig::new(RealDatasetKind::TrustExperts).generate();
        assert_eq!(ds.p(), 101);
        assert_eq!(ds.n(), 9759);
        assert_eq!(ds.m(), 7);
        assert_eq!(ds.response, Response::Linear);
    }

    #[test]
    fn pathway_sizes_sum_and_skew() {
        let mut rng = Rng::new(3);
        let sizes = SurrogateConfig::pathway_sizes(5000, 100, 1, 2000, &mut rng);
        assert_eq!(sizes.iter().sum::<usize>(), 5000);
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max > 20 * min.max(1), "not skewed: max {max} min {min}");
    }

    #[test]
    fn logistic_surrogates_have_binary_response() {
        let ds = SurrogateConfig::scaled(RealDatasetKind::Adenoma, 0.03).generate();
        assert!(ds.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
