//! Within-group interaction expansion (Table 1 / §D.4 of the paper).
//!
//! For each group, all pairwise (order 2) and optionally triple-wise
//! (order 3) products of its columns are appended as new features, *with no
//! interaction hierarchy imposed*. Expanded features stay in their parent
//! group (the paper keeps m = 52 groups while p grows from 400 to
//! p_O2 = 2111 / p_O3 = 7338), so group sizes grow combinatorially — the
//! regime where bi-level screening shines.

use super::{Dataset, GeneratedData};
use crate::groups::Groups;
use crate::linalg::Matrix;

/// Interaction expansion order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InteractionOrder {
    None,
    Order2,
    Order3,
}

/// Expand a dataset with within-group interactions. Returns the expanded
/// dataset plus, for bookkeeping, the parent indices of every output column
/// (singleton for main effects).
pub fn expand_interactions(
    base: &Dataset,
    order: InteractionOrder,
) -> (Dataset, Vec<Vec<usize>>) {
    if order == InteractionOrder::None {
        let parents = (0..base.p()).map(|j| vec![j]).collect();
        return (base.clone(), parents);
    }
    let n = base.n();
    let mut cols: Vec<Vec<f64>> = Vec::new();
    let mut sizes: Vec<usize> = Vec::new();
    let mut parents: Vec<Vec<usize>> = Vec::new();

    for (_, r) in base.groups.iter() {
        let vars: Vec<usize> = r.collect();
        let before = cols.len();
        // Main effects. (Interaction expansion is a dense-generator
        // feature — per-cell products have no sparse shortcut.)
        let base_x = base.x.dense();
        for &j in &vars {
            cols.push(base_x.col(j).to_vec());
            parents.push(vec![j]);
        }
        // Order-2 products.
        for a in 0..vars.len() {
            for b in (a + 1)..vars.len() {
                let (ja, jb) = (vars[a], vars[b]);
                let col: Vec<f64> = (0..n)
                    .map(|i| base_x.get(i, ja) * base_x.get(i, jb))
                    .collect();
                cols.push(col);
                parents.push(vec![ja, jb]);
            }
        }
        // Order-3 products.
        if order == InteractionOrder::Order3 {
            for a in 0..vars.len() {
                for b in (a + 1)..vars.len() {
                    for c in (b + 1)..vars.len() {
                        let (ja, jb, jc) = (vars[a], vars[b], vars[c]);
                        let col: Vec<f64> = (0..n)
                            .map(|i| {
                                base_x.get(i, ja) * base_x.get(i, jb) * base_x.get(i, jc)
                            })
                            .collect();
                        cols.push(col);
                        parents.push(vec![ja, jb, jc]);
                    }
                }
            }
        }
        sizes.push(cols.len() - before);
    }

    let mut x = Matrix::from_columns(n, &cols);
    x.standardize_l2();
    let dataset = Dataset {
        x: x.into(),
        y: base.y.clone(),
        groups: Groups::from_sizes(&sizes),
        response: base.response,
        name: format!("{}+interactions", base.name),
    };
    (dataset, parents)
}

/// Convenience: expand a generated synthetic problem, re-deriving the
/// response from main effects plus equally-strong interaction signal on a
/// fraction of the interaction columns (the paper uses "active proportion
/// 0.3, same signal as the marginal effects").
pub fn expand_generated(
    gd: &GeneratedData,
    order: InteractionOrder,
    interaction_active_prop: f64,
    signal: f64,
    seed: u64,
) -> Dataset {
    let (mut ds, parents) = expand_interactions(&gd.dataset, order);
    if order == InteractionOrder::None {
        return ds;
    }
    let mut rng = crate::rng::Rng::new(seed ^ 0xfeed);
    // Signal: keep main-effect signal where the parent was active; activate
    // a fraction of interaction columns whose parents are all active.
    let active: std::collections::HashSet<usize> = gd.active_vars.iter().copied().collect();
    let mut beta = vec![0.0; ds.p()];
    for (j, par) in parents.iter().enumerate() {
        if par.len() == 1 {
            // main effect: copy the original coefficient
            beta[j] = gd.beta_true[par[0]];
        } else if par.iter().all(|v| active.contains(v))
            && rng.bernoulli(interaction_active_prop)
        {
            beta[j] = rng.normal(0.0, signal);
        }
    }
    let xb = ds.x.matvec(&beta);
    ds.y = match ds.response {
        super::Response::Linear => {
            xb.iter().map(|v| v + rng.normal(0.0, 1.0)).collect()
        }
        super::Response::Logistic => xb
            .iter()
            .map(|v| {
                let prob = 1.0 / (1.0 + (-(v + rng.normal(0.0, 1.0))).exp());
                if rng.bernoulli(prob) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect(),
    };
    if ds.response == super::Response::Linear {
        let mean = ds.y.iter().sum::<f64>() / ds.y.len() as f64;
        ds.y.iter_mut().for_each(|v| *v -= mean);
    }
    ds
}

/// Expected expanded dimensionality for the given group sizes.
pub fn expanded_p(sizes: &[usize], order: InteractionOrder) -> usize {
    sizes
        .iter()
        .map(|&s| {
            let c2 = s * (s - 1) / 2;
            let c3 = if s >= 3 { s * (s - 1) * (s - 2) / 6 } else { 0 };
            match order {
                InteractionOrder::None => s,
                InteractionOrder::Order2 => s + c2,
                InteractionOrder::Order3 => s + c2 + c3,
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{GroupSpec, SyntheticConfig};

    fn base() -> GeneratedData {
        SyntheticConfig {
            n: 30,
            p: 12,
            groups: GroupSpec::Sizes(vec![3, 4, 5]),
            ..SyntheticConfig::default()
        }
        .generate(2)
    }

    #[test]
    fn order2_dimensions() {
        let gd = base();
        let (ds, parents) = expand_interactions(&gd.dataset, InteractionOrder::Order2);
        // 3+3, 4+6, 5+10 → sizes 6, 10, 15, p = 31.
        assert_eq!(ds.groups.sizes(), vec![6, 10, 15]);
        assert_eq!(ds.p(), 31);
        assert_eq!(parents.len(), 31);
        assert_eq!(expanded_p(&[3, 4, 5], InteractionOrder::Order2), 31);
    }

    #[test]
    fn order3_dimensions() {
        let gd = base();
        let (ds, _) = expand_interactions(&gd.dataset, InteractionOrder::Order3);
        // + C(3,3)=1, C(4,3)=4, C(5,3)=10 → 32+1+4+10 = 46... (31 + 15)
        assert_eq!(ds.p(), 31 + 15);
        assert_eq!(expanded_p(&[3, 4, 5], InteractionOrder::Order3), 46);
    }

    #[test]
    fn product_columns_are_products_pre_standardization() {
        let gd = base();
        let (_, parents) = expand_interactions(&gd.dataset, InteractionOrder::Order2);
        // Column for parents (a,b) within group 0 exists.
        let has_pair = parents.iter().any(|p| p.len() == 2);
        assert!(has_pair);
    }

    #[test]
    fn paper_scale_dimensions_are_in_band() {
        // p = 400, m = 52, sizes in [3, 15] → p_O2 ≈ 2111, p_O3 ≈ 7338.
        let mut rng = crate::rng::Rng::new(5);
        let sizes = crate::groups::Groups::random_sizes(400, 3, 15, &mut rng);
        let p2 = expanded_p(&sizes, InteractionOrder::Order2);
        let p3 = expanded_p(&sizes, InteractionOrder::Order3);
        assert!(p2 > 1300 && p2 < 3200, "p2 = {p2}");
        assert!(p3 > 4000 && p3 < 12000, "p3 = {p3}");
    }
}
