//! Synthetic data generation following §3.1 / Table A1 of the paper.
//!
//! `y = Xβ + ε` with `X ∼ N(0, Σ)`, where `Σ` applies correlation `ρ`
//! *within* each group (`Σᵢⱼ = ρ` for i, j in the same group, unit
//! diagonal). Sampling uses the equicorrelation factor representation
//! `xᵢⱼ = √ρ·z_g + √(1−ρ)·eᵢⱼ`, which realizes Σ exactly. The signal is
//! `β ∼ N(0, signal²)` on active variables; group- and within-group
//! sparsity follow the paper's 0.2/0.2 defaults. Logistic responses draw
//! `y ∼ Bernoulli(σ(Xβ + ε))` (§D.6).

use super::{Dataset, Response};
use crate::groups::Groups;
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Configuration for a synthetic experiment (defaults = Table A1).
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub n: usize,
    pub p: usize,
    /// Group layout; `GroupSpec::Uneven` draws sizes in `[lo, hi]`.
    pub groups: GroupSpec,
    /// Proportion of groups carrying signal.
    pub group_sparsity: f64,
    /// Proportion of variables carrying signal *within* an active group.
    pub var_sparsity: f64,
    /// Within-group correlation ρ of the design.
    pub rho: f64,
    /// Signal strength: β ∼ N(0, signal²) on active coordinates.
    pub signal: f64,
    /// Noise sd of ε.
    pub noise_sd: f64,
    pub response: Response,
    /// Standardize the design / center y after generation.
    pub standardize: bool,
}

/// How to lay variables into groups.
#[derive(Clone, Debug)]
pub enum GroupSpec {
    /// Even groups of a fixed size (Fig. 1 uses size 20).
    Even(usize),
    /// `m` is implied; sizes drawn uniformly in `[lo, hi]` summing to p
    /// (Table A1 default: [3, 100] giving m ≈ 22 at p = 1000).
    Uneven { lo: usize, hi: usize },
    /// Explicit sizes.
    Sizes(Vec<usize>),
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            n: 200,
            p: 1000,
            groups: GroupSpec::Uneven { lo: 3, hi: 100 },
            group_sparsity: 0.2,
            var_sparsity: 0.2,
            rho: 0.3,
            signal: 2.0,
            noise_sd: 1.0,
            response: Response::Linear,
            standardize: true,
        }
    }
}

/// A generated problem together with its ground truth.
#[derive(Clone, Debug)]
pub struct GeneratedData {
    pub dataset: Dataset,
    /// True coefficients on the *generated* (pre-standardization) scale.
    pub beta_true: Vec<f64>,
    pub active_groups: Vec<usize>,
    pub active_vars: Vec<usize>,
}

impl SyntheticConfig {
    /// Generate a dataset with the given seed. Deterministic.
    pub fn generate(&self, seed: u64) -> GeneratedData {
        let mut rng = Rng::new(seed);
        let sizes = match &self.groups {
            GroupSpec::Even(s) => Groups::even(self.p, *s).sizes(),
            GroupSpec::Uneven { lo, hi } => Groups::random_sizes(self.p, *lo, *hi, &mut rng),
            GroupSpec::Sizes(s) => s.clone(),
        };
        let groups = Groups::from_sizes(&sizes);
        assert_eq!(groups.p(), self.p, "group sizes must sum to p");
        let m = groups.m();

        // Design: per-row shared group factor + idiosyncratic noise.
        let sr = self.rho.max(0.0).sqrt();
        let se = (1.0 - self.rho.max(0.0)).sqrt();
        let mut x = Matrix::zeros(self.n, self.p);
        for i in 0..self.n {
            for g in 0..m {
                let z = rng.gauss();
                for j in groups.range(g) {
                    x.set(i, j, sr * z + se * rng.gauss());
                }
            }
        }

        // Sparse grouped signal.
        let n_active_groups = ((m as f64 * self.group_sparsity).round() as usize).clamp(1, m);
        let active_groups = rng.sample_indices(m, n_active_groups);
        let mut beta = vec![0.0; self.p];
        let mut active_vars = Vec::new();
        for &g in &active_groups {
            let p_g = groups.size(g);
            let k = ((p_g as f64 * self.var_sparsity).round() as usize).clamp(1, p_g);
            let start = groups.range(g).start;
            let within = rng.sample_indices(p_g, k);
            for w in within {
                let j = start + w;
                beta[j] = rng.normal(0.0, self.signal);
                active_vars.push(j);
            }
        }

        // Response.
        let xb = x.matvec(&beta);
        let y: Vec<f64> = match self.response {
            Response::Linear => {
                xb.iter().map(|v| v + rng.normal(0.0, self.noise_sd)).collect()
            }
            Response::Logistic => xb
                .iter()
                .map(|v| {
                    let eta = v + rng.normal(0.0, self.noise_sd);
                    let prob = 1.0 / (1.0 + (-eta).exp());
                    if rng.bernoulli(prob) {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect(),
        };

        let mut dataset = Dataset {
            x: x.into(),
            y,
            groups,
            response: self.response,
            name: format!("synthetic(p={}, n={})", self.p, self.n),
        };
        if self.standardize {
            dataset.standardize();
        }
        GeneratedData { dataset, beta_true: beta, active_groups, active_vars }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table_a1_shape() {
        let gd = SyntheticConfig::default().generate(1);
        let d = &gd.dataset;
        assert_eq!(d.n(), 200);
        assert_eq!(d.p(), 1000);
        // m ≈ 22 for sizes in [3, 100]; allow slack from the random draw.
        assert!(d.m() >= 10 && d.m() <= 60, "m = {}", d.m());
        assert!(!gd.active_vars.is_empty());
    }

    #[test]
    fn within_group_correlation_is_near_rho() {
        let cfg = SyntheticConfig {
            n: 4000,
            p: 10,
            groups: GroupSpec::Sizes(vec![5, 5]),
            rho: 0.5,
            standardize: false,
            ..SyntheticConfig::default()
        };
        let gd = cfg.generate(9);
        let x = gd.dataset.x.dense();
        let corr = |a: usize, b: usize| {
            let (ca, cb) = (x.col(a), x.col(b));
            let n = ca.len() as f64;
            let (ma, mb) = (
                ca.iter().sum::<f64>() / n,
                cb.iter().sum::<f64>() / n,
            );
            let mut num = 0.0;
            let mut va = 0.0;
            let mut vb = 0.0;
            for i in 0..ca.len() {
                num += (ca[i] - ma) * (cb[i] - mb);
                va += (ca[i] - ma).powi(2);
                vb += (cb[i] - mb).powi(2);
            }
            num / (va.sqrt() * vb.sqrt())
        };
        // Same group → ≈ 0.5; across groups → ≈ 0.
        assert!((corr(0, 1) - 0.5).abs() < 0.06, "within {}", corr(0, 1));
        assert!(corr(0, 7).abs() < 0.06, "across {}", corr(0, 7));
    }

    #[test]
    fn sparsity_proportions_respected() {
        let cfg = SyntheticConfig {
            p: 100,
            n: 50,
            groups: GroupSpec::Even(10),
            group_sparsity: 0.3,
            var_sparsity: 0.5,
            ..SyntheticConfig::default()
        };
        let gd = cfg.generate(4);
        assert_eq!(gd.active_groups.len(), 3);
        assert_eq!(gd.active_vars.len(), 15); // 3 groups × 5 vars
    }

    #[test]
    fn logistic_response_is_binary() {
        let cfg = SyntheticConfig {
            n: 60,
            p: 20,
            groups: GroupSpec::Even(5),
            response: Response::Logistic,
            ..SyntheticConfig::default()
        };
        let gd = cfg.generate(11);
        assert!(gd.dataset.y.iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = gd.dataset.y.iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 0 && ones < 60, "degenerate labels");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticConfig::default().generate(77);
        let b = SyntheticConfig::default().generate(77);
        assert_eq!(
            a.dataset.x.dense().as_slice()[..50],
            b.dataset.x.dense().as_slice()[..50]
        );
        assert_eq!(a.beta_true, b.beta_true);
    }
}
