//! λ-path construction (Appendix A.3 for SGL, B.2.1 for aSGL).
//!
//! `λ₁` is the exact point at which the first predictor enters:
//!
//! * SGL — the dual norm at zero: `λ₁ = max_g τ_g⁻¹‖∇_g f(0)‖_{ε_g}`.
//! * aSGL — γ_g is undefined at β ≡ 0's norm form, so λ₁ is the largest
//!   per-group root of the piecewise quadratic
//!   `‖S(∇_g f(0), λ α v^(g))‖₂² = p_g w_g²(1−α)²λ²` (solved here by
//!   monotone bisection; reduces to the dual-norm value for unit weights).
//!
//! Paths are log-linear over `[λ₁, ratio·λ₁]` (ratio 0.1 synthetic / 0.2
//! real, Table A1).

use crate::penalty::Penalty;

/// λ₁ for a penalty given the gradient of the loss at β = 0.
pub fn lambda_max(pen: &Penalty, grad0: &[f64]) -> f64 {
    if !pen.is_adaptive() {
        return crate::norms::dual_sgl_norm(grad0, &pen.groups, pen.alpha);
    }
    let mut best: f64 = 0.0;
    for (g, r) in pen.groups.iter() {
        best = best.max(group_entry_lambda(
            &grad0[r.clone()],
            &pen.v[r],
            pen.w[g],
            pen.alpha,
            pen.groups.size(g),
        ));
    }
    best
}

/// The λ at which group g would enter: root of
/// `h(λ) = ‖S(∇_g, λαv)‖₂ − √p_g w_g (1−α) λ`.
fn group_entry_lambda(grad_g: &[f64], v_g: &[f64], w_g: f64, alpha: f64, p_g: usize) -> f64 {
    let sqrt_pg = (p_g as f64).sqrt();
    let gnorm2: f64 = grad_g.iter().map(|x| x * x).sum::<f64>().sqrt();
    if gnorm2 == 0.0 {
        return 0.0;
    }
    if alpha == 0.0 {
        // Pure group lasso: ‖∇_g‖₂ = √p_g w_g λ.
        return gnorm2 / (sqrt_pg * w_g).max(1e-300);
    }
    if alpha == 1.0 || (1.0 - alpha) * w_g == 0.0 {
        // Pure (adaptive) lasso: λ = max |∇ᵢ|/(α vᵢ).
        return grad_g
            .iter()
            .zip(v_g)
            .map(|(gi, vi)| gi.abs() / (alpha * vi.max(1e-300)))
            .fold(0.0f64, f64::max);
    }
    let h = |lam: f64| -> f64 {
        let mut s = 0.0;
        for (gi, vi) in grad_g.iter().zip(v_g) {
            let t = crate::norms::soft_threshold(*gi, lam * alpha * vi);
            s += t * t;
        }
        s.sqrt() - sqrt_pg * w_g * (1.0 - alpha) * lam
    };
    // h(0) = ‖∇_g‖₂ > 0; find hi with h(hi) < 0 (S term vanishes once
    // λ ≥ max|∇ᵢ|/(αvᵢ)).
    let mut hi = grad_g
        .iter()
        .zip(v_g)
        .map(|(gi, vi)| gi.abs() / (alpha * vi.max(1e-300)))
        .fold(0.0f64, f64::max)
        .max(1e-12);
    while h(hi) > 0.0 {
        hi *= 2.0;
    }
    let mut lo = 0.0;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if h(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= 1e-14 * hi {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// Log-linear path `λ₁ ≥ … ≥ λ_l = ratio·λ₁`.
pub fn log_linear_path(lambda1: f64, len: usize, ratio: f64) -> Vec<f64> {
    assert!(len >= 1);
    assert!(ratio > 0.0 && ratio <= 1.0);
    if len == 1 {
        return vec![lambda1];
    }
    (0..len)
        .map(|i| lambda1 * ratio.powf(i as f64 / (len - 1) as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::Groups;
    use crate::linalg::Matrix;
    use crate::loss::{Loss, LossKind};
    use crate::rng::Rng;

    #[test]
    fn path_is_log_linear_and_monotone() {
        let p = log_linear_path(2.0, 5, 0.1);
        assert_eq!(p.len(), 5);
        assert!((p[0] - 2.0).abs() < 1e-15);
        assert!((p[4] - 0.2).abs() < 1e-12);
        for w in p.windows(2) {
            assert!(w[0] > w[1]);
            // constant ratio
            let r0 = p[1] / p[0];
            assert!((w[1] / w[0] - r0).abs() < 1e-12);
        }
    }

    #[test]
    fn adaptive_lambda_max_reduces_to_dual_norm_for_unit_weights() {
        let mut rng = Rng::new(1);
        let g = Groups::from_sizes(&[3, 5, 2]);
        let grad0: Vec<f64> = rng.gauss_vec(10);
        let pen_unit = Penalty::asgl(g.clone(), 0.7, vec![1.0; 10], vec![1.0; 3]);
        // Force the adaptive bisection path even with unit weights.
        let mut lam_a: f64 = 0.0;
        for (gg, r) in g.iter() {
            lam_a = lam_a.max(super::group_entry_lambda(
                &grad0[r.clone()],
                &pen_unit.v[r],
                1.0,
                0.7,
                g.size(gg),
            ));
        }
        let lam_d = crate::norms::dual_sgl_norm(&grad0, &g, 0.7);
        assert!((lam_a - lam_d).abs() < 1e-8 * lam_d, "{lam_a} vs {lam_d}");
    }

    #[test]
    fn lambda_max_gives_null_model_and_entry_just_below() {
        let mut rng = Rng::new(2);
        let p = 12;
        let mut x = Matrix::from_fn(40, p, |_, _| rng.gauss());
        x.standardize_l2();
        let mut y: Vec<f64> = rng.gauss_vec(40);
        let ym = y.iter().sum::<f64>() / 40.0;
        y.iter_mut().for_each(|v| *v -= ym);
        let g = Groups::even(p, 4);
        let pen = Penalty::sgl(g.clone(), 0.95);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam1 = lambda_max(&pen, &loss.gradient(&vec![0.0; p]));
        let cfg = crate::solver::SolverConfig { tol: 1e-10, max_iters: 50000, ..Default::default() };
        let at = crate::solver::solve(&loss, &pen, lam1 * (1.0 + 1e-6), &vec![0.0; p], &cfg);
        assert!(at.beta.iter().all(|&b| b == 0.0), "not null at λ₁");
        let below = crate::solver::solve(&loss, &pen, lam1 * 0.9, &vec![0.0; p], &cfg);
        assert!(below.beta.iter().any(|&b| b != 0.0), "nothing entered below λ₁");
    }

    #[test]
    fn alpha_edge_cases() {
        let grad = [3.0, -4.0];
        // α = 0: ‖∇‖₂/√2 = 5/√2.
        let l0 = super::group_entry_lambda(&grad, &[1.0, 1.0], 1.0, 0.0, 2);
        assert!((l0 - 5.0 / 2f64.sqrt()).abs() < 1e-12);
        // α = 1: max|∇|/v = 4.
        let l1 = super::group_entry_lambda(&grad, &[1.0, 1.0], 1.0, 1.0, 2);
        assert!((l1 - 4.0).abs() < 1e-12);
    }
}
